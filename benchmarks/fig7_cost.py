"""Fig. 7 analogue: minimum COST found by each algorithm, normalized to the
best cost found by any algorithm, per benchmark cell (geomean summary).

The search runs on a noisy cost model (sigma=0.25 — the paper's learned cost
model has substantial error vs. real exec time, §3); the reported metric is
the cost-model value of the chosen schedule, exactly like the paper's Fig. 7.
"""
from __future__ import annotations

import time

from benchmarks.common import (ALGOS_FIG7, ENGINE_STAMP as ENGINE, SUITE,
                               best_of_seeds, csv_line, emit, geomean)

NOISE = 0.25


def main(cells=None, seeds=(0, 1, 2)) -> dict:
    cells = cells or SUITE
    rows = []
    per_algo = {a: [] for a in ALGOS_FIG7}
    for arch, shape in cells:
        t0 = time.time()
        costs = {}
        walls = {}
        for algo in ALGOS_FIG7:
            ta = time.time()
            (res, mdp) = best_of_seeds(arch, shape, algo, seeds=seeds,
                                       noise_sigma=NOISE)
            walls[algo] = time.time() - ta
            costs[algo] = res.cost
        best = min(costs.values())
        for algo, c in costs.items():
            norm = c / best
            per_algo[algo].append(norm)
            rows.append({"cell": f"{arch}×{shape}", "algo": algo,
                         "cost_s": c, "normalized": norm,
                         "wall_s_all_seeds": walls[algo], "engine": ENGINE})
        print(f"[fig7] {arch}×{shape}: " + " ".join(
            f"{a}={costs[a]/best:.3f}" for a in ALGOS_FIG7) +
            f" ({time.time()-t0:.0f}s)", flush=True)
    summary = {a: geomean(v) for a, v in per_algo.items()}
    emit(rows + [{"cell": "GEOMEAN", "algo": a, "normalized": g,
                  "engine": ENGINE} for a, g in summary.items()], "fig7_cost")
    for a, g in summary.items():
        csv_line(f"fig7_cost_geomean[{a}]", 0.0, f"{g:.4f}")
    return summary


if __name__ == "__main__":
    main()
