"""Beyond-paper ablation: search robustness to cost-model error.

The paper ARGUES MCTS is 'more resilient to noise in the cost model' (§1,
§3) but never isolates it; we can.  Sweep the noise sigma of the cost model
and report the TRUE (noise-free) exec time of each algorithm's chosen plan,
relative to the noise-free optimum found by any algorithm."""
from __future__ import annotations

from benchmarks.common import csv_line, emit, geomean, run_algo, true_cost

CELLS = [
    ("phi3.5-moe-42b-a6.6b", "train_4k"),
    ("granite-3-2b", "train_4k"),
    ("deepseek-67b", "decode_32k"),
]
SIGMAS = [0.0, 0.15, 0.3, 0.6]
ALGOS = ["greedy", "beam", "mcts_10s"]


def main(seeds=(0, 1, 2)) -> dict:
    rows = []
    summary = {}
    for sigma in SIGMAS:
        per_algo = {a: [] for a in ALGOS}
        for arch, shape in CELLS:
            true_best = float("inf")
            found = {}
            for algo in ALGOS:
                best = float("inf")
                for seed in seeds:
                    res, _ = run_algo(arch, shape, algo, seed=seed,
                                      noise_sigma=sigma, noise_seed=7)
                    best = min(best, true_cost(arch, shape, res.plan))
                found[algo] = best
                true_best = min(true_best, best)
            for algo, c in found.items():
                per_algo[algo].append(c / true_best)
                rows.append({"sigma": sigma, "cell": f"{arch}×{shape}",
                             "algo": algo, "regret": c / true_best})
        summary[sigma] = {a: geomean(v) for a, v in per_algo.items()}
        print(f"[noise] sigma={sigma}: " + " ".join(
            f"{a}={summary[sigma][a]:.3f}" for a in ALGOS), flush=True)
    emit(rows, "noise_robustness")
    for sigma, d in summary.items():
        for a, g in d.items():
            csv_line(f"noise_regret[s={sigma}|{a}]", 0.0, f"{g:.4f}")
    return summary


if __name__ == "__main__":
    main()
