"""§Roofline report: per (arch × shape × mesh) — the three terms from the
compiled dry-run, dominant bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, MFU.

Reads experiments/dryrun/baseline.json (produced by scripts/sweep_dryrun.py);
cells missing from the cache are compiled on demand (subprocess)."""
from __future__ import annotations

import json
import os

from benchmarks.common import csv_line, emit

BASELINE = os.path.join("experiments", "dryrun", "baseline.json")


def load_baseline() -> dict:
    if os.path.exists(BASELINE):
        with open(BASELINE) as f:
            return json.load(f)["results"]
    # fall back: compile everything now (slow path)
    from repro.configs import cells
    from repro.core.measure import measure_cell

    out = {}
    for cfg, shape in cells():
        for mesh in ("single", "multi"):
            key = f"{cfg.name}|{shape.name}|{mesh}"
            out[key] = measure_cell(cfg.name, shape.name, mesh)
    return out


def main(mesh: str = "single") -> list:
    res = load_baseline()
    rows = []
    print(f"[roofline] {'cell':44s} {'compute':>9s} {'memory':>9s} "
          f"{'coll':>9s} {'step':>9s} dom        MFU   useful")
    for key in sorted(res):
        arch, shape, m = key.split("|")
        if m != mesh:
            continue
        r = res[key]
        rows.append({
            "cell": f"{arch}×{shape}", "mesh": m,
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "step_s": r["step_s"],
            "dominant": r["dominant"], "mfu": r["mfu"],
            "useful_flops_ratio": r["useful_flops_ratio"],
            "bytes_per_device": r["bytes_per_device"],
            "fits_hbm": r["fits_hbm"],
        })
        print(f"[roofline] {arch+'×'+shape:44s} "
              f"{r['compute_s']*1e3:8.1f}ms {r['memory_s']*1e3:8.1f}ms "
              f"{r['collective_s']*1e3:8.1f}ms {r['step_s']*1e3:8.1f}ms "
              f"{r['dominant']:10s} {r['mfu']:.3f} "
              f"{r['useful_flops_ratio']:.2f}")
    emit(rows, f"roofline_{mesh}")
    for r in rows:
        csv_line(f"roofline[{r['cell']}|{mesh}]", r["step_s"] * 1e6,
                 f"dom={r['dominant']};mfu={r['mfu']:.3f}")
    return rows


if __name__ == "__main__":
    main("single")
    main("multi")
