"""Declarative sweep harness: matrix spec → expand → run → measure via the
fleet → store stamped artifact rows.

The fig-7/fig-9/Table-1 style sweeps all share one shape — a cartesian
matrix of (cell × algo × budget …) over shared defaults — so, in the
style of matrix-benchmarking, the sweep IS a data file
(``benchmarks/sweeps/*.json``) and this module is the one runner:

    PYTHONPATH=src python -m benchmarks.sweep benchmarks/sweeps/fig9_budget.json
    ... --quick --measure stub --results /tmp/smoke    # CI smoke
    ... --measure real --workers 4                     # compile re-rank

Spec format (JSON — the perf-smoke CI env has no yaml)::

    {
      "name": "fig9_budget",
      "defaults": {"seed": 0, "noise_sigma": 0.25, ...},
      "matrix": {
        "cell": [["granite-3-2b", "train_4k"], ...],
        "algo": ["beam", "mcts_1s", "mcts_0.5s"]
      }
    }

Every expanded row gets a content-hash key over its settings; rows whose
key is already stored are skipped (resume a partial sweep for free, like
the measurement cache itself) unless ``--rerun``.  Phase 1 runs every
search; phase 2 fans ALL rows' best-plan measurements out in ONE
``MeasurementFleet.measure_many`` call (cache hits and single-flight
dedup included); phase 3 appends one JSONL row per cell to
``<results>/<name>.jsonl`` with the settings, engine provenance, wall
time, cost, and the measurement's retry/failure counters stamped.
``scripts/render_experiments.py`` renders the regression view over the
stored history.
"""
from __future__ import annotations

import argparse
import hashlib
import itertools
import json
import os
import sys
import time
from typing import Callable, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import ENGINE_STAMP, run_algo  # noqa: E402

DEFAULT_RESULTS = os.path.join("experiments", "sweeps")

ROW_DEFAULTS = {
    "mesh": "single",
    "seed": 0,
    "noise_sigma": 0.0,
    "noise_seed": 0,
    "engine": "array",
    "cost": "analytic",
    "budget_s": None,
    "n_standard": 15,
    "n_greedy": 1,
}


def load_spec(path: str) -> dict:
    with open(path) as f:
        spec = json.load(f)
    assert "name" in spec and "matrix" in spec, "spec needs name + matrix"
    return spec


def expand_spec(spec: dict) -> List[dict]:
    """Cartesian expansion of the matrix axes over the spec defaults.
    The ``cell`` axis is the (arch, shape) pair; every other axis value
    merges into the row settings under its axis name."""
    axes = spec["matrix"]
    names = sorted(axes)
    rows = []
    for combo in itertools.product(*(axes[n] for n in names)):
        row = dict(ROW_DEFAULTS)
        row.update(spec.get("defaults", {}))
        for name, value in zip(names, combo):
            if name == "cell":
                row["arch"], row["shape"] = value
            else:
                row[name] = value
        rows.append(row)
    return rows


def row_key(settings: dict) -> str:
    blob = json.dumps(settings, sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:12]


def stored_keys(path: str) -> set:
    """Keys of rows that are DONE — a stored row whose measurement failed
    (``measured_step_s: null`` with a failed outcome) does not count, so a
    transient fleet failure is retried on the next resume instead of being
    pinned forever."""
    keys = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    row = json.loads(line)
                    key = row["key"]
                except (ValueError, KeyError):
                    continue  # a torn row never blocks a sweep
                measure = row.get("measure")
                if isinstance(measure, dict) and measure.get("failed"):
                    continue
                keys.add(key)
    return keys


def run_sweep(
    spec: dict,
    *,
    results_dir: str = DEFAULT_RESULTS,
    measure: str = "none",
    workers: int = 4,
    quick: bool = False,
    rerun: bool = False,
    fleet_kwargs: Optional[dict] = None,
    inject: Optional[Callable[[int, dict], None]] = None,
    log=print,
) -> List[dict]:
    """Run one sweep spec end to end; returns the newly stored rows.

    ``measure``: ``none`` (search only), ``stub`` (analytic stub records
    via the fleet — deterministic, XLA-free), ``real`` (subprocess XLA
    compiles via the fleet).  ``inject`` is the fault-injection hook the
    CI gate uses: called as ``inject(i, request)`` on each measurement
    request before dispatch (mutate ``request["extras"]`` in place).
    """
    assert measure in ("none", "stub", "real"), measure
    name = spec["name"]
    os.makedirs(results_dir, exist_ok=True)
    out_path = os.path.join(results_dir, f"{name}.jsonl")
    done = set() if rerun else stored_keys(out_path)

    rows = expand_spec(spec)
    if quick:
        rows = rows[:1]
    todo = []
    for settings in rows:
        key = row_key(settings)
        if key in done:
            continue
        todo.append((key, settings))
    log(f"[sweep:{name}] {len(rows)} row(s) expanded, "
        f"{len(rows) - len(todo)} already stored, {len(todo)} to run")
    if not todo:
        return []

    # phase 1: searches
    results = []
    for key, s in todo:
        t0 = time.perf_counter()
        res, _mdp = run_algo(
            s["arch"], s["shape"], s["algo"], seed=s["seed"],
            noise_sigma=s["noise_sigma"], noise_seed=s["noise_seed"],
            time_budget_s=s["budget_s"], n_standard=s["n_standard"],
            n_greedy=s["n_greedy"], engine=s["engine"], cost=s["cost"],
        )
        wall = time.perf_counter() - t0
        results.append((key, s, res, wall))
        log(f"[sweep:{name}] {s['arch']}×{s['shape']} {s['algo']}: "
            f"cost {res.cost * 1e3:.2f} ms in {wall:.1f}s")

    # phase 2: one fan-out over every row's winning plan
    outcomes = [None] * len(results)
    fleet_stats = None
    if measure != "none":
        from repro.core.measure import make_request
        from repro.core.measure_fleet import MeasurementFleet

        fkw = dict(fleet_kwargs or {})
        if measure == "stub":
            from repro.core.measure_stub import stub_measure

            fkw.setdefault("target", stub_measure)
            fkw.setdefault(
                "cache_dir", os.path.join(results_dir, "measure_cache")
            )
        with MeasurementFleet(n_workers=workers, **fkw) as fleet:
            reqs = []
            for i, (key, s, res, wall) in enumerate(results):
                req = make_request(
                    s["arch"], s["shape"], s["mesh"], res.plan,
                    timeout=fleet.timeout,
                )
                if inject is not None:
                    inject(i, req)
                reqs.append(req)
            outcomes = fleet.measure_many(reqs)
            fleet_stats = fleet.stats()
        log(f"[sweep:{name}] fleet: {fleet_stats}")

    # phase 3: stamp + store
    new_rows = []
    with open(out_path, "a") as f:
        for (key, s, res, wall), out in zip(results, outcomes):
            row = {
                "sweep": name,
                "key": key,
                "settings": s,
                "engine": ENGINE_STAMP,
                "ts": time.time(),
                "cost": res.cost,
                "wall_s": round(wall, 3),
                "n_evals": res.n_evals,
                "n_measure_failures": res.n_measure_failures,
                "plan": res.plan.to_dict(),
                "measure_mode": measure,
                "measured_step_s": (
                    out.record["step_s"] if out is not None and out.ok
                    else None
                ),
                "measure": out.provenance() if out is not None else None,
                "fleet": fleet_stats,
            }
            f.write(json.dumps(row, default=str) + "\n")
            new_rows.append(row)
    log(f"[sweep:{name}] stored {len(new_rows)} row(s) → {out_path}")
    return new_rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("spec", help="path to a JSON matrix spec")
    ap.add_argument("--results", default=DEFAULT_RESULTS)
    ap.add_argument("--measure", default="none",
                    choices=["none", "stub", "real"])
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--quick", action="store_true",
                    help="run only the first expanded row (CI smoke)")
    ap.add_argument("--rerun", action="store_true",
                    help="re-run rows whose key is already stored")
    ap.add_argument("--list", action="store_true", dest="list_only",
                    help="print the expanded rows and exit")
    args = ap.parse_args(argv)
    spec = load_spec(args.spec)
    if args.list_only:
        for s in expand_spec(spec):
            print(row_key(s), json.dumps(s, sort_keys=True, default=str))
        return 0
    run_sweep(
        spec,
        results_dir=args.results,
        measure=args.measure,
        workers=args.workers,
        quick=args.quick,
        rerun=args.rerun,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
