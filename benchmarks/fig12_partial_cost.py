"""Fig. 1/2 analogue (§3): a cost model trained on random COMPLETE schedules
cannot rank PARTIAL schedules.

We train the learned MLP cost model on random complete schedules, then
measure Spearman rank correlation against the oracle on (a) complete
schedules and (b) partial prefixes of increasing depth (scored through their
default completion — the only thing beam search can do).  The paper's
observation is the monotone degradation in (b).

Everything prices through the BATCH seam: training labels and both
correlation legs go through ``cost_batch`` (one columnar-kernel pass per
sweep for the analytic oracle, one jitted forward pass for the MLP, with
the prefix legs default-completed against the space's memoized default
actions) — so this artifact exercises the same batched pricing path the
engine serves, not a private scalar loop."""
from __future__ import annotations

from benchmarks.common import ENGINE_STAMP, csv_line, emit
from repro.core.autotuner import make_mdp
from repro.core.learned_cost import ranking_correlation, train_learned_cost

CELLS = [
    ("phi3.5-moe-42b-a6.6b", "train_4k"),
    ("deepseek-67b", "train_4k"),
    ("jamba-1.5-large-398b", "train_4k"),
]


def main() -> dict:
    out = {}
    rows = []
    for arch, shape in CELLS:
        mdp = make_mdp(arch, shape)
        lcm = train_learned_cost(mdp.space, mdp.cost_model, n_samples=384,
                                 steps=400, seed=0)
        rc_complete = ranking_correlation(lcm, mdp.cost_model, mdp.space, n=128)
        depths = [2, 4, 6, 8]
        rc_partial = {
            d: ranking_correlation(lcm, mdp.cost_model, mdp.space, n=128,
                                   partial_depth=d)
            for d in depths
        }
        out[f"{arch}"] = {"complete": rc_complete, **{f"d{d}": v for d, v in rc_partial.items()}}
        rows.append({"cell": f"{arch}×{shape}", "complete": rc_complete,
                     **{f"partial_d{d}": v for d, v in rc_partial.items()},
                     "engine": ENGINE_STAMP,
                     "pricing": "cost_batch (columnar)"})
        print(f"[fig12] {arch}: complete={rc_complete:.3f} " +
              " ".join(f"d{d}={v:.3f}" for d, v in rc_partial.items()),
              flush=True)
    emit(rows, "fig12_partial_cost")
    avg_c = sum(r["complete"] for r in rows) / len(rows)
    avg_p = sum(r["partial_d4"] for r in rows) / len(rows)
    csv_line("fig12_spearman_complete", 0.0, f"{avg_c:.3f}")
    csv_line("fig12_spearman_partial_d4", 0.0, f"{avg_p:.3f}")
    return out


if __name__ == "__main__":
    main()
