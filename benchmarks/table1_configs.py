"""Table 1 analogue: the MCTS configuration sweep (UCB variants, budgets,
0/1-reward ablation) on the 16-cell suite — geomean normalized cost + the
paper's §4.1 claims (binary rewards ≈9%% worse; best-cost root choice)."""
from __future__ import annotations

import time

from benchmarks.common import (ENGINE_STAMP as ENGINE, SUITE, best_of_seeds,
                               csv_line, emit, geomean)

NOISE = 0.25
VARIANTS = [
    "mcts_30s",
    "mcts_10s",
    "mcts_1s",
    "mcts_Cp10_30s",
    "mcts_sqrt2_30s",
    "mcts_binary_30s",  # §4.1: 0/1 rewards (paper: 9% worse)
]


def main(cells=None, seeds=(0, 1)) -> dict:
    cells = cells or SUITE
    per_variant = {v: [] for v in VARIANTS}
    rows = []
    for arch, shape in cells:
        costs = {}
        walls = {}
        for v in VARIANTS:
            t0 = time.time()
            res, _ = best_of_seeds(arch, shape, v, seeds=seeds, noise_sigma=NOISE)
            walls[v] = time.time() - t0
            costs[v] = res.cost
        best = min(costs.values())
        for v, c in costs.items():
            per_variant[v].append(c / best)
            rows.append({"cell": f"{arch}×{shape}", "variant": v,
                         "cost_s": c, "normalized": c / best,
                         "wall_s_all_seeds": walls[v], "engine": ENGINE})
        print(f"[table1] {arch}×{shape}: " + " ".join(
            f"{v}={costs[v]/best:.3f}" for v in VARIANTS), flush=True)
    summary = {v: geomean(xs) for v, xs in per_variant.items()}
    emit(rows, "table1_configs")
    for v, g in summary.items():
        csv_line(f"table1_geomean[{v}]", 0.0, f"{g:.4f}")
    if summary["mcts_binary_30s"] > summary["mcts_30s"]:
        delta = (summary["mcts_binary_30s"] / summary["mcts_30s"] - 1) * 100
        csv_line("table1_binary_reward_penalty_pct", 0.0, f"{delta:.1f}")
    return summary


if __name__ == "__main__":
    main()
