"""Shared benchmark infrastructure: the 16-cell suite (the paper evaluates
16 Halide apps; our analogue spans all 10 archs × all 4 shape families),
algorithm runners with paper-protocol budgets, CSV emission."""
from __future__ import annotations

import dataclasses
import json
import math
import os
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.autotuner import TABLE1, autotune, make_mdp  # noqa: E402
from repro.core.mcts import MCTSConfig  # noqa: E402

# The 16 "benchmarks" (DESIGN.md §6). jamba plays ResNet50's role (the big
# multi-stage app where real measurement is impractical).
SUITE = [
    ("granite-3-2b", "train_4k"),
    ("granite-3-2b", "prefill_32k"),
    ("granite-3-2b", "decode_32k"),
    ("stablelm-12b", "train_4k"),
    ("stablelm-12b", "decode_32k"),
    ("nemotron-4-15b", "train_4k"),
    ("nemotron-4-15b", "prefill_32k"),
    ("deepseek-67b", "train_4k"),
    ("deepseek-67b", "decode_32k"),
    ("qwen2-vl-72b", "train_4k"),
    ("qwen2-vl-72b", "prefill_32k"),
    ("musicgen-large", "train_4k"),
    ("granite-moe-1b-a400m", "train_4k"),
    ("phi3.5-moe-42b-a6.6b", "train_4k"),
    ("jamba-1.5-large-398b", "long_500k"),
    ("falcon-mamba-7b", "long_500k"),
]

# iteration budgets scaled from the paper's 30s/10s/1s C++ budgets so one
# full suite pass stays CPU-tractable; relative ratios preserved (×24 : ×8 : ×1)
BUDGETS = {"30s": 32, "10s": 12, "1s": 4, "0.5s": 2}

# provenance stamp for every published artifact row: the engine that
# produced the timing columns (search VALUES are engine-independent —
# tests/test_differential.py).  ONE constant so benchmarks can never
# publish contradictory engine provenance.
ENGINE_STAMP = ("array (batched leaves + shared transposition cache "
                "+ columnar cost kernel)")

ALGOS_FIG7 = [
    "random",
    "greedy",
    "beam",
    "mcts_1s",
    "mcts_10s",
    "mcts_30s",
    "mcts_Cp10_30s",
    "mcts_sqrt2_30s",
]


def scaled_cfg(name: str) -> Optional[MCTSConfig]:
    if not name.startswith("mcts"):
        return None
    base = TABLE1.get(name, TABLE1["mcts_30s"])
    for suffix, iters in BUDGETS.items():
        if name.endswith(suffix):
            return dataclasses.replace(base, iters_per_decision=iters)
    return base


def run_algo(
    arch: str,
    shape: str,
    algo: str,
    seed: int = 0,
    noise_sigma: float = 0.0,
    noise_seed: int = 0,
    measure_fn=None,
    time_budget_s: Optional[float] = None,
    n_standard: int = 15,
    n_greedy: int = 1,
    engine: str = "array",
    cost: str = "analytic",
    pricing: Optional[str] = None,
):
    """One search run under the paper protocol (scaled budgets).

    The cost model's noise (``noise_seed``) is fixed per cell so all
    algorithms rank against the SAME (imperfect) model; only the search
    seed varies across repetitions.  MCTS runs drive the vectorized array
    engine (batched leaf evaluation + shared transposition cache) by
    default — search results are certified identical to the reference
    engine by ``tests/test_differential.py``; pass ``engine="reference"``
    for the paper-faithful Node trees.  ``cost`` selects the serving layer
    of the cost stack (``"analytic"`` exact — the default for every
    published figure — or ``"learned"``/``"hybrid"`` online learned-cost
    serving; see ``repro.core.engine.serving``).  ``pricing`` selects the
    analytic kernel (None exact columnar, ``"jit"`` the jax-jitted path
    with its versioned tag; see ``cost_model.py``)."""
    mdp = make_mdp(arch, shape, noise_sigma=noise_sigma, noise_seed=noise_seed,
                   pricing=pricing)
    if algo.startswith("mcts"):
        from repro.core.ensemble import ProTuner

        cfg = dataclasses.replace(scaled_cfg(algo), seed=seed)
        tuner = ProTuner(
            mdp,
            n_standard=n_standard,
            n_greedy=n_greedy,
            mcts_config=cfg,
            measure_fn=measure_fn if "real" in algo else None,
            seed=seed,
            engine=engine,
            cost=cost,
        )
        res = tuner.run(time_budget_s=time_budget_s)
        res.algo = algo
        return res, mdp
    res = autotune(arch, shape, algo=algo, seed=seed, mdp=mdp,
                   measure_fn=measure_fn, time_budget_s=time_budget_s,
                   engine=engine, cost=cost)
    return res, mdp


def best_of_seeds(arch, shape, algo, seeds=(0, 1, 2), **kw):
    """Paper protocol: run with different seeds, report the best schedule."""
    best = None
    for s in seeds:
        res, mdp = run_algo(arch, shape, algo, seed=s, **kw)
        if best is None or res.cost < best[0].cost:
            best = (res, mdp)
    return best


def true_cost(arch, shape, plan) -> float:
    """Noise-free analytic cost of a plan (the 'would-be' step time)."""
    return make_mdp(arch, shape).cost_model.cost(plan)


def emit(rows: List[dict], name: str, outdir: str = "experiments/bench"):
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)


def csv_line(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def geomean(xs):
    xs = [x for x in xs if x > 0]
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 0.0
