"""Evolve vs MCTS at equal eval budget — the PR-8 acceptance benchmark.

Per Table-1 headline cell (the decode cell and the MoE train cell):

1. run the MCTS ensemble (``mcts_1s`` scaled protocol) and record its
   final analytic cost and its unique-eval consumption ``E``;
2. populate a throwaway ``PlanStore`` the same way production would —
   ``autotune(..., plan_store=...)`` runs of the cheap baselines (beam,
   greedy) record their plans;
3. run ``algo="evolve"`` with the store's plans seeding generation 0 and
   ``max_evals=E`` — the SAME unique-plan pricing budget MCTS consumed
   (evolve checks the budget between generations, so the overshoot is at
   most one population; actual consumption is in the artifact);
4. run ``algo="portfolio"`` under the same budget for the artifact (its
   members share one cache, so the budget is portfolio-wide).

The headline number is ``evolve_vs_mcts`` — the acceptance gate
(``--check``) requires it ≤ EVOLVE_MCTS_RATIO on BOTH cells: the
evolutionary searcher over complete plans, warm-started from stored
knowledge, must match the tree searcher on equal footing.  Search and
pricing are deterministic for fixed seeds, so the ratio is exactly
reproducible — this is a hard gate, not a wall-clock one.

    PYTHONPATH=src python -m benchmarks.evolve_portfolio
    PYTHONPATH=src python -m benchmarks.evolve_portfolio --quick --check
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time

from benchmarks.common import ENGINE_STAMP, csv_line, emit, run_algo
from repro.core.autotuner import autotune, make_mdp
from repro.core.engine.backend import resolve_backend
from repro.service.store import PlanStore

# headline cells (paper Table 1): decode first, then the MoE train cell
CELLS = [
    ("decode", "granite-3-2b", "decode_32k"),
    ("moe_train", "granite-moe-1b-a400m", "train_4k"),
]

# acceptance: evolve's final analytic cost within 5% of the MCTS ensemble's
# at the same unique-eval budget (deterministic for fixed seeds)
EVOLVE_MCTS_RATIO = 1.05

# the MCTS reference configuration: the scaled ``1s`` ensemble protocol
MCTS_ALGO = "mcts_1s"

# store-seeding baselines: cheap searches whose recorded plans warm
# generation 0 (production equivalent: whatever anyone tuned on the cell)
SEED_ALGOS = ("beam", "greedy")


def bench_cell(name, arch, shape, *, store_dir, n_standard=15, n_greedy=1,
               seed=0) -> dict:
    # 1. the MCTS reference run sets the eval budget
    t0 = time.perf_counter()
    res_m, _ = run_algo(arch, shape, MCTS_ALGO, seed=seed,
                        n_standard=n_standard, n_greedy=n_greedy)
    wall_m = time.perf_counter() - t0
    budget = res_m.n_evals

    # 2. populate the plan store through the production path
    store = PlanStore(store_dir)
    for algo in SEED_ALGOS:
        autotune(arch, shape, algo=algo, seed=seed, plan_store=store)
    seeds = store.seed_plans(arch=arch, shape=shape, mesh="single")

    # 3. evolve at the same budget, generation 0 warm-started from the store
    t0 = time.perf_counter()
    res_e = resolve_backend("evolve").run(
        make_mdp(arch, shape), seed=seed, max_evals=budget,
        seed_plans=seeds)
    wall_e = time.perf_counter() - t0

    # 4. portfolio at the same (shared) budget, same seeding
    t0 = time.perf_counter()
    res_p = resolve_backend("portfolio").run(
        make_mdp(arch, shape), seed=seed, max_evals=budget,
        seed_plans=seeds, n_standard=4, n_greedy=1)
    wall_p = time.perf_counter() - t0

    row = {
        "cell": name,
        "arch": arch,
        "shape": shape,
        "engine": ENGINE_STAMP,
        "mcts_algo": MCTS_ALGO,
        "n_trees": n_standard + n_greedy,
        "eval_budget": budget,
        "mcts_cost": res_m.cost,
        "mcts_wall_s": wall_m,
        "n_seed_plans": len(seeds),
        "seed_algos": list(SEED_ALGOS),
        "evolve_cost": res_e.cost,
        "evolve_evals": res_e.n_evals,
        "evolve_generations": len(res_e.decisions),
        "evolve_wall_s": wall_e,
        "evolve_vs_mcts": res_e.cost / res_m.cost,
        "portfolio_cost": res_p.cost,
        "portfolio_evals": res_p.n_evals,
        "portfolio_members_run": len(res_p.decisions),
        "portfolio_winner": next(
            d["member"] for d in res_p.decisions if d["winner"]),
        "portfolio_wall_s": wall_p,
        "portfolio_vs_mcts": res_p.cost / res_m.cost,
    }
    csv_line(
        f"evolve_portfolio[{name}]", wall_e * 1e6,
        f"evolve {row['evolve_vs_mcts']:.4f}x vs {MCTS_ALGO} at "
        f"{budget} evals (evolve used {res_e.n_evals}, "
        f"{row['evolve_generations']} gens, {len(seeds)} store seeds); "
        f"portfolio {row['portfolio_vs_mcts']:.4f}x "
        f"(winner={row['portfolio_winner']})")
    return row


def main(n_standard: int = 15, n_greedy: int = 1, publish: bool = True) -> list:
    rows = []
    for name, arch, shape in CELLS:
        with tempfile.TemporaryDirectory() as store_dir:
            rows.append(bench_cell(name, arch, shape, store_dir=store_dir,
                                   n_standard=n_standard, n_greedy=n_greedy))
    if publish:  # scaled-down (--quick / CI-gate) runs must not overwrite
        emit(rows, "evolve_portfolio")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="scaled-down ensemble (7+1 trees)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless evolve reaches within "
                         f"{EVOLVE_MCTS_RATIO}x of the MCTS cost on BOTH "
                         "headline cells at equal eval budget "
                         "(deterministic — no retry)")
    args = ap.parse_args()
    kw = dict(n_standard=7, publish=False) if args.quick else {}
    rows = main(**kw)
    for r in rows:
        print(f"# {r['cell']}: evolve {r['evolve_vs_mcts']:.4f}x vs "
              f"{MCTS_ALGO} at {r['eval_budget']} evals; portfolio "
              f"{r['portfolio_vs_mcts']:.4f}x (winner "
              f"{r['portfolio_winner']})")
    if args.check:
        bad = [
            f"{r['cell']}: evolve {r['evolve_vs_mcts']:.4f}x > "
            f"{EVOLVE_MCTS_RATIO}x the {MCTS_ALGO} cost"
            for r in rows if r["evolve_vs_mcts"] > EVOLVE_MCTS_RATIO
        ]
        if bad:
            print("# CHECK FAILED: " + "; ".join(bad))
            sys.exit(1)
        print(f"# check passed: evolve within {EVOLVE_MCTS_RATIO}x of "
              f"{MCTS_ALGO} on both headline cells at equal eval budget")
