"""Learned-cost serving benchmark: the three cost-serving modes on the
Table-1 headline cells.

For each cell, runs the same Table-1 ensemble three times —
``cost="analytic"`` (the certified exact path), ``cost="hybrid"``
(online-trained MLP serves cache-miss batches only while its holdout
Spearman clears the confidence gate), and ``cost="learned"`` (the model
serves unconditionally once it exists — the gate-off ablation) — and
reports:

* wall time and the learned/analytic pricing split (how much of the miss
  traffic the model absorbed, and in how many batched forward passes);
* plan quality under the EXACT model: every run's final plan is re-priced
  by the analytic oracle (``TuneResult.cost`` is always exact-analytic),
  so ``quality_ratio`` = mode_cost / analytic_cost — 1.0 means the learned
  server found an equally good schedule, >1.0 quantifies what model error
  cost the search (the gate's job is to keep hybrid pinned at ≈1.0);
* the trainer's fit log (versions, dataset sizes, holdout Spearman).

Context for reading the numbers: the analytic oracle here costs ≈100 µs
per plan, so on CPU the MLP serve CANNOT win wall-clock — the benchmark
measures the quality/coverage tradeoff of the serving seam.  The seam pays
in wall time when the layer below is expensive (real measurement, or the
paper's compile-and-run oracle).  Note also that on-policy cache snapshots
are HARDER to rank than fig-12's uniform random schedules (the search
concentrates samples in near-tied cost regions), so holdout Spearman runs
well below the fig-12 headline — that is the finding, not a bug.

    PYTHONPATH=src python -m benchmarks.learned_serving [--quick]
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import csv_line, emit
from repro.core.autotuner import make_mdp
from repro.core.engine import HybridCostBackend, OnlineCostTrainer
from repro.core.ensemble import ProTuner
from repro.core.mcts import MCTSConfig

CELLS = [
    ("granite-3-2b", "decode_32k"),
    ("granite-moe-1b-a400m", "train_4k"),
]


def run_cell(cell, *, iters: int, n_standard: int, n_greedy: int,
             seed: int = 0) -> dict:
    arch, shape = cell
    out = {"cell": "x".join(cell), "iters_per_decision": iters,
           "n_trees": n_standard + n_greedy, "engine": "array"}

    def one(cost):
        mdp = make_mdp(arch, shape)
        cfg = MCTSConfig(iters_per_decision=iters, seed=seed)
        tuner = ProTuner(mdp, n_standard=n_standard, n_greedy=n_greedy,
                         mcts_config=cfg, seed=seed, cost=cost)
        t0 = time.perf_counter()
        res = tuner.run()
        return res, time.perf_counter() - t0, tuner.cost_backend

    res_a, wall_a, _ = one("analytic")
    out["analytic_wall_s"] = wall_a
    out["analytic_cost"] = res_a.cost
    name = out["cell"]
    csv_line(f"learned_serving[{name}][analytic]", wall_a * 1e6,
             f"{res_a.cost*1e3:.3f} ms plan")

    space = make_mdp(arch, shape).space
    for mode in ("hybrid", "learned"):
        # confidence gate at the fig-12 complete-schedule ballpark: serve
        # only while the model ranks held-out cache entries well (the gate
        # is only consulted in hybrid mode)
        trainer = OnlineCostTrainer(space, min_examples=64, refit_every=256,
                                    steps=200, confidence_threshold=0.8)
        res_m, wall_m, backend = one(
            HybridCostBackend(space, mode=mode, trainer=trainer)
        )
        st = backend.stats()
        frac = st["learned_plans"] / max(
            st["learned_plans"] + st["analytic_plans"], 1)
        out[f"{mode}_wall_s"] = wall_m
        out[f"{mode}_cost"] = res_m.cost
        out[f"{mode}_quality_ratio"] = (
            res_m.cost / res_a.cost if res_a.cost else 0.0
        )
        out[f"{mode}_n_fits"] = st["n_fits"]
        out[f"{mode}_holdout_spearman"] = st["holdout_spearman"]
        out[f"{mode}_learned_batches"] = st["learned_batches"]
        out[f"{mode}_learned_fraction"] = frac
        out[f"{mode}_fit_log"] = [
            {"version": r.version, "n": r.n_examples,
             "holdout_spearman": r.holdout_spearman,
             "confident": r.confident}
            for r in trainer.reports
        ]
        csv_line(
            f"learned_serving[{name}][{mode}]", wall_m * 1e6,
            f"{res_m.cost*1e3:.3f} ms plan; "
            f"quality x{out[f'{mode}_quality_ratio']:.3f}; "
            f"learned_fraction={frac:.2f}; fits={st['n_fits']}; "
            f"spearman={st['holdout_spearman']}")
    return out


def main(iters: int = 384, n_standard: int = 15, n_greedy: int = 1) -> list:
    rows = [run_cell(c, iters=iters, n_standard=n_standard,
                     n_greedy=n_greedy) for c in CELLS]
    emit(rows, "learned_serving")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="scaled-down budgets (96 iters, 7+1 trees)")
    args = ap.parse_args()
    kw = dict(iters=96, n_standard=7) if args.quick else {}
    rows = main(**kw)
    r = rows[0]
    print(f"# headline {r['cell']}: gated hybrid quality "
          f"x{r['hybrid_quality_ratio']:.3f} vs exact-analytic "
          f"(served {r['hybrid_learned_fraction']:.0%} of miss pricing); "
          f"ungated learned quality x{r['learned_quality_ratio']:.3f} "
          f"(served {r['learned_learned_fraction']:.0%}, "
          f"{r['learned_learned_batches']} batched forward passes)")
