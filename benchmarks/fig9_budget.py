"""Fig. 9 analogue: autotuning under a fixed WALL-CLOCK budget per cell
(paper: 15 min; ours: scaled to 20 s of 1-core Python per cell).  Each
algorithm reruns with fresh seeds until the budget is exhausted; the best
schedule found within budget is scored (noise-free exec time).
mcts_0.5s / mcts_1s use per-decision second budgets, as in the paper.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import SUITE, csv_line, emit, geomean, run_algo, true_cost
from repro.core.autotuner import make_mdp
from repro.core.ensemble import ProTuner
from repro.core.mcts import MCTSConfig

NOISE = 0.25
BUDGET_S = 20.0


def _budget_mcts(arch, shape, per_decision_s, budget_s, seed0=0):
    t0, seed = time.time(), seed0
    best_plan, best_cost = None, float("inf")
    while time.time() - t0 < budget_s:
        mdp = make_mdp(arch, shape, noise_sigma=NOISE, noise_seed=0)
        cfg = MCTSConfig(seconds_per_decision=per_decision_s, seed=seed)
        tuner = ProTuner(mdp, n_standard=15, n_greedy=1, mcts_config=cfg, seed=seed)
        res = tuner.run(time_budget_s=max(budget_s - (time.time() - t0), 0.5))
        if res.cost < best_cost:
            best_cost, best_plan = res.cost, res.plan
        seed += 1
    return best_plan


def _budget_beam(arch, shape, budget_s, seed0=0):
    from repro.core.beam import beam_search

    t0, seed = time.time(), seed0
    best_plan, best_cost = None, float("inf")
    while time.time() - t0 < budget_s:
        mdp = make_mdp(arch, shape, noise_sigma=NOISE, noise_seed=0)
        res = beam_search(mdp, beam_size=32, passes=5, seed=seed,
                          time_budget_s=max(budget_s - (time.time() - t0), 0.5))
        if res.cost < best_cost:
            best_cost, best_plan = res.cost, res.plan
        seed += 1
    return best_plan


def main(cells=None, budget_s: float = BUDGET_S) -> dict:
    cells = cells or SUITE[:8]
    algos = {
        "beam": lambda a, s: _budget_beam(a, s, budget_s),
        "mcts_1s": lambda a, s: _budget_mcts(a, s, 0.08, budget_s),
        "mcts_0.5s": lambda a, s: _budget_mcts(a, s, 0.04, budget_s),
    }
    rows, per_algo = [], {a: [] for a in algos}
    for arch, shape in cells:
        res = {name: true_cost(arch, shape, fn(arch, shape))
               for name, fn in algos.items()}
        best = min(res.values())
        for name, c in res.items():
            per_algo[name].append(c / best)
            rows.append({"cell": f"{arch}×{shape}", "algo": name,
                         "exec_s": c, "normalized": c / best})
        print(f"[fig9] {arch}×{shape}: " + " ".join(
            f"{n}={c/best:.3f}" for n, c in res.items()), flush=True)
    summary = {a: geomean(v) for a, v in per_algo.items()}
    emit(rows, "fig9_budget")
    for a, g in summary.items():
        csv_line(f"fig9_budget_geomean[{a}]", budget_s * 1e6, f"{g:.4f}")
    return summary


if __name__ == "__main__":
    main()
