"""Fig. 4/5 analogue: ensemble composition ablation — X standard + Y greedy
MCTSes (16 trees total), on four cells (the paper used bilateral_grid,
nl_means, iir_blur, max_filter).  Reports the best exec time per mix and the
fraction of root decisions won by greedy trees (Fig. 4's metric, which we
log directly in ``TuneResult.decisions``)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import csv_line, emit, geomean, scaled_cfg, true_cost
from repro.core.autotuner import make_mdp
from repro.core.ensemble import ProTuner

NOISE = 0.25
CELLS = [
    ("granite-3-2b", "train_4k"),
    ("phi3.5-moe-42b-a6.6b", "train_4k"),
    ("jamba-1.5-large-398b", "long_500k"),
    ("deepseek-67b", "decode_32k"),
]
MIXES = [(16, 0), (15, 1), (12, 4), (8, 8), (0, 16)]


def main(cells=None, seeds=(0, 1)) -> dict:
    cells = cells or CELLS
    rows = []
    summary = {}
    for arch, shape in cells:
        per_mix = {}
        for n_std, n_gr in MIXES:
            best_cost, greedy_frac = float("inf"), 0.0
            for seed in seeds:
                mdp = make_mdp(arch, shape, noise_sigma=NOISE, noise_seed=0)
                cfg = dataclasses.replace(scaled_cfg("mcts_10s"), seed=seed)
                tuner = ProTuner(mdp, n_standard=n_std, n_greedy=n_gr,
                                 mcts_config=cfg, seed=seed)
                res = tuner.run()
                c = true_cost(arch, shape, res.plan)
                if c < best_cost:
                    best_cost = c
                    wins = [d["winner_greedy"] for d in res.decisions]
                    greedy_frac = sum(wins) / max(len(wins), 1)
            per_mix[f"{n_std}_{n_gr}"] = (best_cost, greedy_frac)
        best = min(v[0] for v in per_mix.values())
        for mix, (c, gf) in per_mix.items():
            rows.append({"cell": f"{arch}×{shape}", "mix": mix,
                         "exec_s": c, "speedup_vs_best": best / c,
                         "greedy_decision_frac": gf})
        summary[f"{arch}×{shape}"] = {
            m: round(best / c, 4) for m, (c, _) in per_mix.items()
        }
        print(f"[fig45] {arch}×{shape}: " + " ".join(
            f"{m}={best/c:.3f}(g%={gf:.2f})" for m, (c, gf) in per_mix.items()),
            flush=True)
    emit(rows, "fig45_ensemble")
    # geomean speedup per mix across cells (Fig. 5 summary; paper: 15_1 best)
    for mix in ["16_0", "15_1", "12_4", "8_8", "0_16"]:
        g = geomean([summary[c][mix] for c in summary])
        csv_line(f"fig45_speedup[{mix}]", 0.0, f"{g:.4f}")
    return summary


if __name__ == "__main__":
    main()
