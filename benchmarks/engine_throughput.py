"""Engine throughput: reference Node-tree MCTS vs the vectorized array
engine, one-at-a-time vs batched leaf evaluation.

Runs the Table-1 ensemble protocol (384 iterations/decision, 15 standard
+ 1 greedy tree) on two representative cells with three engine legs — the
searches are behaviorally identical for the same seeds (certified by
``tests/test_differential.py``), so this is a pure implementation
comparison:

* ``reference``     — paper-faithful Node trees, scalar pricing, no cache;
* ``array_scalar``  — the PR-1 array engine: flat arrays + shared
  transposition cache, but one-at-a-time leaf evaluation;
* ``array``         — the default engine: lockstep pending-leaf rounds
  with batched terminal-cost evaluation (``run_decision_batch`` +
  ``cost_batch``).

Reported per cell: iterations/sec per leg, cache hits/misses, and two
speedups — ``speedup`` (batched array vs reference, the end-to-end win)
and ``speedup_batched_vs_scalar`` (the isolated value of batching leaf
evaluation over the PR-1 engine; ~1.5-1.9x on the decode headline cell at
Table-1 scale, reported but NOT gated — per-leg ratios are too
load-sensitive on small CI runners).  ``--check`` enforces exactly two
things: the array engine beats the reference on the decode cell, and all
legs produce identical results — the CI perf-smoke gate that keeps the
default flip honest.

    PYTHONPATH=src python -m benchmarks.engine_throughput
    PYTHONPATH=src python -m benchmarks.engine_throughput --quick --check
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import csv_line, emit
from repro.core.autotuner import make_mdp
from repro.core.ensemble import ProTuner
from repro.core.mcts import MCTSConfig

# headline first: the decode cell's compact space is where the shared
# cache pays off hardest (96%+ hit rate at Table-1 budgets) and where
# selection/backprop — what the batched driver restructures — dominate
CELLS = [
    ("granite-3-2b", "decode_32k"),
    ("granite-moe-1b-a400m", "train_4k"),
]


def run_ensemble(cell, engine: str, *, iters: int, n_standard: int,
                 n_greedy: int, seed: int = 0, cache=None,
                 parallel: bool = False, batch=None):
    """One full tuning run; returns (TuneResult, iterations, wall_s)."""
    arch, shape = cell
    mdp = make_mdp(arch, shape)
    cfg = MCTSConfig(iters_per_decision=iters, seed=seed)
    tuner = ProTuner(mdp, n_standard=n_standard, n_greedy=n_greedy,
                     mcts_config=cfg, seed=seed, engine=engine, cache=cache,
                     parallel=parallel, batch=batch)
    t0 = time.perf_counter()
    res = tuner.run()
    wall = time.perf_counter() - t0
    n_trees = n_standard + n_greedy
    total_iters = iters * n_trees * len(res.decisions)
    return res, total_iters, wall


def bench_cell(cell, *, iters: int, n_standard: int, n_greedy: int) -> dict:
    out = {"cell": "x".join(cell), "iters_per_decision": iters,
           "n_trees": n_standard + n_greedy,
           # the engine that produced the headline (array_*) columns — the
           # repo default since PR 2; render_experiments.py reports this
           "engine": "array (batched leaves + shared transposition cache)"}

    res_ref, it_ref, wall_ref = run_ensemble(
        cell, "reference", iters=iters, n_standard=n_standard,
        n_greedy=n_greedy)
    out["reference_wall_s"] = wall_ref
    out["reference_iters_per_sec"] = it_ref / wall_ref
    out["reference_evals"] = res_ref.n_evals

    res_sca, it_sca, wall_sca = run_ensemble(
        cell, "array", batch=False, iters=iters, n_standard=n_standard,
        n_greedy=n_greedy)
    out["array_scalar_wall_s"] = wall_sca
    out["array_scalar_iters_per_sec"] = it_sca / wall_sca

    res_arr, it_arr, wall_arr = run_ensemble(
        cell, "array", iters=iters, n_standard=n_standard, n_greedy=n_greedy)
    out["array_wall_s"] = wall_arr
    out["array_iters_per_sec"] = it_arr / wall_arr
    out["array_evals"] = res_arr.n_evals
    out["cache_hits"] = res_arr.cache_hits
    out["cache_misses"] = res_arr.cache_misses
    out["cache_hit_rate"] = res_arr.cache_hits / max(
        res_arr.cache_hits + res_arr.cache_misses, 1)
    out["evals_saved"] = res_ref.n_evals - res_arr.n_evals
    out["speedup"] = out["array_iters_per_sec"] / out["reference_iters_per_sec"]
    out["speedup_batched_vs_scalar"] = (
        out["array_iters_per_sec"] / out["array_scalar_iters_per_sec"])
    out["same_result"] = (
        res_ref.plan == res_sca.plan == res_arr.plan
        and res_ref.cost == res_sca.cost == res_arr.cost
        and [d["action"] for d in res_ref.decisions]
        == [d["action"] for d in res_sca.decisions]
        == [d["action"] for d in res_arr.decisions])

    name = out["cell"]
    csv_line(f"engine_throughput[{name}][reference]", wall_ref * 1e6,
             f"{out['reference_iters_per_sec']:.0f} it/s")
    csv_line(f"engine_throughput[{name}][array+scalar]", wall_sca * 1e6,
             f"{out['array_scalar_iters_per_sec']:.0f} it/s")
    csv_line(f"engine_throughput[{name}][array+batched]", wall_arr * 1e6,
             f"{out['array_iters_per_sec']:.0f} it/s")
    csv_line(f"engine_throughput_speedup[{name}]", 0.0,
             f"{out['speedup']:.1f}x vs reference; "
             f"{out['speedup_batched_vs_scalar']:.2f}x batched-vs-scalar; "
             f"cache_hits={out['cache_hits']}; "
             f"hit_rate={out['cache_hit_rate']:.3f}; "
             f"evals_saved={out['evals_saved']}; same={out['same_result']}")
    return out


def main(iters: int = 384, n_standard: int = 15, n_greedy: int = 1) -> list:
    rows = [bench_cell(c, iters=iters, n_standard=n_standard,
                       n_greedy=n_greedy) for c in CELLS]
    emit(rows, "engine_throughput")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="scaled-down budgets (96 iters, 7+1 trees)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless the array engine beats reference on "
                         "the decode cell with identical results (CI gate)")
    args = ap.parse_args()
    kw = dict(iters=96, n_standard=7) if args.quick else {}
    rows = main(**kw)
    r = rows[0]
    print(f"# headline {r['cell']}: {r['speedup']:.2f}x vs reference, "
          f"{r['speedup_batched_vs_scalar']:.2f}x batched-vs-scalar "
          f"({r['array_scalar_iters_per_sec']:.0f} -> "
          f"{r['array_iters_per_sec']:.0f} it/s), "
          f"cache hits {r['cache_hits']}, evals saved {r['evals_saved']}, "
          f"identical result: {r['same_result']}")
    if args.check:
        bad = []
        for row in rows:
            if not row["same_result"]:
                bad.append(f"{row['cell']}: engines diverged")
        if rows[0]["speedup"] < 1.0:
            bad.append(
                f"{rows[0]['cell']}: array engine slower than reference "
                f"({rows[0]['speedup']:.2f}x)")
        if bad:
            print("# CHECK FAILED: " + "; ".join(bad))
            sys.exit(1)
        print("# check passed: array >= reference on the decode cell, "
              "all legs identical")
