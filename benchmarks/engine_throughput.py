"""Engine throughput: reference Node-tree MCTS vs the vectorized
array engine with the shared transposition cache.

Runs the Table-1 ensemble protocol (384 iterations/decision, 15 standard
+ 1 greedy tree) on two representative cells with both engines — the
searches are behaviorally identical for the same seeds, so this is a pure
implementation comparison — and reports:

* iterations/sec for each engine,
* cost-model evaluations saved by the transposition cache (hits), and
* the end-to-end speedup.  The headline cell (a serving/decode cell,
  where tree reuse revisits a compact schedule space and transposition
  sharing is strongest) must clear ≥5×; the train cell shows the
  lower-bound speedup on a much larger space.

    PYTHONPATH=src python -m benchmarks.engine_throughput
    PYTHONPATH=src python -m benchmarks.engine_throughput --quick
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import csv_line, emit
from repro.core.autotuner import make_mdp
from repro.core.ensemble import ProTuner
from repro.core.mcts import MCTSConfig

# headline first: the decode cell's compact space is where the shared
# cache pays off hardest (96%+ hit rate at Table-1 budgets)
CELLS = [
    ("granite-3-2b", "decode_32k"),
    ("granite-moe-1b-a400m", "train_4k"),
]


def run_ensemble(cell, engine: str, *, iters: int, n_standard: int,
                 n_greedy: int, seed: int = 0, cache=None,
                 parallel: bool = False):
    """One full tuning run; returns (TuneResult, iterations, wall_s)."""
    arch, shape = cell
    mdp = make_mdp(arch, shape)
    cfg = MCTSConfig(iters_per_decision=iters, seed=seed)
    tuner = ProTuner(mdp, n_standard=n_standard, n_greedy=n_greedy,
                     mcts_config=cfg, seed=seed, engine=engine, cache=cache,
                     parallel=parallel)
    t0 = time.perf_counter()
    res = tuner.run()
    wall = time.perf_counter() - t0
    n_trees = n_standard + n_greedy
    total_iters = iters * n_trees * len(res.decisions)
    return res, total_iters, wall


def bench_cell(cell, *, iters: int, n_standard: int, n_greedy: int) -> dict:
    out = {"cell": "x".join(cell), "iters_per_decision": iters,
           "n_trees": n_standard + n_greedy}

    res_ref, it_ref, wall_ref = run_ensemble(
        cell, "reference", iters=iters, n_standard=n_standard,
        n_greedy=n_greedy)
    out["reference_wall_s"] = wall_ref
    out["reference_iters_per_sec"] = it_ref / wall_ref
    out["reference_evals"] = res_ref.n_evals

    res_arr, it_arr, wall_arr = run_ensemble(
        cell, "array", iters=iters, n_standard=n_standard, n_greedy=n_greedy)
    out["array_wall_s"] = wall_arr
    out["array_iters_per_sec"] = it_arr / wall_arr
    out["array_evals"] = res_arr.n_evals
    out["cache_hits"] = res_arr.cache_hits
    out["cache_misses"] = res_arr.cache_misses
    out["cache_hit_rate"] = res_arr.cache_hits / max(
        res_arr.cache_hits + res_arr.cache_misses, 1)
    out["evals_saved"] = res_ref.n_evals - res_arr.n_evals
    out["speedup"] = out["array_iters_per_sec"] / out["reference_iters_per_sec"]
    out["same_result"] = (res_ref.plan == res_arr.plan
                          and res_ref.cost == res_arr.cost)

    name = out["cell"]
    csv_line(f"engine_throughput[{name}][reference]", wall_ref * 1e6,
             f"{out['reference_iters_per_sec']:.0f} it/s")
    csv_line(f"engine_throughput[{name}][array+cache]", wall_arr * 1e6,
             f"{out['array_iters_per_sec']:.0f} it/s")
    csv_line(f"engine_throughput_speedup[{name}]", 0.0,
             f"{out['speedup']:.1f}x; cache_hits={out['cache_hits']}; "
             f"hit_rate={out['cache_hit_rate']:.3f}; "
             f"evals_saved={out['evals_saved']}; same={out['same_result']}")
    return out


def main(iters: int = 384, n_standard: int = 15, n_greedy: int = 1) -> dict:
    rows = [bench_cell(c, iters=iters, n_standard=n_standard,
                       n_greedy=n_greedy) for c in CELLS]
    emit(rows, "engine_throughput")
    return rows[0]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="scaled-down budgets (96 iters, 7+1 trees)")
    args = ap.parse_args()
    kw = dict(iters=96, n_standard=7) if args.quick else {}
    r = main(**kw)
    print(f"# headline {r['cell']}: speedup {r['speedup']:.2f}x  "
          f"({r['reference_iters_per_sec']:.0f} -> "
          f"{r['array_iters_per_sec']:.0f} it/s), "
          f"cache hits {r['cache_hits']}, evals saved {r['evals_saved']}, "
          f"identical result: {r['same_result']}")
