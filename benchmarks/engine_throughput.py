"""Engine throughput: reference Node-tree MCTS vs the vectorized array
engine — one-at-a-time vs batched leaf evaluation vs the columnar kernel.

Runs the Table-1 ensemble protocol (384 iterations/decision, 15 standard
+ 1 greedy tree) on two representative cells with four engine legs — the
searches are behaviorally identical for the same seeds (certified by
``tests/test_differential.py``), so this is a pure implementation
comparison:

* ``reference``      — paper-faithful Node trees, scalar pricing, no cache;
* ``array_scalar``   — the PR-1 array engine: flat arrays + shared
  transposition cache, but one-at-a-time leaf evaluation;
* ``array_batched``  — the PR-2 engine: lockstep pending-leaf rounds with
  batched terminal-cost evaluation, miss batches priced by the scalar
  per-plan replay (``AnalyticCostModel(columnar=False)``);
* ``array``          — the default engine: the same lockstep rounds with
  miss batches priced by the COLUMNAR roofline kernel
  (``PlanColumns`` + ``_terms_columnar``, one vectorized pass per batch).

A ``parallel`` leg rides along per cell: the default array engine run
through the persistent pinned process pool (``engine/workers.py``) at
TWO workers — the few-core parity configuration — with the shared-memory
cache transport and in-worker lockstep batching on (the pool defaults),
plus an ``export``-transport pool leg (``shm=False, worker_batch=False``,
the PR-5 configuration) as the deterministic baseline.  Reported against
the batched-sequential leg: wall clock, the payload-byte counters —
submit/return bytes per round, the one-time init snapshot, the
steady-state forward-delta size vs the export baseline's — and the
CROSS-WORKER DUPLICATE EVAL counters (states priced by two or more
workers in the same round; all of these are deterministic for fixed
seeds).  On the warm-cache decode cell the steady-round dup count must
be exactly zero in shm mode, and the steady shm submit payload must not
exceed the export baseline's.

The ``--parity`` mode runs ONLY this 2-worker comparison (for the CI
few-core step, pinned to 2 CPUs via ``taskset``): deterministic gates
are hard, and the pool>=batched-sequential wall gate engages only when
the process actually has 2+ CPUs to run on
(``len(os.sched_getaffinity(0))``) — on a 1-core box the pool cannot
win and only the catastrophic floor applies.

A cost-kernel microbenchmark rides along per cell (``kernel_*`` columns):
one deduplicated batch of random unique plans priced scalar-batched vs
columnar, isolating the kernel win from engine bookkeeping — at Table-1
miss-batch sizes the column math clears the scalar replay by whatever the
end-to-end legs can't show once cache hit rates pass 99%.  A second
microbench (``kernel_jit_*`` columns) compares all THREE pricing paths —
scalar replay, columnar kernel, jax-jitted kernel — on the
``cost_columns`` seam (pre-encoded ``PlanColumns``, so shared dedup/encode
overhead is out of the picture) at batch sizes 1/16/256: batch 1 shows the
jax dispatch floor that keeps ``JIT_MIN_BATCH`` above 1, batch 256 is the
generation-sized burst where the jitted kernel must beat the columnar one.

Gate policy (``--check``): gates split into DETERMINISTIC ones (identical
results across legs, byte counters, restart counts — exactly reproducible
for fixed seeds, so any miss is a real regression and fails immediately)
and WALL-CLOCK ratio ones (speedups, kernel crossovers — subject to CI
cgroup throttling bursts that can halve a leg).  A wall-clock miss
triggers ONE full re-run of the benchmark: the check fails only if a
wall-clock gate misses on both runs (or a deterministic gate misses at
all).  This keeps the flake rate quadratically small without ever
loosening the deterministic guarantees.

Reported per cell: iterations/sec per leg, cache hits/misses, and three
speedups — ``speedup`` (columnar array vs reference, the end-to-end win),
``speedup_batched_vs_scalar`` (batching leaf evaluation over PR-1), and
``speedup_columnar_vs_batched`` (the columnar kernel over the scalar
replay, end-to-end).  ``--check`` enforces three things on the decode
headline cell: the array engine beats the reference, all legs produce
identical results, and the columnar kernel does not regress the hot path
— the isolated kernel microbench must beat the scalar replay outright,
and the end-to-end columnar leg must clear a catastrophic-regression
floor (per-leg end-to-end ratios swing wildly under CI cgroup
throttling; the microbench, measured back-to-back, is where a silent
kernel regression cannot hide).

    PYTHONPATH=src python -m benchmarks.engine_throughput
    PYTHONPATH=src python -m benchmarks.engine_throughput --quick --check
"""
from __future__ import annotations

import argparse
import os
import random
import sys
import time

from benchmarks.common import ENGINE_STAMP, csv_line, emit
from repro.core.autotuner import make_mdp
from repro.core.cost_model import AnalyticCostModel
from repro.core.engine.shm_cache import HAVE_SHM
from repro.core.ensemble import ProTuner
from repro.core.mcts import MCTSConfig

# headline first: the decode cell's compact space is where the shared
# cache pays off hardest (96%+ hit rate at Table-1 budgets) and where
# selection/backprop — what the batched driver restructures — dominate
CELLS = [
    ("granite-3-2b", "decode_32k"),
    ("granite-moe-1b-a400m", "train_4k"),
]

# the end-to-end columnar-vs-batched gate tolerance: at 99%+ cache hit
# rates pricing is a sliver of wall time, so the leg ratio is parity plus
# scheduler noise — and on cgroup-throttled CI runners a throttling burst
# can halve a whole leg (observed: identical code measured anywhere from
# 0.62x to 1.11x).  The tight regression catch is therefore the kernel
# microbench (4-9x margin, adjacent measurements, robust under
# throttling); the leg floor only catches a CATASTROPHIC end-to-end
# regression (e.g. the kernel engaging where it loses badly).
COLUMNAR_LEG_FLOOR = 0.5
KERNEL_BATCH = 256  # microbench batch: a Table-1 first-round miss burst
# kernel_jit microbench grid: the jax dispatch floor (1), the columnar
# dispatch threshold (16), and a generation/miss-burst width (256) — the
# batch the jit-vs-columnar gate runs at
KERNEL_JIT_BATCHES = (1, 16, 256)

# parallel-leg gates.  The pool legs run at exactly PARITY_WORKERS
# workers — the few-core configuration the shm transport and in-worker
# lockstep batching are built to win at.  The BYTE and COUNTER gates are
# deterministic (pickled sizes and eval counts for fixed seeds):
# consecutive steady-state rounds within a constant factor, no round's
# forward delta anywhere near the init snapshot, ZERO cross-worker
# duplicate evals in steady (warm-cache) rounds under shm — round 0 pays
# an unavoidable cold-cache overlap; every later round's frontier is
# deduplicated through the folded shm log — and the shm submit payload
# strictly below the export-transport baseline measured in the same run.
# The WALL gate depends on the box: with PARITY_WORKERS+ CPUs actually
# schedulable the pool must match or beat the batched-sequential leg
# (soft, retry-once); on fewer CPUs the pool cannot win by construction
# and only a catastrophic floor applies — this box's timings swing
# ±10-20%, so the floor is generous.
PARITY_WORKERS = 2
PARALLEL_ROUND_RATIO = 4.0      # consecutive steady-state submit rounds
PARALLEL_WALL_RATIO = 4.0       # parallel may not be > 4x slower ...
PARALLEL_WALL_FLOOR_S = 5.0     # ... unless both legs are under 5s anyway


def _n_cpus() -> int:
    """CPUs this process can actually schedule on (taskset/cgroup aware)."""
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # pragma: no cover - non-Linux
        return os.cpu_count() or 1


def run_ensemble(cell, engine: str, *, iters: int, n_standard: int,
                 n_greedy: int, seed: int = 0, cache=None,
                 parallel: bool = False, batch=None, columnar: bool = True,
                 n_workers=None, shm=None, worker_batch=None):
    """One full tuning run; returns (TuneResult, iterations, wall_s).
    ``columnar=False`` flips the cell's cost model to the pre-columnar
    scalar replay (values bit-identical; only the pricing path changes).
    ``n_workers``/``shm``/``worker_batch`` configure the pinned pool for
    parallel legs (None = the pool's own defaults).  Repetition/noise
    handling lives in ``bench_cell`` (rotating best-of-reps), not here."""
    arch, shape = cell
    mdp = make_mdp(arch, shape)
    mdp.cost_model.columnar = columnar
    cfg = MCTSConfig(iters_per_decision=iters, seed=seed)
    tuner = ProTuner(mdp, n_standard=n_standard, n_greedy=n_greedy,
                     mcts_config=cfg, seed=seed, engine=engine,
                     cache=cache, parallel=parallel, batch=batch,
                     n_workers=n_workers, shm=shm, worker_batch=worker_batch)
    t0 = time.perf_counter()
    res = tuner.run()
    wall = time.perf_counter() - t0
    total_iters = iters * (n_standard + n_greedy) * len(res.decisions)
    return res, total_iters, wall


def bench_kernel(cell, *, n_plans: int = KERNEL_BATCH, reps: int = 5) -> dict:
    """The isolated pricing comparison: one deduplicated batch of random
    unique plans, scalar-batched replay vs the columnar kernel.  Values
    are asserted identical; the ratio is the kernel's clean win."""
    arch, shape = cell
    mdp = make_mdp(arch, shape)
    space = mdp.space
    rng = random.Random(0)
    seen, plans = set(), []
    while len(plans) < n_plans:
        p = space.random_plan(rng)
        if p not in seen:
            seen.add(p)
            plans.append(p)
    cfg, shp, mesh = space.cfg, space.shape, space.mesh
    scalar = AnalyticCostModel(cfg, shp, mesh, columnar=False)
    columnar = AnalyticCostModel(cfg, shp, mesh)  # default: kernel + dispatch
    assert scalar.cost_batch(plans) == columnar.cost_batch(plans)  # warm + certify
    t_s = min(
        _timed(lambda: scalar.cost_batch(plans)) for _ in range(reps)
    )
    t_c = min(
        _timed(lambda: columnar.cost_batch(plans)) for _ in range(reps)
    )
    return {
        "kernel_batch": len(plans),
        "kernel_scalar_us_per_plan": t_s / len(plans) * 1e6,
        "kernel_columnar_us_per_plan": t_c / len(plans) * 1e6,
        "kernel_speedup": t_s / t_c,
    }


def bench_kernel_jit(cell, *, reps: int = 5) -> dict:
    """Three-way pricing-path comparison on the ``cost_columns`` seam:
    scalar replay vs columnar kernel vs jax-jitted kernel over the SAME
    pre-encoded ``PlanColumns`` batches at sizes 1/16/256 (adjacent
    best-of-reps measurements, dedup/encode excluded — the cleanest view
    of each kernel's own cost).  The jitted model is warmed first so XLA
    compiles never land in a timed rep.  Values are certified along the
    way: scalar == columnar exactly, jit within JIT_RTOL."""
    from repro.core.cost_model import JIT_RTOL, PlanColumns

    arch, shape = cell
    mdp = make_mdp(arch, shape)
    space = mdp.space
    rng = random.Random(0)
    seen, plans = set(), []
    while len(plans) < max(KERNEL_JIT_BATCHES):
        p = space.random_plan(rng)
        if p not in seen:
            seen.add(p)
            plans.append(p)
    cfg, shp, mesh = space.cfg, space.shape, space.mesh
    # min_batch=1 on the kernel models so batch 1 really measures the
    # kernels (the production dispatch would route it to scalar replay)
    models = {
        "scalar": AnalyticCostModel(cfg, shp, mesh, columnar=False),
        "columnar": AnalyticCostModel(cfg, shp, mesh, columnar_min_batch=1),
        "jit": AnalyticCostModel(cfg, shp, mesh, pricing="jit",
                                 columnar_min_batch=1),
    }
    out = {"kernel_jit_batches": list(KERNEL_JIT_BATCHES)}
    for b in KERNEL_JIT_BATCHES:
        cols = PlanColumns.from_plans(plans[:b])
        vals = {}
        for name, m in models.items():
            vals[name] = m.cost_columns(cols)  # warm: ctx + jit compile
            t = min(_timed(lambda: m.cost_columns(cols)) for _ in range(reps))
            out[f"kernel_{name}_us_per_plan_b{b}"] = t / b * 1e6
        assert vals["scalar"] == vals["columnar"]
        import numpy as _np
        _np.testing.assert_allclose(
            _np.asarray(vals["jit"]), _np.asarray(vals["columnar"]),
            rtol=JIT_RTOL, atol=0.0)
        out[f"kernel_jit_vs_columnar_b{b}"] = (
            out[f"kernel_columnar_us_per_plan_b{b}"]
            / out[f"kernel_jit_us_per_plan_b{b}"])
        out[f"kernel_jit_vs_scalar_b{b}"] = (
            out[f"kernel_scalar_us_per_plan_b{b}"]
            / out[f"kernel_jit_us_per_plan_b{b}"])
    return out


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _same_result(a, b) -> bool:
    return (a.plan == b.plan and a.cost == b.cost
            and [d["action"] for d in a.decisions]
            == [d["action"] for d in b.decisions])


def _steady(rounds) -> int:
    """The steady-state (cache-warm) per-round payload: worst of the last
    two rounds — round 0 carries the cold-cache burst."""
    return max(rounds[-2:]) if rounds else 0


def bench_parallel(cell, *, iters: int, n_standard: int, n_greedy: int,
                   reps: int = 2) -> dict:
    """The few-core parity comparison: batched-sequential vs the pinned
    pool at ``PARITY_WORKERS`` workers, both pool transports (leg order
    rotates across reps; best-of-reps per leg).

    Three legs ride every rep:

    * ``seq``        — the default batched array engine, no pool;
    * ``par``        — the pool with its defaults: shm cache transport +
      in-worker lockstep batching (auto-on for pure-analytic runs);
    * ``par_export`` — the pool forced onto the watermark/export delta
      transport with per-tree worker loops (``shm=False,
      worker_batch=False``) — the pre-shm configuration, measured in the
      SAME run so the submit-payload and dup-eval gates compare like
      against like deterministically.

    Byte counters, eval counts and cross-worker duplicate counts are
    exact functions of the seed; only the wall columns carry noise."""
    legs0 = [
        ("seq", dict(parallel=False)),
        ("par", dict(parallel=True, n_workers=PARITY_WORKERS)),
        ("par_export", dict(parallel=True, n_workers=PARITY_WORKERS,
                            shm=False, worker_batch=False)),
    ]
    best = {}
    for rep in range(max(reps, 1)):
        k = rep % len(legs0)
        for name, kw in legs0[k:] + legs0[:k]:
            got = run_ensemble(cell, "array", iters=iters,
                               n_standard=n_standard, n_greedy=n_greedy,
                               **kw)
            if name not in best or got[2] < best[name][2]:
                best[name] = got
    res_s, _, wall_s = best["seq"]
    res_p, it_p, wall_p = best["par"]
    res_e, _, wall_e = best["par_export"]
    b = res_p.submit_bytes_rounds
    be = res_e.submit_bytes_rounds
    steady = b[-2:] if len(b) >= 2 else b  # cache-warm rounds
    stats = res_p.stats
    dup_rounds = stats.get("dup_evals_rounds", [])
    out = {
        "parallel_workers_n": PARITY_WORKERS,
        "parallel_shm": bool(stats.get("shm")),
        "parallel_worker_batch": bool(stats.get("worker_batch")),
        "parallel_wall_s": wall_p,
        "parallel_iters_per_sec": it_p / wall_p,
        "speedup_parallel_vs_sequential": wall_s / wall_p,
        "parallel_submit_bytes": res_p.submit_bytes,
        "parallel_return_bytes": res_p.return_bytes,
        "parallel_snapshot_bytes": res_p.snapshot_bytes,
        "parallel_submit_bytes_rounds": b,
        "parallel_return_bytes_rounds": res_p.return_bytes_rounds,
        "parallel_submit_steady_bytes": _steady(b),
        # cross-worker duplicate evals: states priced by 2+ workers in the
        # same round (master-side key-overlap count, deterministic).  All
        # of them must land in round 0 (cold cache) — a steady-round dup
        # means the shm fold stopped deduplicating the frontier.
        "parallel_dup_evals": stats.get("dup_evals", 0),
        "parallel_dup_evals_steady": sum(dup_rounds[1:]),
        "parallel_dup_evals_rounds": dup_rounds,
        # per-worker serving split: hits/misses/dedup plus how many cache
        # entries arrived via the shm fold vs pickled exports
        "parallel_worker_stats": stats.get("workers", []),
        # consecutive steady-state rounds: the constant-factor claim
        "parallel_submit_round_ratio": (
            max(steady) / max(min(steady), 1) if len(steady) == 2 else 1.0
        ),
        # worst round's forward delta vs the init snapshot — the
        # pre-pinning pool shipped the snapshot (or more) EVERY round
        "parallel_max_round_vs_snapshot": (
            max(b) / max(res_p.snapshot_bytes, 1) if b else 0.0
        ),
        "parallel_restarts": res_p.n_worker_restarts,
        "parallel_same_result": _same_result(res_s, res_p),
        # the export-transport baseline, same run, same seeds
        "parallel_export_wall_s": wall_e,
        "parallel_export_submit_bytes": res_e.submit_bytes,
        "parallel_export_submit_steady_bytes": _steady(be),
        "parallel_export_restarts": res_e.n_worker_restarts,
        "parallel_export_same_result": _same_result(res_s, res_e),
    }
    return out


LEGS = [
    # leg key -> run_ensemble overrides; bench_cell round-robins the legs
    # ACROSS reps (leg order rotates within each rep) so slow temporal
    # drift in machine load — the dominant noise source on shared runners
    # — cannot systematically bias any one leg
    ("reference", dict(engine="reference", columnar=False)),
    ("array_scalar", dict(engine="array", batch=False, columnar=False)),
    ("array_batched", dict(engine="array", columnar=False)),
    ("array", dict(engine="array")),
]


def bench_cell(cell, *, iters: int, n_standard: int, n_greedy: int,
               reps: int = 3, pool_reps=None) -> dict:
    """One cell's full leg matrix.  ``pool_reps`` sizes the pinned-pool
    comparison independently of the engine-leg reps — quick/CI runs pass
    ``pool_reps=1`` so the pool path (all three parity legs and their
    deterministic gates) is exercised on every push at a fraction of the
    wall cost, instead of being skipped to fit the budget."""
    out = {"cell": "x".join(cell), "iters_per_decision": iters,
           "n_trees": n_standard + n_greedy,
           # the engine that produced the headline (array_*) columns — the
           # repo default; render_experiments.py reports this
           "engine": ENGINE_STAMP}

    best = {}
    for rep in range(max(reps, 1)):
        for i in range(len(LEGS)):
            name, kw = LEGS[(i + rep) % len(LEGS)]
            got = run_ensemble(cell, iters=iters, n_standard=n_standard,
                               n_greedy=n_greedy, **kw)
            if name not in best or got[2] < best[name][2]:
                best[name] = got

    res_ref, it_ref, wall_ref = best["reference"]
    out["reference_wall_s"] = wall_ref
    out["reference_iters_per_sec"] = it_ref / wall_ref
    out["reference_evals"] = res_ref.n_evals

    res_sca, it_sca, wall_sca = best["array_scalar"]
    out["array_scalar_wall_s"] = wall_sca
    out["array_scalar_iters_per_sec"] = it_sca / wall_sca

    res_bat, it_bat, wall_bat = best["array_batched"]
    out["array_batched_wall_s"] = wall_bat
    out["array_batched_iters_per_sec"] = it_bat / wall_bat

    res_arr, it_arr, wall_arr = best["array"]
    out["array_wall_s"] = wall_arr
    out["array_iters_per_sec"] = it_arr / wall_arr
    out["array_evals"] = res_arr.n_evals
    out["cache_hits"] = res_arr.cache_hits
    out["cache_misses"] = res_arr.cache_misses
    out["cache_hit_rate"] = res_arr.cache_hits / max(
        res_arr.cache_hits + res_arr.cache_misses, 1)
    out["evals_saved"] = res_ref.n_evals - res_arr.n_evals
    out["speedup"] = out["array_iters_per_sec"] / out["reference_iters_per_sec"]
    out["speedup_batched_vs_scalar"] = (
        out["array_batched_iters_per_sec"] / out["array_scalar_iters_per_sec"])
    out["speedup_columnar_vs_batched"] = (
        out["array_iters_per_sec"] / out["array_batched_iters_per_sec"])
    out["same_result"] = (
        res_ref.plan == res_sca.plan == res_bat.plan == res_arr.plan
        and res_ref.cost == res_sca.cost == res_bat.cost == res_arr.cost
        and [d["action"] for d in res_ref.decisions]
        == [d["action"] for d in res_sca.decisions]
        == [d["action"] for d in res_bat.decisions]
        == [d["action"] for d in res_arr.decisions])
    out.update(bench_kernel(cell))
    out.update(bench_kernel_jit(cell))
    out.update(bench_parallel(
        cell, iters=iters, n_standard=n_standard, n_greedy=n_greedy,
        reps=pool_reps if pool_reps is not None else max(reps - 1, 2)))

    name = out["cell"]
    csv_line(f"engine_throughput[{name}][reference]", wall_ref * 1e6,
             f"{out['reference_iters_per_sec']:.0f} it/s")
    csv_line(f"engine_throughput[{name}][array+scalar]", wall_sca * 1e6,
             f"{out['array_scalar_iters_per_sec']:.0f} it/s")
    csv_line(f"engine_throughput[{name}][array+batched]", wall_bat * 1e6,
             f"{out['array_batched_iters_per_sec']:.0f} it/s")
    csv_line(f"engine_throughput[{name}][array+columnar]", wall_arr * 1e6,
             f"{out['array_iters_per_sec']:.0f} it/s")
    csv_line(f"engine_throughput[{name}][array+parallel]",
             out["parallel_wall_s"] * 1e6,
             f"{out['parallel_iters_per_sec']:.0f} it/s; "
             f"{out['speedup_parallel_vs_sequential']:.2f}x vs sequential "
             f"at {out['parallel_workers_n']} workers; "
             f"shm={out['parallel_shm']}; "
             f"worker_batch={out['parallel_worker_batch']}; "
             f"submit steady {out['parallel_submit_steady_bytes']}B/round "
             f"(export transport: "
             f"{out['parallel_export_submit_steady_bytes']}B), total "
             f"{out['parallel_submit_bytes']}B vs "
             f"{out['parallel_export_submit_bytes']}B; snapshot "
             f"{out['parallel_snapshot_bytes']}B shipped once "
             f"(was: every round); dup evals "
             f"{out['parallel_dup_evals']} (steady rounds: "
             f"{out['parallel_dup_evals_steady']}); "
             f"restarts={out['parallel_restarts']}; "
             f"same={out['parallel_same_result']}")
    csv_line(f"engine_throughput_kernel[{name}]",
             out["kernel_columnar_us_per_plan"],
             f"{out['kernel_speedup']:.2f}x columnar-vs-scalar on "
             f"{out['kernel_batch']}-plan miss batches "
             f"({out['kernel_scalar_us_per_plan']:.1f} -> "
             f"{out['kernel_columnar_us_per_plan']:.1f} us/plan)")
    csv_line(f"engine_throughput_kernel_jit[{name}]",
             out["kernel_jit_us_per_plan_b256"],
             "; ".join(
                 f"b={b}: scalar {out[f'kernel_scalar_us_per_plan_b{b}']:.1f}"
                 f" / columnar {out[f'kernel_columnar_us_per_plan_b{b}']:.1f}"
                 f" / jit {out[f'kernel_jit_us_per_plan_b{b}']:.1f} us/plan"
                 f" (jit {out[f'kernel_jit_vs_columnar_b{b}']:.2f}x vs"
                 f" columnar)"
                 for b in KERNEL_JIT_BATCHES))
    csv_line(f"engine_throughput_speedup[{name}]", 0.0,
             f"{out['speedup']:.1f}x vs reference; "
             f"{out['speedup_batched_vs_scalar']:.2f}x batched-vs-scalar; "
             f"{out['speedup_columnar_vs_batched']:.2f}x columnar-vs-batched; "
             f"cache_hits={out['cache_hits']}; "
             f"hit_rate={out['cache_hit_rate']:.3f}; "
             f"evals_saved={out['evals_saved']}; same={out['same_result']}")
    return out


def check_parallel(row, *, cpus=None) -> tuple:
    """The pinned-pool parity gates on one benchmarked row.  Returns
    ``(hard, soft)``: hard gates are the deterministic counters —
    identical results for BOTH transports, zero restarts, round-sized
    submit payloads, zero steady-round cross-worker duplicate evals under
    shm, and the shm submit payload at-or-below the export baseline's
    steady rounds and strictly below its total.  The soft (wall-clock,
    retry-once) gate depends on ``cpus`` — the CPUs this process can
    schedule on: with ``PARITY_WORKERS`` or more, the pool must match or
    beat the batched-sequential leg; with fewer it cannot win by
    construction, so only the catastrophic floor applies."""
    hard, soft = [], []
    cell = row["cell"]
    if not row["parallel_same_result"]:
        hard.append(f"{cell}: parallel diverged from sequential")
    if not row["parallel_export_same_result"]:
        hard.append(
            f"{cell}: export-transport pool diverged from sequential")
    if row["parallel_restarts"] or row["parallel_export_restarts"]:
        hard.append(
            f"{cell}: {row['parallel_restarts']}+"
            f"{row['parallel_export_restarts']} unexpected worker restarts")
    if row["parallel_submit_round_ratio"] > PARALLEL_ROUND_RATIO:
        hard.append(
            f"{cell}: steady-state submit rounds diverged "
            f"({row['parallel_submit_round_ratio']:.2f}x > "
            f"{PARALLEL_ROUND_RATIO}) — submit payload no longer "
            f"round-sized")
    if row["parallel_max_round_vs_snapshot"] >= 1.0:
        hard.append(
            f"{cell}: a forward delta reached snapshot size "
            f"({row['parallel_max_round_vs_snapshot']:.2f}x) — the "
            f"submit side is re-shipping whole state")
    if HAVE_SHM and not row["parallel_shm"]:
        hard.append(
            f"{cell}: shm cache transport did not engage on a "
            f"pure-analytic run despite POSIX shared memory")
    if row["parallel_shm"]:
        if row["parallel_dup_evals_steady"]:
            hard.append(
                f"{cell}: {row['parallel_dup_evals_steady']} cross-worker "
                f"duplicate evals in steady rounds "
                f"({row['parallel_dup_evals_rounds']}) — the shm fold "
                f"stopped deduplicating sibling frontiers")
        if (row["parallel_submit_steady_bytes"]
                > row["parallel_export_submit_steady_bytes"]):
            hard.append(
                f"{cell}: shm steady submit "
                f"({row['parallel_submit_steady_bytes']}B/round) above the "
                f"export baseline "
                f"({row['parallel_export_submit_steady_bytes']}B/round)")
        if row["parallel_submit_bytes"] >= row["parallel_export_submit_bytes"]:
            hard.append(
                f"{cell}: shm total submit ({row['parallel_submit_bytes']}B)"
                f" not below the export baseline "
                f"({row['parallel_export_submit_bytes']}B)")
    # --- wall-clock (retry-once) ---
    cpus = _n_cpus() if cpus is None else cpus
    speedup = row["speedup_parallel_vs_sequential"]
    if cpus >= PARITY_WORKERS:
        if speedup < 1.0:
            soft.append(
                f"{cell}: pool slower than batched sequential at "
                f"{row['parallel_workers_n']} workers on a {cpus}-CPU box "
                f"({speedup:.2f}x)")
    elif (speedup < 1.0 / PARALLEL_WALL_RATIO
            and row["parallel_wall_s"] > PARALLEL_WALL_FLOOR_S):
        soft.append(
            f"{cell}: parallel leg catastrophically slow "
            f"({speedup:.2f}x of sequential over "
            f"{row['parallel_wall_s']:.2f}s)")
    return hard, soft


def check_rows(rows) -> tuple:
    """Evaluate the CI gates on benchmarked rows.  Returns
    ``(hard, soft)`` failure-message lists: ``hard`` gates are
    DETERMINISTIC (identical plans/costs/decisions across legs, payload
    byte counters, eval/dup counters, restart counts — exactly
    reproducible for fixed seeds, never retried), ``soft`` gates are
    wall-clock ratios (retried once by the ``--check`` driver before
    failing; see the module docstring)."""
    hard, soft = [], []
    for row in rows:
        if not row["same_result"]:
            hard.append(f"{row['cell']}: engines diverged")
    r0 = rows[0]
    # --- pinned-pool parity gates (headline cell) ---
    ph, ps = check_parallel(r0)
    hard += ph
    soft += ps
    # --- wall-clock ratio gates (retry-once) ---
    if r0["speedup"] < 1.0:
        soft.append(
            f"{r0['cell']}: array engine slower than reference "
            f"({r0['speedup']:.2f}x)")
    if r0["kernel_speedup"] < 1.0:
        soft.append(
            f"{r0['cell']}: columnar kernel slower than the "
            f"scalar replay on {r0['kernel_batch']}-plan batches "
            f"({r0['kernel_speedup']:.2f}x)")
    b = max(KERNEL_JIT_BATCHES)
    if r0[f"kernel_jit_vs_columnar_b{b}"] < 1.0:
        soft.append(
            f"{r0['cell']}: jitted kernel slower than columnar at "
            f"batch {b} ({r0[f'kernel_jit_vs_columnar_b{b}']:.2f}x)")
    if r0["speedup_columnar_vs_batched"] < COLUMNAR_LEG_FLOOR:
        soft.append(
            f"{r0['cell']}: columnar leg regressed end-to-end "
            f"({r0['speedup_columnar_vs_batched']:.2f}x < "
            f"{COLUMNAR_LEG_FLOOR})")
    return hard, soft


def main(iters: int = 384, n_standard: int = 15, n_greedy: int = 1,
         publish: bool = True, reps: int = 3, pool_reps=None) -> list:
    rows = [bench_cell(c, iters=iters, n_standard=n_standard,
                       n_greedy=n_greedy, reps=reps, pool_reps=pool_reps)
            for c in CELLS]
    if publish:  # scaled-down (--quick / CI-gate) runs must not overwrite
        emit(rows, "engine_throughput")  # the published Table-1 artifact
    return rows


def parity_main(iters: int = 96, n_standard: int = 7, n_greedy: int = 1,
                reps: int = 2) -> dict:
    """The ``--parity`` row: ONLY the 2-worker pool comparison on the
    warm-cache decode headline cell (the CI few-core step runs this under
    ``taskset -c 0,1``)."""
    cell = CELLS[0]
    row = {"cell": "x".join(cell)}
    row.update(bench_parallel(cell, iters=iters, n_standard=n_standard,
                              n_greedy=n_greedy, reps=reps))
    print(f"# parity {row['cell']}: "
          f"{row['speedup_parallel_vs_sequential']:.2f}x pool-vs-sequential "
          f"at {row['parallel_workers_n']} workers ({_n_cpus()} CPUs); "
          f"shm={row['parallel_shm']}; "
          f"worker_batch={row['parallel_worker_batch']}; "
          f"submit steady {row['parallel_submit_steady_bytes']}B/round vs "
          f"export {row['parallel_export_submit_steady_bytes']}B, total "
          f"{row['parallel_submit_bytes']}B vs "
          f"{row['parallel_export_submit_bytes']}B; dup evals per round "
          f"{row['parallel_dup_evals_rounds']}; "
          f"same={row['parallel_same_result']}/"
          f"{row['parallel_export_same_result']}")
    return row


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="scaled-down budgets (96 iters, 7+1 trees, "
                         "single-rep pool legs)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless, on the decode cell: the array "
                         "engine beats reference, the columnar kernel "
                         "holds the hot path (leg parity + microbench "
                         "win), all legs agree, and the pinned pool holds "
                         "its deterministic counter gates (CI gate)")
    ap.add_argument("--parity", action="store_true",
                    help="run ONLY the 2-worker pool parity comparison on "
                         "the decode cell and gate it: deterministic "
                         "counters hard, pool>=sequential soft (engages "
                         "when 2+ CPUs are schedulable; run under "
                         "'taskset -c 0,1' for the few-core CI gate)")
    args = ap.parse_args()
    if args.parity:
        row = parity_main()
        hard, soft = check_parallel(row)
        if not hard and soft:
            print("# wall-clock gate miss, retrying once: " + "; ".join(soft))
            row = parity_main()
            hard, soft = check_parallel(row)
        bad = hard + soft
        if bad:
            print("# PARITY CHECK FAILED: " + "; ".join(bad))
            sys.exit(1)
        print("# parity check passed: both pool transports bit-identical "
              "to sequential, zero steady-round duplicate evals, shm "
              "submit payload below the export baseline"
              + (", pool >= batched sequential at "
                 f"{PARITY_WORKERS} workers"
                 if _n_cpus() >= PARITY_WORKERS else
                 f" (wall gate idle: {_n_cpus()} CPU(s) schedulable)"))
        sys.exit(0)
    kw = (dict(iters=96, n_standard=7, publish=False, reps=2, pool_reps=1)
          if args.quick else {})
    rows = main(**kw)
    r = rows[0]
    print(f"# headline {r['cell']}: {r['speedup']:.2f}x vs reference, "
          f"{r['speedup_columnar_vs_batched']:.2f}x columnar-vs-batched "
          f"({r['array_batched_iters_per_sec']:.0f} -> "
          f"{r['array_iters_per_sec']:.0f} it/s), kernel "
          f"{r['kernel_speedup']:.2f}x on {r['kernel_batch']}-plan batches, "
          f"cache hits {r['cache_hits']}, evals saved {r['evals_saved']}, "
          f"identical result: {r['same_result']}")
    if args.check:
        hard, soft = check_rows(rows)
        if not hard and soft:
            # Retry-once-on-miss: wall-clock ratio gates are subject to CI
            # throttling bursts, so one miss buys exactly one full re-run;
            # only a second miss fails.  Deterministic gates (hard) never
            # retry — a miss there is a real regression.
            print("# wall-clock gate miss, retrying once: "
                  + "; ".join(soft))
            rows = main(**kw)
            hard, soft = check_rows(rows)
        bad = hard + soft
        if bad:
            print("# CHECK FAILED: " + "; ".join(bad))
            sys.exit(1)
        print("# check passed: array >= reference, columnar kernel >= "
              "scalar replay, jit kernel >= columnar at batch "
              f"{max(KERNEL_JIT_BATCHES)}, columnar leg holds the batched "
              "leg, all legs identical on the decode cell, and the pinned "
              "pool held its parity gates (bit-identical on both "
              "transports, zero steady-round dup evals, shm submit below "
              "the export baseline, round-sized payloads)")
