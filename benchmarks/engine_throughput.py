"""Engine throughput: reference Node-tree MCTS vs the vectorized array
engine — one-at-a-time vs batched leaf evaluation vs the columnar kernel.

Runs the Table-1 ensemble protocol (384 iterations/decision, 15 standard
+ 1 greedy tree) on two representative cells with four engine legs — the
searches are behaviorally identical for the same seeds (certified by
``tests/test_differential.py``), so this is a pure implementation
comparison:

* ``reference``      — paper-faithful Node trees, scalar pricing, no cache;
* ``array_scalar``   — the PR-1 array engine: flat arrays + shared
  transposition cache, but one-at-a-time leaf evaluation;
* ``array_batched``  — the PR-2 engine: lockstep pending-leaf rounds with
  batched terminal-cost evaluation, miss batches priced by the scalar
  per-plan replay (``AnalyticCostModel(columnar=False)``);
* ``array``          — the default engine: the same lockstep rounds with
  miss batches priced by the COLUMNAR roofline kernel
  (``PlanColumns`` + ``_terms_columnar``, one vectorized pass per batch).

A ``parallel`` leg rides along per cell: the default array engine run
through the persistent pinned process pool (``engine/workers.py``) at the
same budgets, reporting wall clock against the sequential leg plus the
DETERMINISTIC payload-byte counters — submit/return bytes per round, the
one-time init snapshot, and the steady-state forward-delta size — that
pin the O(round) transport claim (the pre-pinning pool re-pickled every
tree and the whole cache on every submit).

A cost-kernel microbenchmark rides along per cell (``kernel_*`` columns):
one deduplicated batch of random unique plans priced scalar-batched vs
columnar, isolating the kernel win from engine bookkeeping — at Table-1
miss-batch sizes the column math clears the scalar replay by whatever the
end-to-end legs can't show once cache hit rates pass 99%.  A second
microbench (``kernel_jit_*`` columns) compares all THREE pricing paths —
scalar replay, columnar kernel, jax-jitted kernel — on the
``cost_columns`` seam (pre-encoded ``PlanColumns``, so shared dedup/encode
overhead is out of the picture) at batch sizes 1/16/256: batch 1 shows the
jax dispatch floor that keeps ``JIT_MIN_BATCH`` above 1, batch 256 is the
generation-sized burst where the jitted kernel must beat the columnar one.

Gate policy (``--check``): gates split into DETERMINISTIC ones (identical
results across legs, byte counters, restart counts — exactly reproducible
for fixed seeds, so any miss is a real regression and fails immediately)
and WALL-CLOCK ratio ones (speedups, kernel crossovers — subject to CI
cgroup throttling bursts that can halve a leg).  A wall-clock miss
triggers ONE full re-run of the benchmark: the check fails only if a
wall-clock gate misses on both runs (or a deterministic gate misses at
all).  This keeps the flake rate quadratically small without ever
loosening the deterministic guarantees.

Reported per cell: iterations/sec per leg, cache hits/misses, and three
speedups — ``speedup`` (columnar array vs reference, the end-to-end win),
``speedup_batched_vs_scalar`` (batching leaf evaluation over PR-1), and
``speedup_columnar_vs_batched`` (the columnar kernel over the scalar
replay, end-to-end).  ``--check`` enforces three things on the decode
headline cell: the array engine beats the reference, all legs produce
identical results, and the columnar kernel does not regress the hot path
— the isolated kernel microbench must beat the scalar replay outright,
and the end-to-end columnar leg must clear a catastrophic-regression
floor (per-leg end-to-end ratios swing wildly under CI cgroup
throttling; the microbench, measured back-to-back, is where a silent
kernel regression cannot hide).

    PYTHONPATH=src python -m benchmarks.engine_throughput
    PYTHONPATH=src python -m benchmarks.engine_throughput --quick --check
"""
from __future__ import annotations

import argparse
import random
import sys
import time

from benchmarks.common import ENGINE_STAMP, csv_line, emit
from repro.core.autotuner import make_mdp
from repro.core.cost_model import AnalyticCostModel
from repro.core.ensemble import ProTuner
from repro.core.mcts import MCTSConfig

# headline first: the decode cell's compact space is where the shared
# cache pays off hardest (96%+ hit rate at Table-1 budgets) and where
# selection/backprop — what the batched driver restructures — dominate
CELLS = [
    ("granite-3-2b", "decode_32k"),
    ("granite-moe-1b-a400m", "train_4k"),
]

# the end-to-end columnar-vs-batched gate tolerance: at 99%+ cache hit
# rates pricing is a sliver of wall time, so the leg ratio is parity plus
# scheduler noise — and on cgroup-throttled CI runners a throttling burst
# can halve a whole leg (observed: identical code measured anywhere from
# 0.62x to 1.11x).  The tight regression catch is therefore the kernel
# microbench (4-9x margin, adjacent measurements, robust under
# throttling); the leg floor only catches a CATASTROPHIC end-to-end
# regression (e.g. the kernel engaging where it loses badly).
COLUMNAR_LEG_FLOOR = 0.5
KERNEL_BATCH = 256  # microbench batch: a Table-1 first-round miss burst
# kernel_jit microbench grid: the jax dispatch floor (1), the columnar
# dispatch threshold (16), and a generation/miss-burst width (256) — the
# batch the jit-vs-columnar gate runs at
KERNEL_JIT_BATCHES = (1, 16, 256)

# parallel-leg gates.  The BYTE gates are deterministic (pickled sizes for
# fixed seeds) and carry the O(round) claim: consecutive steady-state
# rounds within a constant factor, and no round's forward delta anywhere
# near the init snapshot (what the stateless pool used to re-ship every
# round).  The WALL gate is best-of-reps with a generous ratio plus an
# absolute floor — this box's timings swing ±10-20%, and on few-core CI
# runners the pool can legitimately sit near parity with sequential — so
# it only catches a catastrophic regression (e.g. the submit side
# re-growing with the tree).
PARALLEL_ROUND_RATIO = 4.0      # consecutive steady-state submit rounds
PARALLEL_WALL_RATIO = 4.0       # parallel may not be > 4x slower ...
PARALLEL_WALL_FLOOR_S = 5.0     # ... unless both legs are under 5s anyway


def run_ensemble(cell, engine: str, *, iters: int, n_standard: int,
                 n_greedy: int, seed: int = 0, cache=None,
                 parallel: bool = False, batch=None, columnar: bool = True):
    """One full tuning run; returns (TuneResult, iterations, wall_s).
    ``columnar=False`` flips the cell's cost model to the pre-columnar
    scalar replay (values bit-identical; only the pricing path changes).
    Repetition/noise handling lives in ``bench_cell`` (rotating best-of-
    reps), not here."""
    arch, shape = cell
    mdp = make_mdp(arch, shape)
    mdp.cost_model.columnar = columnar
    cfg = MCTSConfig(iters_per_decision=iters, seed=seed)
    tuner = ProTuner(mdp, n_standard=n_standard, n_greedy=n_greedy,
                     mcts_config=cfg, seed=seed, engine=engine,
                     cache=cache, parallel=parallel, batch=batch)
    t0 = time.perf_counter()
    res = tuner.run()
    wall = time.perf_counter() - t0
    total_iters = iters * (n_standard + n_greedy) * len(res.decisions)
    return res, total_iters, wall


def bench_kernel(cell, *, n_plans: int = KERNEL_BATCH, reps: int = 5) -> dict:
    """The isolated pricing comparison: one deduplicated batch of random
    unique plans, scalar-batched replay vs the columnar kernel.  Values
    are asserted identical; the ratio is the kernel's clean win."""
    arch, shape = cell
    mdp = make_mdp(arch, shape)
    space = mdp.space
    rng = random.Random(0)
    seen, plans = set(), []
    while len(plans) < n_plans:
        p = space.random_plan(rng)
        if p not in seen:
            seen.add(p)
            plans.append(p)
    cfg, shp, mesh = space.cfg, space.shape, space.mesh
    scalar = AnalyticCostModel(cfg, shp, mesh, columnar=False)
    columnar = AnalyticCostModel(cfg, shp, mesh)  # default: kernel + dispatch
    assert scalar.cost_batch(plans) == columnar.cost_batch(plans)  # warm + certify
    t_s = min(
        _timed(lambda: scalar.cost_batch(plans)) for _ in range(reps)
    )
    t_c = min(
        _timed(lambda: columnar.cost_batch(plans)) for _ in range(reps)
    )
    return {
        "kernel_batch": len(plans),
        "kernel_scalar_us_per_plan": t_s / len(plans) * 1e6,
        "kernel_columnar_us_per_plan": t_c / len(plans) * 1e6,
        "kernel_speedup": t_s / t_c,
    }


def bench_kernel_jit(cell, *, reps: int = 5) -> dict:
    """Three-way pricing-path comparison on the ``cost_columns`` seam:
    scalar replay vs columnar kernel vs jax-jitted kernel over the SAME
    pre-encoded ``PlanColumns`` batches at sizes 1/16/256 (adjacent
    best-of-reps measurements, dedup/encode excluded — the cleanest view
    of each kernel's own cost).  The jitted model is warmed first so XLA
    compiles never land in a timed rep.  Values are certified along the
    way: scalar == columnar exactly, jit within JIT_RTOL."""
    from repro.core.cost_model import JIT_RTOL, PlanColumns

    arch, shape = cell
    mdp = make_mdp(arch, shape)
    space = mdp.space
    rng = random.Random(0)
    seen, plans = set(), []
    while len(plans) < max(KERNEL_JIT_BATCHES):
        p = space.random_plan(rng)
        if p not in seen:
            seen.add(p)
            plans.append(p)
    cfg, shp, mesh = space.cfg, space.shape, space.mesh
    # min_batch=1 on the kernel models so batch 1 really measures the
    # kernels (the production dispatch would route it to scalar replay)
    models = {
        "scalar": AnalyticCostModel(cfg, shp, mesh, columnar=False),
        "columnar": AnalyticCostModel(cfg, shp, mesh, columnar_min_batch=1),
        "jit": AnalyticCostModel(cfg, shp, mesh, pricing="jit",
                                 columnar_min_batch=1),
    }
    out = {"kernel_jit_batches": list(KERNEL_JIT_BATCHES)}
    for b in KERNEL_JIT_BATCHES:
        cols = PlanColumns.from_plans(plans[:b])
        vals = {}
        for name, m in models.items():
            vals[name] = m.cost_columns(cols)  # warm: ctx + jit compile
            t = min(_timed(lambda: m.cost_columns(cols)) for _ in range(reps))
            out[f"kernel_{name}_us_per_plan_b{b}"] = t / b * 1e6
        assert vals["scalar"] == vals["columnar"]
        import numpy as _np
        _np.testing.assert_allclose(
            _np.asarray(vals["jit"]), _np.asarray(vals["columnar"]),
            rtol=JIT_RTOL, atol=0.0)
        out[f"kernel_jit_vs_columnar_b{b}"] = (
            out[f"kernel_columnar_us_per_plan_b{b}"]
            / out[f"kernel_jit_us_per_plan_b{b}"])
        out[f"kernel_jit_vs_scalar_b{b}"] = (
            out[f"kernel_scalar_us_per_plan_b{b}"]
            / out[f"kernel_jit_us_per_plan_b{b}"])
    return out


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def bench_parallel(cell, *, iters: int, n_standard: int, n_greedy: int,
                   reps: int = 2) -> dict:
    """Sequential vs pinned-pool legs at the same budgets (leg order
    rotates across reps; best-of-reps per leg), plus the payload-byte
    counters — deterministic for a fixed seed — that measure the O(round)
    submit claim."""
    best = {}
    for rep in range(max(reps, 1)):
        legs = [("seq", False), ("par", True)]
        if rep % 2:
            legs.reverse()
        for name, flag in legs:
            got = run_ensemble(cell, "array", iters=iters,
                               n_standard=n_standard, n_greedy=n_greedy,
                               parallel=flag)
            if name not in best or got[2] < best[name][2]:
                best[name] = got
    res_s, _, wall_s = best["seq"]
    res_p, it_p, wall_p = best["par"]
    b = res_p.submit_bytes_rounds
    steady = b[-2:] if len(b) >= 2 else b  # cache-warm rounds
    out = {
        "parallel_wall_s": wall_p,
        "parallel_iters_per_sec": it_p / wall_p,
        "speedup_parallel_vs_sequential": wall_s / wall_p,
        "parallel_submit_bytes": res_p.submit_bytes,
        "parallel_return_bytes": res_p.return_bytes,
        "parallel_snapshot_bytes": res_p.snapshot_bytes,
        "parallel_submit_bytes_rounds": b,
        "parallel_return_bytes_rounds": res_p.return_bytes_rounds,
        # consecutive steady-state rounds: the constant-factor claim
        "parallel_submit_round_ratio": (
            max(steady) / max(min(steady), 1) if len(steady) == 2 else 1.0
        ),
        # worst round's forward delta vs the init snapshot — the
        # pre-pinning pool shipped the snapshot (or more) EVERY round
        "parallel_max_round_vs_snapshot": (
            max(b) / max(res_p.snapshot_bytes, 1) if b else 0.0
        ),
        "parallel_restarts": res_p.n_worker_restarts,
        "parallel_same_result": (
            res_s.plan == res_p.plan and res_s.cost == res_p.cost
            and [d["action"] for d in res_s.decisions]
            == [d["action"] for d in res_p.decisions]),
    }
    return out


LEGS = [
    # leg key -> run_ensemble overrides; bench_cell round-robins the legs
    # ACROSS reps (leg order rotates within each rep) so slow temporal
    # drift in machine load — the dominant noise source on shared runners
    # — cannot systematically bias any one leg
    ("reference", dict(engine="reference", columnar=False)),
    ("array_scalar", dict(engine="array", batch=False, columnar=False)),
    ("array_batched", dict(engine="array", columnar=False)),
    ("array", dict(engine="array")),
]


def bench_cell(cell, *, iters: int, n_standard: int, n_greedy: int,
               reps: int = 3) -> dict:
    out = {"cell": "x".join(cell), "iters_per_decision": iters,
           "n_trees": n_standard + n_greedy,
           # the engine that produced the headline (array_*) columns — the
           # repo default; render_experiments.py reports this
           "engine": ENGINE_STAMP}

    best = {}
    for rep in range(max(reps, 1)):
        for i in range(len(LEGS)):
            name, kw = LEGS[(i + rep) % len(LEGS)]
            got = run_ensemble(cell, iters=iters, n_standard=n_standard,
                               n_greedy=n_greedy, **kw)
            if name not in best or got[2] < best[name][2]:
                best[name] = got

    res_ref, it_ref, wall_ref = best["reference"]
    out["reference_wall_s"] = wall_ref
    out["reference_iters_per_sec"] = it_ref / wall_ref
    out["reference_evals"] = res_ref.n_evals

    res_sca, it_sca, wall_sca = best["array_scalar"]
    out["array_scalar_wall_s"] = wall_sca
    out["array_scalar_iters_per_sec"] = it_sca / wall_sca

    res_bat, it_bat, wall_bat = best["array_batched"]
    out["array_batched_wall_s"] = wall_bat
    out["array_batched_iters_per_sec"] = it_bat / wall_bat

    res_arr, it_arr, wall_arr = best["array"]
    out["array_wall_s"] = wall_arr
    out["array_iters_per_sec"] = it_arr / wall_arr
    out["array_evals"] = res_arr.n_evals
    out["cache_hits"] = res_arr.cache_hits
    out["cache_misses"] = res_arr.cache_misses
    out["cache_hit_rate"] = res_arr.cache_hits / max(
        res_arr.cache_hits + res_arr.cache_misses, 1)
    out["evals_saved"] = res_ref.n_evals - res_arr.n_evals
    out["speedup"] = out["array_iters_per_sec"] / out["reference_iters_per_sec"]
    out["speedup_batched_vs_scalar"] = (
        out["array_batched_iters_per_sec"] / out["array_scalar_iters_per_sec"])
    out["speedup_columnar_vs_batched"] = (
        out["array_iters_per_sec"] / out["array_batched_iters_per_sec"])
    out["same_result"] = (
        res_ref.plan == res_sca.plan == res_bat.plan == res_arr.plan
        and res_ref.cost == res_sca.cost == res_bat.cost == res_arr.cost
        and [d["action"] for d in res_ref.decisions]
        == [d["action"] for d in res_sca.decisions]
        == [d["action"] for d in res_bat.decisions]
        == [d["action"] for d in res_arr.decisions])
    out.update(bench_kernel(cell))
    out.update(bench_kernel_jit(cell))
    out.update(bench_parallel(cell, iters=iters, n_standard=n_standard,
                              n_greedy=n_greedy, reps=max(reps - 1, 2)))

    name = out["cell"]
    csv_line(f"engine_throughput[{name}][reference]", wall_ref * 1e6,
             f"{out['reference_iters_per_sec']:.0f} it/s")
    csv_line(f"engine_throughput[{name}][array+scalar]", wall_sca * 1e6,
             f"{out['array_scalar_iters_per_sec']:.0f} it/s")
    csv_line(f"engine_throughput[{name}][array+batched]", wall_bat * 1e6,
             f"{out['array_batched_iters_per_sec']:.0f} it/s")
    csv_line(f"engine_throughput[{name}][array+columnar]", wall_arr * 1e6,
             f"{out['array_iters_per_sec']:.0f} it/s")
    csv_line(f"engine_throughput[{name}][array+parallel]",
             out["parallel_wall_s"] * 1e6,
             f"{out['parallel_iters_per_sec']:.0f} it/s; "
             f"{out['speedup_parallel_vs_sequential']:.2f}x vs sequential; "
             f"submit/round steady "
             f"{out['parallel_submit_bytes_rounds'][-2:]}, snapshot "
             f"{out['parallel_snapshot_bytes']}B shipped once "
             f"(was: every round); restarts={out['parallel_restarts']}; "
             f"same={out['parallel_same_result']}")
    csv_line(f"engine_throughput_kernel[{name}]",
             out["kernel_columnar_us_per_plan"],
             f"{out['kernel_speedup']:.2f}x columnar-vs-scalar on "
             f"{out['kernel_batch']}-plan miss batches "
             f"({out['kernel_scalar_us_per_plan']:.1f} -> "
             f"{out['kernel_columnar_us_per_plan']:.1f} us/plan)")
    csv_line(f"engine_throughput_kernel_jit[{name}]",
             out["kernel_jit_us_per_plan_b256"],
             "; ".join(
                 f"b={b}: scalar {out[f'kernel_scalar_us_per_plan_b{b}']:.1f}"
                 f" / columnar {out[f'kernel_columnar_us_per_plan_b{b}']:.1f}"
                 f" / jit {out[f'kernel_jit_us_per_plan_b{b}']:.1f} us/plan"
                 f" (jit {out[f'kernel_jit_vs_columnar_b{b}']:.2f}x vs"
                 f" columnar)"
                 for b in KERNEL_JIT_BATCHES))
    csv_line(f"engine_throughput_speedup[{name}]", 0.0,
             f"{out['speedup']:.1f}x vs reference; "
             f"{out['speedup_batched_vs_scalar']:.2f}x batched-vs-scalar; "
             f"{out['speedup_columnar_vs_batched']:.2f}x columnar-vs-batched; "
             f"cache_hits={out['cache_hits']}; "
             f"hit_rate={out['cache_hit_rate']:.3f}; "
             f"evals_saved={out['evals_saved']}; same={out['same_result']}")
    return out


def check_rows(rows) -> tuple:
    """Evaluate the CI gates on benchmarked rows.  Returns
    ``(hard, soft)`` failure-message lists: ``hard`` gates are
    DETERMINISTIC (identical plans/costs/decisions across legs, payload
    byte counters, restart counts — exactly reproducible for fixed seeds,
    never retried), ``soft`` gates are wall-clock ratios (retried once by
    the ``--check`` driver before failing; see the module docstring)."""
    hard, soft = [], []
    for row in rows:
        if not row["same_result"]:
            hard.append(f"{row['cell']}: engines diverged")
    r0 = rows[0]
    # --- deterministic pinned-pool gates (byte counters, fixed seeds) ---
    if not r0["parallel_same_result"]:
        hard.append(f"{r0['cell']}: parallel diverged from sequential")
    if r0["parallel_restarts"]:
        hard.append(
            f"{r0['cell']}: {r0['parallel_restarts']} unexpected "
            f"worker restarts")
    if r0["parallel_submit_round_ratio"] > PARALLEL_ROUND_RATIO:
        hard.append(
            f"{r0['cell']}: steady-state submit rounds diverged "
            f"({r0['parallel_submit_round_ratio']:.2f}x > "
            f"{PARALLEL_ROUND_RATIO}) — submit payload no longer "
            f"round-sized")
    if r0["parallel_max_round_vs_snapshot"] >= 1.0:
        hard.append(
            f"{r0['cell']}: a forward delta reached snapshot size "
            f"({r0['parallel_max_round_vs_snapshot']:.2f}x) — the "
            f"submit side is re-shipping whole state")
    # --- wall-clock ratio gates (retry-once) ---
    if r0["speedup"] < 1.0:
        soft.append(
            f"{r0['cell']}: array engine slower than reference "
            f"({r0['speedup']:.2f}x)")
    if r0["kernel_speedup"] < 1.0:
        soft.append(
            f"{r0['cell']}: columnar kernel slower than the "
            f"scalar replay on {r0['kernel_batch']}-plan batches "
            f"({r0['kernel_speedup']:.2f}x)")
    b = max(KERNEL_JIT_BATCHES)
    if r0[f"kernel_jit_vs_columnar_b{b}"] < 1.0:
        soft.append(
            f"{r0['cell']}: jitted kernel slower than columnar at "
            f"batch {b} ({r0[f'kernel_jit_vs_columnar_b{b}']:.2f}x)")
    if r0["speedup_columnar_vs_batched"] < COLUMNAR_LEG_FLOOR:
        soft.append(
            f"{r0['cell']}: columnar leg regressed end-to-end "
            f"({r0['speedup_columnar_vs_batched']:.2f}x < "
            f"{COLUMNAR_LEG_FLOOR})")
    if (r0["speedup_parallel_vs_sequential"] < 1.0 / PARALLEL_WALL_RATIO
            and r0["parallel_wall_s"] > PARALLEL_WALL_FLOOR_S):
        soft.append(
            f"{r0['cell']}: parallel leg catastrophically slow "
            f"({r0['speedup_parallel_vs_sequential']:.2f}x of "
            f"sequential over {r0['parallel_wall_s']:.2f}s)")
    return hard, soft


def main(iters: int = 384, n_standard: int = 15, n_greedy: int = 1,
         publish: bool = True, reps: int = 3) -> list:
    rows = [bench_cell(c, iters=iters, n_standard=n_standard,
                       n_greedy=n_greedy, reps=reps) for c in CELLS]
    if publish:  # scaled-down (--quick / CI-gate) runs must not overwrite
        emit(rows, "engine_throughput")  # the published Table-1 artifact
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="scaled-down budgets (96 iters, 7+1 trees)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless, on the decode cell: the array "
                         "engine beats reference, the columnar kernel "
                         "holds the hot path (leg parity + microbench "
                         "win), and all legs agree (CI gate)")
    args = ap.parse_args()
    kw = dict(iters=96, n_standard=7, publish=False, reps=2) if args.quick else {}
    rows = main(**kw)
    r = rows[0]
    print(f"# headline {r['cell']}: {r['speedup']:.2f}x vs reference, "
          f"{r['speedup_columnar_vs_batched']:.2f}x columnar-vs-batched "
          f"({r['array_batched_iters_per_sec']:.0f} -> "
          f"{r['array_iters_per_sec']:.0f} it/s), kernel "
          f"{r['kernel_speedup']:.2f}x on {r['kernel_batch']}-plan batches, "
          f"cache hits {r['cache_hits']}, evals saved {r['evals_saved']}, "
          f"identical result: {r['same_result']}")
    if args.check:
        hard, soft = check_rows(rows)
        if not hard and soft:
            # Retry-once-on-miss: wall-clock ratio gates are subject to CI
            # throttling bursts, so one miss buys exactly one full re-run;
            # only a second miss fails.  Deterministic gates (hard) never
            # retry — a miss there is a real regression.
            print("# wall-clock gate miss, retrying once: "
                  + "; ".join(soft))
            rows = main(**kw)
            hard, soft = check_rows(rows)
        bad = hard + soft
        if bad:
            print("# CHECK FAILED: " + "; ".join(bad))
            sys.exit(1)
        print("# check passed: array >= reference, columnar kernel >= "
              "scalar replay, jit kernel >= columnar at batch "
              f"{max(KERNEL_JIT_BATCHES)}, columnar leg holds the batched "
              "leg, all legs identical on the decode cell, and the pinned "
              "pool matched sequential with round-sized submit payloads")
