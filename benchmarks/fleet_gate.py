"""Deterministic CI gate for the measurement fleet (perf-smoke job).

Drives the fig-9 budget sweep spec through the sweep harness with a
4-worker fleet and the analytic stub target, injecting one worker
SIGKILL and one watchdog timeout into the first two measurement
requests, then asserts the ISSUE-6 acceptance criteria:

* zero lost requests — every artifact row has a measured record;
* every request's retries stay within the configured budget;
* exactly two worker restarts (the SIGKILL + the watchdog's kill) and
  exactly one watchdog timeout were observed;
* per-request retry/timeout/death counters are surfaced on the stored
  artifact rows;
* every fleet-written cache file is byte-for-byte identical to the one
  the serial ``measure_cell`` path writes for the same plan;
* no poisoned (unparseable or schema-less) cache entries on disk.

Everything runs against tmp dirs with the XLA-free stub, so the gate is
seconds, not compiles.  Exit 0 = pass, 1 = fail (CI-gateable).
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.sweep import load_spec, run_sweep  # noqa: E402

SPEC = os.path.join(os.path.dirname(__file__), "sweeps", "fig9_budget.json")
N_WORKERS = 4
MAX_RETRIES = 2
TIMEOUT_S = 2.0
GRACE_S = 1.0


def main() -> int:
    from repro.core.measure import measure_cell
    from repro.core.measure_stub import stub_measure
    from repro.core.space import SchedulePlan

    tmp = tempfile.mkdtemp(prefix="fleet_gate_")
    results_dir = os.path.join(tmp, "results")
    fleet_cache = os.path.join(tmp, "fleet_cache")
    serial_cache = os.path.join(tmp, "serial_cache")
    bad = []

    spec = load_spec(SPEC)
    # the real per-cell budget is 20 s; the gate shrinks it so the whole
    # 32-row sweep stays CI-sized (the fleet path under test is identical)
    spec["defaults"]["budget_s"] = 0.2

    def inject(i: int, req: dict) -> None:
        if i == 0:
            req["extras"] = {"inject": {
                "marker": os.path.join(tmp, "kill.marker"), "kind": "kill"}}
        elif i == 1:
            req["extras"] = {"inject": {
                "marker": os.path.join(tmp, "sleep.marker"), "kind": "sleep",
                "sleep_s": 30}}

    try:
        rows = run_sweep(
            spec,
            results_dir=results_dir,
            measure="stub",
            workers=N_WORKERS,
            fleet_kwargs={
                "cache_dir": fleet_cache,
                "target": stub_measure,
                "timeout": TIMEOUT_S,
                "grace_s": GRACE_S,
                "max_retries": MAX_RETRIES,
                "backoff_s": 0.05,
            },
            inject=inject,
        )

        # zero lost requests; counters surfaced on every stored row
        for row in rows:
            prov = row["measure"]
            if row["measured_step_s"] is None or prov is None or prov["failed"]:
                bad.append(f"lost request on row {row['key']}: {prov}")
            elif prov["retries"] > MAX_RETRIES:
                bad.append(
                    f"row {row['key']}: {prov['retries']} retries "
                    f"> budget {MAX_RETRIES}")
        for field in ("retries", "timeouts", "worker_deaths", "from_cache"):
            if any(field not in (r["measure"] or {}) for r in rows):
                bad.append(f"provenance field {field!r} missing from rows")

        # the two injections were exercised, recovered, and counted
        stats = rows[0]["fleet"]
        if stats["n_worker_restarts"] != 2:
            bad.append(f"expected 2 worker restarts (SIGKILL + watchdog "
                       f"kill), saw {stats['n_worker_restarts']}")
        if stats["n_timeouts"] != 1:
            bad.append(f"expected 1 watchdog timeout, saw "
                       f"{stats['n_timeouts']}")
        if rows[0]["measure"]["worker_deaths"] != 1:
            bad.append(f"row 0 (SIGKILL-injected) worker_deaths = "
                       f"{rows[0]['measure']['worker_deaths']}, expected 1")
        if rows[1]["measure"]["timeouts"] != 1:
            bad.append(f"row 1 (sleep-injected) timeouts = "
                       f"{rows[1]['measure']['timeouts']}, expected 1")

        # byte-identity vs the serial measure_cell path, and no poisoned
        # entries anywhere in the fleet's cache dir
        for row in rows:
            s = row["settings"]
            measure_cell(
                s["arch"], s["shape"], s["mesh"],
                plan=SchedulePlan.from_dict(row["plan"]),
                cache_dir=serial_cache, target=stub_measure,
            )
        fleet_files = sorted(os.listdir(fleet_cache))
        serial_files = sorted(os.listdir(serial_cache))
        if fleet_files != serial_files:
            bad.append(f"cache key sets differ: fleet {len(fleet_files)} "
                       f"vs serial {len(serial_files)}")
        for name in fleet_files:
            with open(os.path.join(fleet_cache, name), "rb") as f:
                fb = f.read()
            try:
                rec = json.loads(fb)
                if not isinstance(rec, dict) or "step_s" not in rec:
                    bad.append(f"poisoned cache entry {name}: bad schema")
            except ValueError:
                bad.append(f"poisoned cache entry {name}: unparseable")
                continue
            serial_path = os.path.join(serial_cache, name)
            if os.path.exists(serial_path):
                with open(serial_path, "rb") as f:
                    if f.read() != fb:
                        bad.append(f"cache entry {name} differs from the "
                                   f"serial measure_cell record")

        if bad:
            print(f"[fleet-gate] FAIL ({len(bad)} problem(s)):")
            for b in bad:
                print(f"  - {b}")
            return 1
        print(f"[fleet-gate] OK: {len(rows)} rows, {len(fleet_files)} cache "
              f"records byte-identical to serial, fleet stats {stats}")
        return 0
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    sys.exit(main())
