"""Benchmark driver: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run              # full suite
    PYTHONPATH=src python -m benchmarks.run --quick      # 4-cell smoke
    PYTHONPATH=src python -m benchmarks.run --measure    # + compile-in-loop

Emits ``name,us_per_call,derived`` CSV lines; detailed JSON artifacts land
in experiments/bench/.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--measure", action="store_true",
                    help="include compile-in-the-loop cost+real runs")
    ap.add_argument("--only", default=None,
                    help="comma list: roofline,fig7,fig8,fig9,fig45,table1,"
                         "search,fig12,noise,engine,serving")
    args = ap.parse_args(argv)

    from benchmarks import (engine_throughput, fig7_cost, fig8_exec,
                            fig9_budget, fig12_partial_cost, fig45_ensemble,
                            learned_serving, noise_robustness, roofline,
                            search_time, table1_configs)
    from benchmarks.common import SUITE

    cells = SUITE[:4] if args.quick else None
    seeds = (0,) if args.quick else (0, 1)
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    t0 = time.time()
    print("name,us_per_call,derived")
    if want("roofline"):
        print("# --- §Roofline (from the compiled dry-run) ---")
        roofline.main("single")
        roofline.main("multi")
    if want("fig7"):
        print("# --- Fig 7: minimum cost found (normalized) ---")
        fig7_cost.main(cells=cells, seeds=seeds)
    if want("fig8"):
        print("# --- Fig 8: execution time of chosen schedules ---")
        fig8_exec.main(cells=cells, seeds=seeds[:2], measure=args.measure)
    if want("table1"):
        print("# --- Table 1: MCTS configuration sweep ---")
        table1_configs.main(cells=cells, seeds=seeds[:2])
    if want("fig45"):
        print("# --- Fig 4/5: ensemble composition (standard vs greedy) ---")
        fig45_ensemble.main(seeds=seeds[:2])
    if want("fig9"):
        print("# --- Fig 9: fixed wall-clock budget ---")
        fig9_budget.main(cells=cells[:4] if cells else None,
                         budget_s=6.0 if args.quick else 12.0)
    if want("search"):
        print("# --- §5.3: search time breakdown ---")
        search_time.main()
    if want("engine"):
        print("# --- engine: array MCTS + transposition cache throughput ---")
        if args.quick:
            engine_throughput.main(iters=96, n_standard=7, publish=False,
                                   reps=2)
        else:
            engine_throughput.main()
    if want("serving"):
        print("# --- engine: learned-cost serving (hybrid vs analytic) ---")
        if args.quick:
            learned_serving.main(iters=96, n_standard=7)
        else:
            learned_serving.main()
    if want("fig12"):
        print("# --- Fig 1/2 (§3): cost models on partial schedules ---")
        fig12_partial_cost.main()
    if want("noise"):
        print("# --- beyond-paper: noise robustness ablation ---")
        noise_robustness.main(seeds=seeds)
    print(f"# total bench wall time: {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
