"""§5.3 analogue: search-time breakdown.

The paper found 88%% of MCTS time in child generation (simulation) and 7.5%%
in cost evaluation; our MCTS logs both timers.  Also reports cost-model
evaluations per schedule decision for beam vs greedy vs MCTS (beam's
exhaustive child evaluation is its documented overhead) and wall time per
algorithm on a representative cell."""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import csv_line, emit, run_algo, scaled_cfg
from repro.core.autotuner import make_mdp
from repro.core.ensemble import ProTuner

CELL = ("phi3.5-moe-42b-a6.6b", "train_4k")


def main() -> dict:
    arch, shape = CELL
    out = {}
    # --- MCTS internal breakdown ---
    mdp = make_mdp(arch, shape)
    cfg = dataclasses.replace(scaled_cfg("mcts_30s"), seed=0)
    tuner = ProTuner(mdp, n_standard=15, n_greedy=1, mcts_config=cfg, seed=0)
    t0 = time.perf_counter()
    res = tuner.run()
    wall = time.perf_counter() - t0
    sim = sum(t.sim_time for t in tuner.trees)
    ev = sum(t.eval_time for t in tuner.trees)
    out["mcts_wall_s"] = wall
    out["mcts_sim_frac"] = sim / max(sim + ev, 1e-9)
    out["mcts_eval_frac"] = ev / max(sim + ev, 1e-9)
    out["mcts_evals"] = res.n_evals

    # --- evals per algorithm under equal decisions ---
    for algo in ("greedy", "beam", "mcts_10s"):
        t0 = time.perf_counter()
        r, m = run_algo(arch, shape, algo, seed=0)
        out[f"{algo}_evals"] = r.n_evals
        out[f"{algo}_wall_s"] = time.perf_counter() - t0
        out[f"{algo}_cost"] = r.cost

    emit([out], "search_time")
    csv_line("search_time_mcts_sim_frac", out["mcts_wall_s"] * 1e6,
             f"{out['mcts_sim_frac']:.3f}")
    csv_line("search_time_mcts_eval_frac", 0.0, f"{out['mcts_eval_frac']:.3f}")
    for algo in ("greedy", "beam", "mcts_10s"):
        csv_line(f"search_time[{algo}]", out[f"{algo}_wall_s"] * 1e6,
                 f"evals={out[f'{algo}_evals']}")
    return out


if __name__ == "__main__":
    main()
