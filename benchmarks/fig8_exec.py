"""Fig. 8 analogue: minimum 'EXECUTION TIME' of the schedules each algorithm
chose, normalized to the best across algorithms.

Execution time = noise-FREE analytic step time of the chosen plan (the
search only ever saw the noisy model).  With ``--measure``, the chosen plans
are additionally compiled on the production mesh (subprocess XLA) and the
HLO-derived step time is reported — the paper's compiled-and-run metric; the
Jamba/ResNet50 cell is excluded from measurement (paper §4.2 caveat) and
falls back to analytic.
"""
from __future__ import annotations

import time

from benchmarks.common import (ALGOS_FIG7, SUITE, best_of_seeds, csv_line,
                               emit, geomean, true_cost)

NOISE = 0.25
ALGOS = ALGOS_FIG7 + ["mcts_cost+real_30s", "mcts_cost+real_1s"]
MEASURE_EXCLUDE = {"jamba-1.5-large-398b"}  # the ResNet50 role


def _real_fn(arch, shape):
    from repro.core.measure import make_measure_fn

    return make_measure_fn(arch, shape, "single")


def main(cells=None, seeds=(0, 1), measure: bool = False) -> dict:
    cells = cells or SUITE
    rows = []
    per_algo = {a: [] for a in ALGOS}
    for arch, shape in cells:
        t0 = time.time()
        exec_t = {}
        for algo in ALGOS:
            measure_fn = None
            if "real" in algo:
                if measure and arch not in MEASURE_EXCLUDE:
                    measure_fn = _real_fn(arch, shape)
                else:
                    # cost-model-only fallback (paper's ResNet50 protocol):
                    # the real-measure variant degrades to its base config
                    measure_fn = None
            res, mdp = best_of_seeds(arch, shape, algo, seeds=seeds,
                                     noise_sigma=NOISE, measure_fn=measure_fn)
            exec_t[algo] = true_cost(arch, shape, res.plan)
        best = min(exec_t.values())
        for algo, c in exec_t.items():
            per_algo[algo].append(c / best)
            rows.append({"cell": f"{arch}×{shape}", "algo": algo,
                         "exec_s": c, "normalized": c / best})
        print(f"[fig8] {arch}×{shape}: " + " ".join(
            f"{a}={exec_t[a]/best:.3f}" for a in ALGOS) +
            f" ({time.time()-t0:.0f}s)", flush=True)
    summary = {a: geomean(v) for a, v in per_algo.items()}
    emit(rows + [{"cell": "GEOMEAN", "algo": a, "normalized": g}
                 for a, g in summary.items()], "fig8_exec")
    for a, g in summary.items():
        csv_line(f"fig8_exec_geomean[{a}]", 0.0, f"{g:.4f}")
    return summary


if __name__ == "__main__":
    import sys

    main(measure="--measure" in sys.argv)
