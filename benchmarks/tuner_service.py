"""Tuner-as-a-service serving benchmark + deterministic CI serve gate.

Headline metrics for the daemon (ISSUE-7 acceptance):

* store hit rate over a repeat-heavy request stream;
* p50/p99 time-to-plan, split by cold (search) vs warm (store) requests;
* zero re-searches on warm cells — every repeat request is answered from
  the persistent store with no new search evals;
* cold-path bit-identity — the daemon's cold plan/cost/decisions equal
  one-shot ``autotune()`` on the same cell/seed.

Three front ends:

    PYTHONPATH=src python -m benchmarks.tuner_service            # artifact
    PYTHONPATH=src python -m benchmarks.tuner_service --check    # CI gate
    PYTHONPATH=src python -m benchmarks.tuner_service --faults   # CI gate

``--check`` additionally restarts the service on the SAME store (fresh
process state, persistent disk state) and asserts every request is a
store hit with zero searches, then round-trips one request through the
actual socket daemon (subprocess) — exit 0 = pass, 1 = fail.  Everything
is analytic/XLA-free, so the gate is seconds.

``--faults`` is the crash-safety gate (ISSUE-10): three deterministic
fault scenarios with EXACT expected counters and zero lost requests —

1. crash_resume — SIGKILL the subprocess daemon mid-search (slowed by
   the fault-injection round delay); exactly 1 write-ahead journal entry
   survives, 0 plans; the restarted daemon replays the journal from the
   round-boundary checkpoint and answers the repeat request from the
   store, bit-identical to one-shot ``autotune()``.
2. deadline_resume — a deadlined request returns best-so-far with
   ``interrupted`` provenance (nothing recorded, checkpoint kept); the
   retry resumes and lands the full bit-identical result.
3. overload — bounded queue of 1 under 4 concurrent requests: exactly
   2 structured ``overloaded`` rejections with retry hints, 2 served,
   graceful drain on shutdown.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time
import uuid

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import ENGINE_STAMP, emit  # noqa: E402

# a repeat-heavy stream over 3 cells: 6 unique requests, 18 total
CELLS = [
    ("granite-3-2b", "train_4k"),
    ("granite-moe-1b-a400m", "train_4k"),
    ("granite-3-2b", "decode_32k"),
]
SEEDS = (0, 1)
REPEATS = 3
ALGO = "mcts_1s"
N_STANDARD, N_GREEDY = 2, 1


def _requests():
    reqs = []
    for _ in range(REPEATS):
        for arch, shape in CELLS:
            for seed in SEEDS:
                reqs.append(dict(arch=arch, shape=shape, algo=ALGO,
                                 seed=seed, n_standard=N_STANDARD,
                                 n_greedy=N_GREEDY))
    return reqs


def _pctile(xs, q):
    if not xs:
        return None
    xs = sorted(xs)
    i = min(len(xs) - 1, int(round(q * (len(xs) - 1))))
    return xs[i]


def drive(service, requests):
    """Run a request stream; returns (rows, summary)."""
    rows = []
    for req in requests:
        out = service.handle(dict(req))
        rows.append({
            "request": out["request"],
            "served": out["served"],
            "time_to_plan_s": out["time_to_plan_s"],
            "cost": out["result"]["cost"],
            "plan": out["result"]["plan"],
            "decisions": len(out["result"]["decisions"]),
        })
    cold = [r["time_to_plan_s"] for r in rows if r["served"] == "search"]
    warm = [r["time_to_plan_s"] for r in rows if r["served"] == "store"]
    summary = {
        "n_requests": len(rows),
        "n_cold": len(cold),
        "n_warm": len(warm),
        "store": service.store.stats(),
        "time_to_plan_s": {
            "cold_p50": _pctile(cold, 0.50), "cold_p99": _pctile(cold, 0.99),
            "warm_p50": _pctile(warm, 0.50), "warm_p99": _pctile(warm, 0.99),
        },
    }
    return rows, summary


def check_socket_roundtrip(store_dir: str) -> dict:
    """Round-trip one request through the real subprocess daemon."""
    from repro.launch.tune_serve import TuneClient

    sock = os.path.join(tempfile.gettempdir(),
                        f"tuner-{uuid.uuid4().hex[:8]}.sock")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.tune_serve", "serve",
         "--store", store_dir, "--socket", sock, "--max-requests", "1"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
    )
    try:
        deadline = time.time() + 60
        while not os.path.exists(sock):
            assert proc.poll() is None, proc.stdout.read()
            assert time.time() < deadline, "daemon never bound its socket"
            time.sleep(0.05)
        client = TuneClient(sock)
        assert client.ping()["ok"]
        arch, shape = CELLS[0]
        out = client.tune(arch, shape, algo=ALGO, seed=SEEDS[0],
                          n_standard=N_STANDARD, n_greedy=N_GREEDY)
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert out["ok"] and out["served"] == "store", out.get("served")
    return out


def _spawn_daemon(store_dir: str, sock: str, *extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.tune_serve", "serve",
         "--store", store_dir, "--socket", sock, *extra],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
    )


def _wait_for(pred, timeout_s=60.0, interval=0.05):
    deadline = time.time() + timeout_s
    while time.time() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return False


def fault_crash_resume(tmp: str, ref) -> dict:
    """SIGKILL mid-search; journal + checkpoint survive; the restarted
    daemon recovers and serves the complete, bit-identical result."""
    import signal
    import threading

    from repro.launch.tune_serve import TuneClient

    store = os.path.join(tmp, "crash-store")
    sock = os.path.join(tmp, "crash.sock")
    arch, shape = CELLS[0]
    ckpt_dir = os.path.join(store, "checkpoints")
    journal_dir = os.path.join(store, "journal")

    proc = _spawn_daemon(store, sock,
                         "--checkpoint-every", "1", "--round-delay", "0.15")
    try:
        assert _wait_for(lambda: os.path.exists(sock)), "daemon never bound"

        def fire():
            try:
                TuneClient(sock).tune(arch, shape, algo=ALGO, seed=SEEDS[0],
                                      n_standard=N_STANDARD, n_greedy=N_GREEDY)
            except Exception:
                pass  # the daemon dies mid-request by design

        t = threading.Thread(target=fire, daemon=True)
        t.start()
        assert _wait_for(
            lambda: os.path.isdir(ckpt_dir) and os.listdir(ckpt_dir),
            interval=0.02,
        ), "no checkpoint appeared mid-search"
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=10)
        t.join(timeout=10)
    finally:
        if proc.poll() is None:
            proc.kill()

    # exact post-crash state: 1 pending journal entry, 0 landed plans
    pending_after_kill = len(os.listdir(journal_dir))
    plans_after_kill = len(os.listdir(os.path.join(store, "plans")))
    assert pending_after_kill == 1, pending_after_kill
    assert plans_after_kill == 0, plans_after_kill

    os.remove(sock)  # the SIGKILLed daemon left a stale socket file
    proc = _spawn_daemon(store, sock, "--checkpoint-every", "1",
                         "--round-delay", "0.15", "--max-requests", "1")
    try:
        assert _wait_for(lambda: os.path.exists(sock)), "restart never bound"
        out = TuneClient(sock, timeout=120.0).tune(
            arch, shape, algo=ALGO, seed=SEEDS[0],
            n_standard=N_STANDARD, n_greedy=N_GREEDY)
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)

    assert out["ok"] and out["served"] == "store", out
    # the socket hop JSON-serializes the plan (tuples -> lists); compare
    # through the same round-trip
    assert out["result"]["plan"] == json.loads(json.dumps(ref.plan.to_dict()))
    assert out["result"]["cost"] == ref.cost
    assert out["result"]["decisions"] == ref.decisions
    assert os.listdir(journal_dir) == []   # recovery released the journal
    assert os.listdir(ckpt_dir) == []      # ... and cleared the checkpoint
    return {
        "pending_journal_after_kill": pending_after_kill,
        "plans_after_kill": plans_after_kill,
        "recovered_served": out["served"],
        "bit_identical": True,
        "lost_requests": 0,
    }


def fault_deadline_resume(tmp: str, ref) -> dict:
    """Deadline interrupt returns best-so-far + provenance; the retry
    resumes from the kept checkpoint and lands the full result."""
    from repro.service.daemon import TunerService
    from repro.service.store import canonical_request

    arch, shape = CELLS[0]
    req = dict(arch=arch, shape=shape, algo=ALGO, seed=SEEDS[0],
               n_standard=N_STANDARD, n_greedy=N_GREEDY)
    svc = TunerService(os.path.join(tmp, "deadline-store"),
                       checkpoint_every=1, round_delay_s=0.05,
                       log=lambda *a: None)
    key = canonical_request(**req)
    cut = svc.handle(dict(req, deadline_s=0.12))
    assert cut["ok"] and cut["served"] == "search", cut
    info = cut["result"]["stats"]["interrupted"]
    assert info["reason"] == "deadline", info
    assert 0 < info["rounds_done"] < info["rounds_total"], info
    assert svc.store.lookup(key) is None          # partial never recorded
    assert svc.store.load_checkpoint(key) is not None
    assert svc.store.pending_requests() == []     # client got its answer

    out = svc.handle(dict(req))                   # resumes and completes
    assert out["ok"] and "interrupted" not in out["result"]["stats"]
    assert out["result"]["plan"] == ref.plan.to_dict()
    assert out["result"]["cost"] == ref.cost
    assert out["result"]["decisions"] == ref.decisions
    assert svc.store.load_checkpoint(key) is None
    counters = {
        "n_searches": svc.n_searches,
        "n_interrupted": svc.n_interrupted,
        "rounds_done_at_deadline": info["rounds_done"],
        "bit_identical": True,
        "lost_requests": 0,
    }
    assert counters["n_searches"] == 2 and counters["n_interrupted"] == 1
    svc.shutdown()
    return counters


def fault_overload(tmp: str) -> dict:
    """Bounded queue of 1 under 4 concurrent requests: exactly 2
    structured rejections, 2 served, graceful shutdown."""
    import threading

    from repro.launch.tune_serve import TuneClient
    from repro.service.daemon import TunerService, serve_forever

    arch, shape = CELLS[0]
    svc = TunerService(os.path.join(tmp, "overload-store"),
                       round_delay_s=0.08, log=lambda *a: None)
    sock = os.path.join(tmp, "overload.sock")
    server = threading.Thread(
        target=serve_forever, args=(svc, sock),
        kwargs=dict(queue_size=1), daemon=True)
    server.start()
    assert _wait_for(lambda: os.path.exists(sock)), "server never bound"
    client = TuneClient(sock)
    results = {}

    def submit(name):
        results[name] = client.tune(arch, shape, algo=ALGO, seed=SEEDS[0],
                                    n_standard=N_STANDARD, n_greedy=N_GREEDY)

    t1 = threading.Thread(target=submit, args=("inflight",), daemon=True)
    t1.start()
    assert _wait_for(lambda: svc.n_requests >= 1)   # search is IN handle
    t2 = threading.Thread(target=submit, args=("queued",), daemon=True)
    t2.start()
    assert _wait_for(
        lambda: client.stats()["stats"]["serve"]["queue_depth"] >= 1)
    overloaded = []
    for _ in range(2):
        out = client.tune(arch, shape, algo=ALGO, seed=SEEDS[0],
                          n_standard=N_STANDARD, n_greedy=N_GREEDY)
        assert not out["ok"] and out["error"] == "overloaded", out
        assert out["retry_after_s"] > 0, out
        overloaded.append(out)
    t1.join(timeout=30)
    t2.join(timeout=30)
    assert results["inflight"]["ok"] and results["queued"]["ok"]
    assert results["inflight"]["served"] == "search"
    assert results["queued"]["served"] == "store"
    st = client.stats()["stats"]["serve"]
    counters = {
        "served": st["served"],
        "n_overloaded": st["n_overloaded"],
        "retry_after_s": [o["retry_after_s"] for o in overloaded],
        "lost_requests": 0,
    }
    assert counters["served"] == 2 and counters["n_overloaded"] == 2
    out = client.shutdown()
    assert out["ok"]
    server.join(timeout=10)
    assert not server.is_alive(), "server did not drain on shutdown"
    return counters


def run_faults(outdir: str) -> int:
    """The --faults CI gate: all three scenarios, exact counters."""
    from repro.core.autotuner import autotune

    arch, shape = CELLS[0]
    ref = autotune(arch, shape, algo=ALGO, seed=SEEDS[0],
                   n_standard=N_STANDARD, n_greedy=N_GREEDY)
    tmp = tempfile.mkdtemp(prefix="tuner-faults-")
    try:
        summary = {
            "crash_resume": fault_crash_resume(tmp, ref),
            "deadline_resume": fault_deadline_resume(tmp, ref),
            "overload": fault_overload(tmp),
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    lost = sum(s["lost_requests"] for s in summary.values())
    assert lost == 0, summary
    for name, s in summary.items():
        print(f"[tuner_service --faults] {name}: "
              + ", ".join(f"{k}={v}" for k, v in s.items()))
    emit([{"engine": ENGINE_STAMP, "summary": summary}],
         "tuner_service_faults", outdir=outdir)
    print("[tuner_service] faults gate OK (zero lost requests)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="assert the serve-gate criteria (CI)")
    ap.add_argument("--faults", action="store_true",
                    help="run the deterministic fault-injection gate "
                         "(crash/deadline/overload) instead of the "
                         "serving benchmark")
    ap.add_argument("--store", default=None,
                    help="persistent store dir (default: tmp, wiped)")
    ap.add_argument("--outdir", default="experiments/bench")
    args = ap.parse_args(argv)

    if args.faults:
        return run_faults(args.outdir)

    from repro.core.autotuner import autotune
    from repro.service.daemon import TunerService

    store_dir = args.store or tempfile.mkdtemp(prefix="tuner-store-")
    owned_tmp = args.store is None

    try:
        svc = TunerService(store_dir, log=lambda *a: None)
        rows, summary = drive(svc, _requests())
        svc.shutdown()

        # cold-path bit-identity vs one-shot autotune on the first cell
        arch, shape = CELLS[0]
        ref = autotune(arch, shape, algo=ALGO, seed=SEEDS[0],
                       n_standard=N_STANDARD, n_greedy=N_GREEDY)
        first = next(r for r in rows
                     if r["request"]["arch"] == arch
                     and r["request"]["shape"] == shape
                     and r["request"]["seed"] == SEEDS[0])
        identical = (first["plan"] == ref.plan.to_dict()
                     and first["cost"] == ref.cost
                     and first["decisions"] == len(ref.decisions))
        summary["cold_bit_identical"] = identical

        # restart on the same store: EVERY request must be a store hit
        svc2 = TunerService(store_dir, log=lambda *a: None)
        rows2, summary2 = drive(svc2, _requests())
        svc2.shutdown()
        summary["after_restart"] = {
            "n_warm": summary2["n_warm"],
            "n_searches": svc2.n_searches,
            "hit_rate": summary2["store"]["hit_rate"],
        }

        print(f"[tuner_service] {summary['n_requests']} requests: "
              f"{summary['n_cold']} cold / {summary['n_warm']} warm, "
              f"hit rate {summary['store']['hit_rate']:.2f}")
        t = summary["time_to_plan_s"]
        print(f"[tuner_service] time-to-plan p50/p99: "
              f"cold {t['cold_p50']:.3f}/{t['cold_p99']:.3f}s, "
              f"warm {t['warm_p50']*1e3:.1f}/{t['warm_p99']*1e3:.1f}ms")
        print(f"[tuner_service] cold-path bit-identical: {identical}; "
              f"restart: {summary['after_restart']}")

        emit([{"engine": ENGINE_STAMP, "summary": summary, "rows": rows}],
             "tuner_service", outdir=args.outdir)

        if args.check:
            n_unique = len(CELLS) * len(SEEDS)
            assert summary["n_cold"] == n_unique, summary
            assert summary["n_warm"] == len(rows) - n_unique, summary
            assert identical, "cold daemon result != one-shot autotune"
            # warm restart: zero searches, all store hits
            assert svc2.n_searches == 0, svc2.n_searches
            assert summary2["n_warm"] == len(rows2), summary2
            # repeat answers are the stored answers, bit-for-bit
            by_key = {json.dumps(r["request"], sort_keys=True): r
                      for r in rows}
            for r in rows2:
                ref_row = by_key[json.dumps(r["request"], sort_keys=True)]
                assert r["plan"] == ref_row["plan"], r["request"]
                assert r["cost"] == ref_row["cost"], r["request"]
            check_socket_roundtrip(store_dir)
            print("[tuner_service] serve gate OK")
    finally:
        if owned_tmp:
            shutil.rmtree(store_dir, ignore_errors=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
