"""Tuner-as-a-service serving benchmark + deterministic CI serve gate.

Headline metrics for the daemon (ISSUE-7 acceptance):

* store hit rate over a repeat-heavy request stream;
* p50/p99 time-to-plan, split by cold (search) vs warm (store) requests;
* zero re-searches on warm cells — every repeat request is answered from
  the persistent store with no new search evals;
* cold-path bit-identity — the daemon's cold plan/cost/decisions equal
  one-shot ``autotune()`` on the same cell/seed.

Two front ends over one scenario:

    PYTHONPATH=src python -m benchmarks.tuner_service            # artifact
    PYTHONPATH=src python -m benchmarks.tuner_service --check    # CI gate

``--check`` additionally restarts the service on the SAME store (fresh
process state, persistent disk state) and asserts every request is a
store hit with zero searches, then round-trips one request through the
actual socket daemon (subprocess) — exit 0 = pass, 1 = fail.  Everything
is analytic/XLA-free, so the gate is seconds.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time
import uuid

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import ENGINE_STAMP, emit  # noqa: E402

# a repeat-heavy stream over 3 cells: 6 unique requests, 18 total
CELLS = [
    ("granite-3-2b", "train_4k"),
    ("granite-moe-1b-a400m", "train_4k"),
    ("granite-3-2b", "decode_32k"),
]
SEEDS = (0, 1)
REPEATS = 3
ALGO = "mcts_1s"
N_STANDARD, N_GREEDY = 2, 1


def _requests():
    reqs = []
    for _ in range(REPEATS):
        for arch, shape in CELLS:
            for seed in SEEDS:
                reqs.append(dict(arch=arch, shape=shape, algo=ALGO,
                                 seed=seed, n_standard=N_STANDARD,
                                 n_greedy=N_GREEDY))
    return reqs


def _pctile(xs, q):
    if not xs:
        return None
    xs = sorted(xs)
    i = min(len(xs) - 1, int(round(q * (len(xs) - 1))))
    return xs[i]


def drive(service, requests):
    """Run a request stream; returns (rows, summary)."""
    rows = []
    for req in requests:
        out = service.handle(dict(req))
        rows.append({
            "request": out["request"],
            "served": out["served"],
            "time_to_plan_s": out["time_to_plan_s"],
            "cost": out["result"]["cost"],
            "plan": out["result"]["plan"],
            "decisions": len(out["result"]["decisions"]),
        })
    cold = [r["time_to_plan_s"] for r in rows if r["served"] == "search"]
    warm = [r["time_to_plan_s"] for r in rows if r["served"] == "store"]
    summary = {
        "n_requests": len(rows),
        "n_cold": len(cold),
        "n_warm": len(warm),
        "store": service.store.stats(),
        "time_to_plan_s": {
            "cold_p50": _pctile(cold, 0.50), "cold_p99": _pctile(cold, 0.99),
            "warm_p50": _pctile(warm, 0.50), "warm_p99": _pctile(warm, 0.99),
        },
    }
    return rows, summary


def check_socket_roundtrip(store_dir: str) -> dict:
    """Round-trip one request through the real subprocess daemon."""
    from repro.launch.tune_serve import TuneClient

    sock = os.path.join(tempfile.gettempdir(),
                        f"tuner-{uuid.uuid4().hex[:8]}.sock")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.tune_serve", "serve",
         "--store", store_dir, "--socket", sock, "--max-requests", "1"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
    )
    try:
        deadline = time.time() + 60
        while not os.path.exists(sock):
            assert proc.poll() is None, proc.stdout.read()
            assert time.time() < deadline, "daemon never bound its socket"
            time.sleep(0.05)
        client = TuneClient(sock)
        assert client.ping()["ok"]
        arch, shape = CELLS[0]
        out = client.tune(arch, shape, algo=ALGO, seed=SEEDS[0],
                          n_standard=N_STANDARD, n_greedy=N_GREEDY)
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert out["ok"] and out["served"] == "store", out.get("served")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="assert the serve-gate criteria (CI)")
    ap.add_argument("--store", default=None,
                    help="persistent store dir (default: tmp, wiped)")
    ap.add_argument("--outdir", default="experiments/bench")
    args = ap.parse_args(argv)

    from repro.core.autotuner import autotune
    from repro.service.daemon import TunerService

    store_dir = args.store or tempfile.mkdtemp(prefix="tuner-store-")
    owned_tmp = args.store is None

    try:
        svc = TunerService(store_dir, log=lambda *a: None)
        rows, summary = drive(svc, _requests())
        svc.shutdown()

        # cold-path bit-identity vs one-shot autotune on the first cell
        arch, shape = CELLS[0]
        ref = autotune(arch, shape, algo=ALGO, seed=SEEDS[0],
                       n_standard=N_STANDARD, n_greedy=N_GREEDY)
        first = next(r for r in rows
                     if r["request"]["arch"] == arch
                     and r["request"]["shape"] == shape
                     and r["request"]["seed"] == SEEDS[0])
        identical = (first["plan"] == ref.plan.to_dict()
                     and first["cost"] == ref.cost
                     and first["decisions"] == len(ref.decisions))
        summary["cold_bit_identical"] = identical

        # restart on the same store: EVERY request must be a store hit
        svc2 = TunerService(store_dir, log=lambda *a: None)
        rows2, summary2 = drive(svc2, _requests())
        svc2.shutdown()
        summary["after_restart"] = {
            "n_warm": summary2["n_warm"],
            "n_searches": svc2.n_searches,
            "hit_rate": summary2["store"]["hit_rate"],
        }

        print(f"[tuner_service] {summary['n_requests']} requests: "
              f"{summary['n_cold']} cold / {summary['n_warm']} warm, "
              f"hit rate {summary['store']['hit_rate']:.2f}")
        t = summary["time_to_plan_s"]
        print(f"[tuner_service] time-to-plan p50/p99: "
              f"cold {t['cold_p50']:.3f}/{t['cold_p99']:.3f}s, "
              f"warm {t['warm_p50']*1e3:.1f}/{t['warm_p99']*1e3:.1f}ms")
        print(f"[tuner_service] cold-path bit-identical: {identical}; "
              f"restart: {summary['after_restart']}")

        emit([{"engine": ENGINE_STAMP, "summary": summary, "rows": rows}],
             "tuner_service", outdir=args.outdir)

        if args.check:
            n_unique = len(CELLS) * len(SEEDS)
            assert summary["n_cold"] == n_unique, summary
            assert summary["n_warm"] == len(rows) - n_unique, summary
            assert identical, "cold daemon result != one-shot autotune"
            # warm restart: zero searches, all store hits
            assert svc2.n_searches == 0, svc2.n_searches
            assert summary2["n_warm"] == len(rows2), summary2
            # repeat answers are the stored answers, bit-for-bit
            by_key = {json.dumps(r["request"], sort_keys=True): r
                      for r in rows}
            for r in rows2:
                ref_row = by_key[json.dumps(r["request"], sort_keys=True)]
                assert r["plan"] == ref_row["plan"], r["request"]
                assert r["cost"] == ref_row["cost"], r["request"]
            check_socket_roundtrip(store_dir)
            print("[tuner_service] serve gate OK")
    finally:
        if owned_tmp:
            shutil.rmtree(store_dir, ignore_errors=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
