"""Analytic roofline cost model for complete TPU schedules — the EXACT
layer of the three-layer cost stack (see docs/architecture.md):

1. **analytic** (this module) — deterministic roofline arithmetic,
   ≈100 µs/plan, the search's default signal and the online trainer's
   ground truth;
2. **learned** (core/learned_cost.py + core/engine/serving.py) — the §3
   MLP, refit online on transposition-cache contents and served on
   cache-miss batches in one jitted forward pass;
3. **real measurement** (core/measure.py) — subprocess XLA compiles,
   re-ranking candidates at root synchronizations (``mcts_cost+real_*``).

Plays the role of the paper's learned cost model in most experiments:
fast, structurally informed, and — by construction — imperfect relative to
the compile-based "real measurement" (core/measure.py derives the same
three roofline terms from the actual XLA HLO).  The search compares plans
by the estimated step time; infeasible plans (HBM over capacity) get a
large but finite multiplicative penalty so the search sees a continuous
landscape, mirroring Halide schedules that compile but run slowly.

All byte/FLOP accounting is per *training/serving step* on the whole mesh;
terms are per the assignment's formulas:

    compute_s    = FLOPs   / (chips × 197 TF/s)
    memory_s     = HBM B   / (chips × 819 GB/s)
    collective_s = wire B/chip / 50 GB/s
    step_s       = max(compute, memory) + (1 - overlap)·collective

Since the columnar refactor the hot path is ONE kernel: a batch of plans
is encoded once as a structure-of-arrays (``PlanColumns.from_plans``) and
every roofline term is computed as numpy column math over the whole batch
(``_terms_columnar``).  The scalar ``cost()``/``terms()`` route through
the same size dispatch as ``cost_batch`` (a batch of one), so the scalar
and batched signals cannot drift apart.  The pre-columnar per-plan
arithmetic is kept verbatim as ``_terms_scalar`` — the oracle the kernel
is differentially certified against (and, because certification makes
the two interchangeable, the fast path for batches below
``columnar_min_batch`` where numpy dispatch overhead dominates): the
column math performs the same IEEE-754 operations on the same operands
in the same order (inapplicable parts contribute exact ``0.0`` addends;
branch-dependent constants are gathered per discrete key), so the two
paths agree bit-for-bit, asserted by ``tests/test_differential.py`` and
the hypothesis properties.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.core.space import MeshSpec, SchedulePlan, ScheduleSpace


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 per chip
    hbm_bw: float = 819e9  # B/s per chip
    link_bw: float = 50e9  # B/s per ICI link
    hbm_bytes: float = 16 * 2**30
    vmem_bytes: float = 128 * 2**20
    pod_link_bw: float = 25e9  # inter-pod (DCN/optical) per chip-pair


HW = HardwareSpec()

BF16 = 2
F32 = 4

# ---------------------------------------------------------------------------
# Discrete plan-field code tables (shared by the columnar kernel and the
# learned-cost featurizer).  Codes index into these tuples; the derived
# boolean lookup tables vectorize the scalar ``in (...)`` membership tests.
# ---------------------------------------------------------------------------
STRATEGIES = ("replicated", "tp", "fsdp", "fsdp_tp", "tp2d")
MOE_MODES = ("ep", "tp", "dense")
REMAT_MODES = ("none", "dots", "full")
GRAD_COMM_MODES = ("fp32", "int8", "rs_ag")

_STRAT_CODE = {s: i for i, s in enumerate(STRATEGIES)}
_MOE_CODE = {m: i for i, m in enumerate(MOE_MODES)}
_REMAT_CODE = {r: i for i, r in enumerate(REMAT_MODES)}
_GRAD_CODE = {g: i for i, g in enumerate(GRAD_COMM_MODES)}

# the ONE definition of which strategies enable each sharding axis —
# the scalar path's membership tests and the kernel's boolean gather
# tables both derive from these (no third copy to drift)
TP_STRATEGIES = frozenset(("tp", "fsdp_tp", "tp2d"))
FSDP_STRATEGIES = frozenset(("fsdp", "fsdp_tp", "tp2d"))
_TP_ON = np.array([s in TP_STRATEGIES for s in STRATEGIES])
_FSDP_ON = np.array([s in FSDP_STRATEGIES for s in STRATEGIES])

# branch constants, in code order — gathered per plan by the kernel with the
# exact values the scalar dict lookups produce
_REMAT_MULT = np.array([3.0, 3.35, 4.0])  # none, dots, full
_GRAD_SCALE_ZERO3 = np.array([2.0, 0.5, 1.0])  # fp32, int8, rs_ag
_GRAD_SCALE_AR = np.array([2.0, 0.25, 1.0])
# resident bytes/param (same expressions as _state_bytes_per_param)
_SBYTES_F32 = BF16 + 2 * 4 + 4
_SBYTES_INT8 = BF16 + 2 * 1.1 + 4

# ---------------------------------------------------------------------------
# The jitted pricing path (``pricing="jit"``).
#
# ``_terms_jitted`` runs the SAME roofline arithmetic as ``_terms_columnar``
# as one jax-jitted elementwise program over padded columns (pow-2 padding,
# the ``LearnedCostModel.cost_batch`` idiom, so the XLA compile cache stays
# bounded).  The kernel traces and executes under ``enable_x64`` so every
# elementwise op is the same float64 operation the numpy kernel performs —
# empirically bit-identical on this XLA CPU build, but XLA is free to
# contract multiplies and adds, so the CONTRACT is relative agreement
# within ``JIT_RTOL``, not bit-equality (pinned by the jit-parity
# hypothesis property).  Because the contract is a tolerance, the jitted
# path carries a versioned ``pricing_tag`` distinct from the exact paths:
# transposition-cache snapshots and plan-store requests priced under
# different tags never mix (store.py keys on the tag).
# ---------------------------------------------------------------------------
JIT_PRICING_TAG = "analytic-jit-v1"
JIT_RTOL = 1e-9  # |jit - columnar| <= JIT_RTOL * columnar, elementwise
# Unique-batch size at/above which pricing="jit" uses the jitted kernel
# (below it: the certified scalar replay, exactly like columnar_min_batch).
# The columnar kernel's crossover vs scalar replay sits at 16; the jitted
# kernel's measured crossover on the decode headline cell sits between 4
# and 8 (jax dispatch is ~120µs flat on CPU, the warm scalar walk ~30µs
# per plan, so batch 1 stays scalar), pinned here and re-measured by
# benchmarks/engine_throughput.py's ``kernel_jit`` microbench legs.
JIT_MIN_BATCH = 8

_JAX_MODS = None


def _jax_mods():
    """Lazy jax import: the forkserver preload chain (repro.core.ensemble →
    this module) must stay jax-free (asserted by tests/test_engine.py), so
    jax loads only when a pricing="jit" model actually prices a batch."""
    global _JAX_MODS
    if _JAX_MODS is None:
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        _JAX_MODS = (jax, jnp, enable_x64)
    return _JAX_MODS


def _pad_pow2(n: int) -> int:
    """Next power of two >= n (>= 1) — bounds the jit compile cache to
    O(log max_batch) specializations, same as learned_cost._pad_len."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _pad_edge(a: np.ndarray, pad: int) -> np.ndarray:
    """Pad a column to ``pad`` rows by repeating the last row — padded lanes
    compute a valid plan's terms (no div-by-zero garbage) and are sliced
    off.  Hand-rolled: np.pad costs ~20µs per column, which at 22 columns
    per batch would eat the kernel's whole win."""
    n = len(a)
    if n == pad:
        return a
    out = np.empty(pad, dtype=a.dtype)
    out[:n] = a
    out[n:] = a[n - 1]
    return out


class PlanColumns:
    """Structure-of-arrays encoding of a ``SchedulePlan`` batch.

    One pass over the plan objects extracts every decision field into a
    flat numpy column (discrete string fields as small-int codes, flags as
    booleans, knobs as integers/floats).  This is the ONE encode a pricing
    batch pays: the analytic kernel (``_terms_columnar``) and the learned
    MLP featurizer (``learned_cost.featurize_columns``) both read these
    columns, so a miss batch handed to ``HybridCostBackend`` is encoded
    once whichever backend ends up pricing it.

    ``plans`` keeps the original objects (ordered) so non-columnar
    consumers — the scalar oracle path, test doubles — can fall back
    without re-materializing them.
    """

    __slots__ = (
        "n", "plans", "pod_data", "strategy", "tp_on", "fsdp_on", "tp2d",
        "mixer_tp", "seq_shard", "ffn_tp", "moe_mode", "moe_ep", "moe_tp",
        "vocab_shard", "remat", "microbatches", "bq", "bkv", "scan_chunk",
        "grad_comm", "overlap", "opt_int8", "kv_int8",
    )

    @classmethod
    def from_plans(cls, plans: Sequence[SchedulePlan]) -> "PlanColumns":
        self = cls.__new__(cls)
        self.n = len(plans)
        self.plans = list(plans)
        self.pod_data = np.array(
            [p.batch_axes == "pod_data" for p in plans], dtype=bool
        )
        strat = np.array([_STRAT_CODE[p.param_strategy] for p in plans],
                         dtype=np.int64)
        self.strategy = strat
        self.tp_on = _TP_ON[strat]
        self.fsdp_on = _FSDP_ON[strat]
        self.tp2d = strat == _STRAT_CODE["tp2d"]
        self.mixer_tp = np.array([p.mixer_tp for p in plans], dtype=bool)
        self.seq_shard = np.array([p.seq_shard for p in plans], dtype=bool)
        self.ffn_tp = np.array([p.ffn_tp for p in plans], dtype=bool)
        moe = np.array([_MOE_CODE[p.moe_mode] for p in plans], dtype=np.int64)
        self.moe_mode = moe
        self.moe_ep = moe == _MOE_CODE["ep"]
        self.moe_tp = moe == _MOE_CODE["tp"]
        self.vocab_shard = np.array([p.vocab_shard for p in plans], dtype=bool)
        self.remat = np.array([_REMAT_CODE[p.remat] for p in plans],
                              dtype=np.int64)
        self.microbatches = np.array([p.microbatches for p in plans],
                                     dtype=np.int64)
        self.bq = np.array([p.attn_block[0] for p in plans], dtype=np.int64)
        self.bkv = np.array([p.attn_block[1] for p in plans], dtype=np.int64)
        self.scan_chunk = np.array([p.scan_chunk for p in plans],
                                   dtype=np.int64)
        self.grad_comm = np.array([_GRAD_CODE[p.grad_comm] for p in plans],
                                  dtype=np.int64)
        self.overlap = np.array([p.overlap for p in plans], dtype=np.float64)
        self.opt_int8 = np.array([p.opt_dtype == "int8" for p in plans],
                                 dtype=bool)
        self.kv_int8 = np.array([p.kv_dtype == "int8" for p in plans],
                                dtype=bool)
        return self

    def stage_onehots(self, stage) -> List[np.ndarray]:
        """Boolean indicator columns, one per option of ``stage``, in
        option order — ``stage_onehots(s)[a][i]`` is True iff plan ``i``
        chose option ``a``.  The vectorized equivalent of the learned
        featurizer's per-stage one-hot block (``learned_cost.featurize``),
        shared so both cost backends read one encoding."""
        name = stage.name
        if name == "attn_block":
            return [(self.bq == q) & (self.bkv == k) for q, k in stage.options]
        if name == "batch_axes":
            return [self.pod_data == (o == "pod_data") for o in stage.options]
        coded = {
            "param_strategy": (self.strategy, _STRAT_CODE),
            "moe_mode": (self.moe_mode, _MOE_CODE),
            "remat": (self.remat, _REMAT_CODE),
            "grad_comm": (self.grad_comm, _GRAD_CODE),
        }
        if name in coded:
            col, code = coded[name]
            return [col == code[o] for o in stage.options]
        if name in ("opt_dtype", "kv_dtype"):
            col = self.opt_int8 if name == "opt_dtype" else self.kv_int8
            return [col == (o == "int8") for o in stage.options]
        col = getattr(self, name)  # bool flags / numeric knobs
        return [col == o for o in stage.options]


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    step_s: float
    flops: float  # whole-step HLO-equivalent FLOPs (all chips)
    hbm_bytes: float  # whole-step HBM traffic (all chips)
    coll_bytes_per_chip: float
    hbm_per_chip: float  # resident bytes per chip
    feasible: bool
    model_flops: float  # 6·N_active·D
    details: Dict[str, float] = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def mfu(self) -> float:
        """MODEL_FLOPS / (step_s × chips × peak) — filled by caller context."""
        return self.details.get("mfu", 0.0)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        return d


class _EvalContext:
    """Plan-independent evaluation state for ``terms``.

    Everything here is a pure function of (cfg, shape, mesh, hw) — or of one
    of the handful of *discrete* plan fields (the TP degree, the KV dtype,
    the flash block pair) — so it can be computed once and reused across a
    whole batch of plans.  Only WHOLE subexpressions are memoized, exactly
    as the scalar path computes them (the per-layer accumulation loops run
    unchanged, once per distinct key); sums are never re-associated, so a
    cached context and a fresh one produce bit-identical IEEE-754 results.

    ``terms`` builds a fresh context per call (scalar evaluation does the
    same work it always did); ``cost_batch`` keeps one context alive on the
    model instance and amortizes the accounting across the batch — this
    asymmetry is what makes batched leaf evaluation cheaper than N scalar
    calls while ``cost_batch(plans) == [cost(p) for p in plans]`` stays an
    exact (``==``) contract, enforced by the hypothesis property tests.
    """

    __slots__ = (
        "m", "_fwd_total", "_param_bytes", "_param_count", "_groups",
        "_layer_counts", "_act_mults", "_kv_totals", "_vmem_spill",
        "_n_periods", "_n_active",
    )

    def __init__(self, model: "AnalyticCostModel"):
        self.m = model
        self._fwd_total: Optional[float] = None
        self._param_bytes: Optional[float] = None
        self._param_count: Optional[int] = None
        self._groups: Optional[Dict[str, int]] = None
        self._layer_counts: Optional[Tuple[int, int, int, int]] = None
        self._act_mults: Dict[int, Tuple[float, float]] = {}
        self._kv_totals: Dict[float, float] = {}
        self._vmem_spill: Dict[Tuple[int, int], bool] = {}
        self._n_periods: Optional[int] = None
        self._n_active: Optional[int] = None

    def n_periods(self) -> int:
        if self._n_periods is None:
            self._n_periods = self.m.cfg.n_periods
        return self._n_periods

    def active_param_count(self) -> int:
        if self._n_active is None:
            self._n_active = self.m.cfg.active_param_count()
        return self._n_active

    def fwd_flops(self) -> float:
        if self._fwd_total is None:
            self._fwd_total = self.m._fwd_flops()[0]
        return self._fwd_total

    def param_count(self) -> int:
        if self._param_count is None:
            self._param_count = self.m.cfg.param_count()
        return self._param_count

    def param_bytes(self) -> float:
        if self._param_bytes is None:
            self._param_bytes = self.m._param_bytes()
        return self._param_bytes

    def param_groups(self) -> Dict[str, int]:
        if self._groups is None:
            self._groups = self.m._param_groups()
        return self._groups

    def layer_counts(self) -> Tuple[int, int, int, int]:
        """(attn, mamba, dense, moe) layer counts per period — integers, so
        replacing the per-plan counting loop is exact."""
        if self._layer_counts is None:
            na = nm = nd = ne = 0
            for spec in self.m.cfg.layer_plan():
                if spec.mixer == "attn":
                    na += 1
                else:
                    nm += 1
                if spec.mlp == "dense":
                    nd += 1
                elif spec.mlp == "moe":
                    ne += 1
            self._layer_counts = (na, nm, nd, ne)
        return self._layer_counts

    def act_mults(self, tp: int) -> Tuple[float, float]:
        """(ffn_mult, mixer_mult) stored-activation multipliers; the loop
        divides by ``tp`` per term, so it is keyed by the (two-valued) TP
        degree and re-run verbatim per key."""
        got = self._act_mults.get(tp)
        if got is None:
            cfg = self.m.cfg
            ffn_mult = 0.0
            mixer_mult = 0.0
            for spec in cfg.layer_plan():
                if spec.mlp == "dense":
                    ffn_mult += 2 * cfg.d_ff / tp
                elif spec.mlp == "moe":
                    ffn_mult += 2 * cfg.experts_per_token * 1.25 * cfg.d_ff / tp
                if spec.mixer == "attn":
                    mixer_mult += (
                        cfg.n_heads + 2 * cfg.n_kv_heads
                    ) * cfg.resolved_head_dim / tp
                else:
                    mixer_mult += 3 * cfg.d_inner / tp
            got = self._act_mults[tp] = (ffn_mult, mixer_mult)
        return got

    def kv_total(self, kv_bytes: float) -> float:
        """Whole-model KV/scan-state bytes before sharding, keyed by the
        (two-valued) per-element KV byte width."""
        got = self._kv_totals.get(kv_bytes)
        if got is None:
            cfg, shape = self.m.cfg, self.m.shape
            total = 0.0
            for spec in cfg.layer_plan():
                if spec.mixer == "attn":
                    total += (
                        2 * shape.global_batch * cfg.n_kv_heads
                        * shape.seq_len * cfg.resolved_head_dim * kv_bytes
                    )
                else:
                    total += shape.global_batch * cfg.d_inner * (
                        cfg.ssm_state * F32 + (cfg.conv_width - 1) * BF16
                    )
            got = self._kv_totals[kv_bytes] = total
        return got

    def vmem_spills(self, bq: int, bkv: int) -> bool:
        key = (bq, bkv)
        got = self._vmem_spill.get(key)
        if got is None:
            from repro.kernels.geometry import flash_vmem_bytes

            got = self._vmem_spill[key] = (
                2 * flash_vmem_bytes(bq, bkv, self.m.cfg.resolved_head_dim)
                > self.m.hw.vmem_bytes * 0.75
            )
        return got


class AnalyticCostModel:
    def __init__(
        self,
        cfg: ModelConfig,
        shape: InputShape,
        mesh: MeshSpec,
        hw: HardwareSpec = HW,
        columnar: bool = True,
        columnar_min_batch: Optional[int] = None,
        pricing: Optional[str] = None,
    ):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.hw = hw
        # pricing selects the batch kernel behind the one dispatch:
        #   "scalar"   — the pre-columnar protocol end to end (fresh-context
        #                scalar terms(), per-unique-plan replay in
        #                cost_batch): the oracle the kernels are certified
        #                against, and the baseline benchmark leg;
        #   "columnar" — (default) the vectorized numpy kernel
        #                (_terms_columnar), bit-identical to scalar;
        #   "jit"      — the jax-jitted kernel (_terms_jitted) over padded
        #                columns: same arithmetic, agreement within
        #                JIT_RTOL (a distinct versioned pricing_tag, so
        #                cached values never mix with the exact paths).
        # The legacy columnar=False spelling maps to pricing="scalar".
        if pricing is None:
            pricing = "columnar" if columnar else "scalar"
        if pricing not in ("scalar", "columnar", "jit"):
            raise ValueError(f"unknown pricing path: {pricing!r}")
        self.pricing = pricing
        self.columnar = pricing != "scalar"
        # Unique-plan count below which a columnar batch dispatches to the
        # scalar replay instead of the kernel: numpy column dispatch costs
        # ~2us/op regardless of width (plus ~25 fresh temp buffers per
        # call, which interleaved engine workloads feel harder than tight
        # microbenchmarks do), so small batches — greedy rollout sweeps,
        # single leaves, half-warm lockstep rounds — price faster as
        # scalar walks.  The columnar/scalar paths are certified
        # bit-identical, so there the threshold is a pure performance knob
        # — results cannot depend on it.  Under pricing="jit" the same
        # knob defaults to JIT_MIN_BATCH (the jitted kernel's measured
        # crossover; 1 means every batch, even single leaves, prices
        # through the kernel) and batches below it use the EXACT scalar
        # replay — so there the threshold does select between tagged
        # pricing paths.  Set to 1 to force every batch through the
        # kernel (the differential tests do).
        if columnar_min_batch is None:
            columnar_min_batch = JIT_MIN_BATCH if pricing == "jit" else 16
        self.columnar_min_batch = columnar_min_batch
        self.n_evals = 0
        self._batch_ctx: Optional[_EvalContext] = None
        self._jit_fn = None  # built (and jax imported) on first jit pricing

    @property
    def pricing_tag(self) -> str:
        """Version tag of the value-producing pricing path: "exact" for the
        bit-identical scalar/columnar pair, JIT_PRICING_TAG for the
        tolerance-contract jitted kernel.  Store/cache keys include the
        tag whenever it is not "exact" so values from different contracts
        never mix (see service/store.py)."""
        return JIT_PRICING_TAG if self.pricing == "jit" else "exact"

    def __getstate__(self):
        # the batch context holds derived caches only — drop it so pickled
        # models (process-pool workers) stay lean; it lazily rebuilds.
        # the jitted kernel closure is unpicklable and rebuilds the same way
        d = self.__dict__.copy()
        d["_batch_ctx"] = None
        d["_jit_fn"] = None
        return d

    # ------------------------------------------------------------------
    def _sizes(self, plan: SchedulePlan):
        mesh = self.mesh
        dp = mesh.axis("data")
        if plan.batch_axes == "pod_data" and mesh.multi_pod:
            dp *= mesh.axis("pod")
        tp_on = plan.param_strategy in TP_STRATEGIES
        tp = mesh.axis("model") if tp_on else 1
        fsdp = dp if plan.param_strategy in FSDP_STRATEGIES else 1
        return dp, tp, fsdp, tp_on

    # ------------------------------------------------------------------
    # Structural FLOP / byte accounting
    # ------------------------------------------------------------------
    def _layer_flops_fwd(self, tokens: int, kv_len: int) -> Dict[str, float]:
        """Forward FLOPs per *period*, for `tokens` processed tokens."""
        cfg = self.cfg
        out: Dict[str, float] = {"attn_proj": 0, "attn_sdpa": 0, "mamba": 0, "mlp": 0, "moe": 0}
        hd = cfg.resolved_head_dim
        for spec in cfg.layer_plan():
            d = cfg.d_model
            if spec.mixer == "attn":
                qo = 2 * tokens * d * cfg.n_heads * hd * 2
                kv = 2 * tokens * d * cfg.n_kv_heads * hd * 2
                out["attn_proj"] += qo + kv
                if self.shape.kind == "decode":
                    sdpa = 2 * 2 * tokens * cfg.n_heads * hd * kv_len
                else:
                    sdpa = 2 * 2 * tokens * cfg.n_heads * hd * (kv_len / 2)
                out["attn_sdpa"] += sdpa
            else:
                Di, N = cfg.d_inner, cfg.ssm_state
                dtr = cfg.resolved_dt_rank
                m = 2 * tokens * d * 2 * Di  # in_proj
                m += 2 * tokens * cfg.conv_width * Di
                m += 2 * tokens * Di * (dtr + 2 * N)
                m += 2 * tokens * dtr * Di
                m += 8 * tokens * Di * N  # scan: exp, mul-add state, reduce
                m += 2 * tokens * Di * d  # out_proj
                out["mamba"] += m
            if spec.mlp == "dense":
                mats = 3 if cfg.act == "swiglu" else 2
                out["mlp"] += 2 * tokens * d * cfg.d_ff * mats
            elif spec.mlp == "moe":
                mats = 3 if cfg.act == "swiglu" else 2
                routed = tokens * cfg.experts_per_token * 1.25  # capacity factor
                out["moe"] += 2 * routed * d * cfg.d_ff * mats
                out["moe"] += 2 * tokens * d * cfg.n_experts  # router
        return out

    def _fwd_flops(self) -> Tuple[float, Dict[str, float]]:
        cfg, shape = self.cfg, self.shape
        tokens = shape.tokens  # decode: batch; train/prefill: B*S
        kv_len = shape.seq_len
        per_period = self._layer_flops_fwd(tokens, kv_len)
        total = sum(per_period.values()) * cfg.n_periods
        head = 2 * tokens * cfg.d_model * cfg.vocab_size
        total += head
        per_period["head"] = head
        return total, per_period

    # ------------------------------------------------------------------
    def _param_bytes(self) -> float:
        return self.cfg.param_count() * BF16

    def _param_groups(self) -> Dict[str, int]:
        """Parameter counts by shardability family."""
        cfg = self.cfg
        groups = {"mixer": 0, "ffn": 0, "moe": 0, "vocab": 0, "other": 0}
        for spec in cfg.layer_plan():
            groups["mixer"] += cfg._mixer_params(spec)
            total, _ = cfg._mlp_params(spec)
            if spec.mlp == "moe":
                groups["moe"] += total
            else:
                groups["ffn"] += total
            groups["other"] += 2 * cfg.d_model
        for k in ("mixer", "ffn", "moe", "other"):
            groups[k] *= cfg.n_periods
        emb = cfg.vocab_size * cfg.d_model
        groups["vocab"] = emb if cfg.tie_embeddings else 2 * emb
        return groups

    def _sharded_param_bytes(
        self, plan: SchedulePlan, tp: int, ctx: Optional[_EvalContext] = None
    ) -> float:
        """Per-model-axis-sharded parameter bytes (before the FSDP split):
        the quantity ZeRO-3 must all-gather and the TP axis must hold."""
        cfg = self.cfg
        g = ctx.param_groups() if ctx is not None else self._param_groups()
        tot = 0.0
        tot += g["mixer"] / (tp if plan.mixer_tp and tp > 1 else 1)
        tot += g["ffn"] / (tp if plan.ffn_tp and tp > 1 else 1)
        if g["moe"]:
            if plan.moe_mode == "ep" and tp > 1:
                tot += g["moe"] / min(tp, cfg.n_experts)
            elif plan.moe_mode == "tp" and tp > 1:
                tot += g["moe"] / tp
            else:
                tot += g["moe"]
        vshard = (
            tp if plan.vocab_shard and tp > 1 and cfg.vocab_size % tp == 0 else 1
        )
        tot += g["vocab"] / vshard
        tot += g["other"]
        return tot * BF16

    def _state_bytes_per_param(self, plan: SchedulePlan) -> float:
        """Resident bytes/param incl. the bf16 param itself, the Adam
        moments, and the f32 grad accumulator (matches training/optimizer.py:
        params are single-copy bf16, moments fp32 or rowwise-int8+scale)."""
        if plan.opt_dtype == "int8":
            return BF16 + 2 * 1.1 + 4
        return BF16 + 2 * 4 + 4

    def _activation_bytes_resident(
        self, plan: SchedulePlan, dp: int, tp: int,
        ctx: Optional[_EvalContext] = None,
    ) -> float:
        """Stored activations per chip between fwd and bwd (train only)."""
        cfg, shape = self.cfg, self.shape
        if shape.kind != "train":
            return 0.0
        tokens_local = shape.tokens / dp / max(plan.microbatches, 1)
        d = cfg.d_model
        # bytes stored per token per layer, by remat policy
        if ctx is None:
            ctx = _EvalContext(self)
        ffn_mult, mixer_mult = ctx.act_mults(tp)
        n_per = cfg.n_periods
        if plan.remat == "full":
            stored = tokens_local * d * n_per  # period-boundary inputs only
        elif plan.remat == "dots":
            stored = tokens_local * (d * 4 + mixer_mult * 0.5 + ffn_mult * 0.5) * n_per
        else:
            stored = tokens_local * (d * 6 + mixer_mult + ffn_mult) * n_per
        logits = 0.0
        if plan.remat == "none":
            logits = tokens_local * cfg.vocab_size / (tp if plan.vocab_shard else 1)
        return stored * BF16 + logits * BF16

    def _kv_cache_bytes_per_chip(
        self, plan: SchedulePlan, dp: int, tp: int,
        ctx: Optional[_EvalContext] = None,
    ) -> float:
        cfg, shape = self.cfg, self.shape
        if shape.kind != "decode":
            return 0.0
        kv_bytes = 1.06 if plan.kv_dtype == "int8" else BF16  # int8 + scales
        if ctx is None:
            ctx = _EvalContext(self)
        total = ctx.kv_total(kv_bytes)
        total *= cfg.n_periods
        dp_used = min(dp, max(shape.global_batch, 1))
        shard = dp_used
        if plan.seq_shard:
            # the sequence dim absorbs whatever the batch dim can't use
            shard *= (dp // dp_used) * (tp if not plan.mixer_tp else 1)
        if plan.mixer_tp and plan.param_strategy in TP_STRATEGIES:
            shard *= min(tp, max(cfg.n_kv_heads, 1))
        return total / shard

    # ------------------------------------------------------------------
    # Collectives
    # ------------------------------------------------------------------
    def _collective_bytes_per_chip(
        self, plan: SchedulePlan, dp: int, tp: int, fsdp: int,
        ctx: Optional[_EvalContext] = None,
    ) -> Tuple[float, Dict[str, float]]:
        cfg, shape = self.cfg, self.shape
        if ctx is None:
            ctx = _EvalContext(self)
        train = shape.kind == "train"
        out: Dict[str, float] = {}
        total = 0.0
        n_mb = max(plan.microbatches, 1)
        tokens_local = shape.tokens / min(dp, max(shape.global_batch, 1))

        # --- parameter-axis collectives ---
        p_tp_bytes = self._sharded_param_bytes(plan, tp, ctx)
        if train:
            if fsdp > 1:
                # ZeRO-3: AG params in fwd + AG in bwd + RS grads, per microbatch
                shard_bytes = p_tp_bytes / fsdp
                ag = shard_bytes * (fsdp - 1)
                grad_scale = {"fp32": 2.0, "rs_ag": 1.0, "int8": 0.5}[plan.grad_comm]
                rs = shard_bytes * (fsdp - 1) * grad_scale
                out["zero3"] = (2 * ag + rs) * n_mb
            else:
                # pure DP gradient all-reduce over dp
                wire = 2 * p_tp_bytes * (dp - 1) / dp
                wire *= {"fp32": 2.0, "rs_ag": 1.0, "int8": 0.25}[plan.grad_comm]
                out["grad_allreduce"] = wire
        elif plan.param_strategy == "tp2d" and fsdp > 1:
            # inference weight gather-on-use over the data axis
            out["weight_gather"] = p_tp_bytes / fsdp * (fsdp - 1)
        # --- TP activation collectives (per layer pair of matmuls) ---
        if tp > 1:
            act = tokens_local * cfg.d_model * BF16
            n_attn, n_mamba, n_dense, n_moe = ctx.layer_counts()
            n_ar = 0
            if plan.mixer_tp:
                n_ar += n_attn + n_mamba
            if plan.ffn_tp:
                n_ar += n_dense
            if plan.moe_mode == "tp":
                n_ar += n_moe
            n_ar *= cfg.n_periods
            wire_one = 2 * act * (tp - 1) / tp  # ring AR
            if plan.seq_shard:
                wire_one *= 0.5  # RS+AG replaces AR: half the wire bytes
            coll = n_ar * wire_one
            if train:
                coll *= 3  # fwd + both bwd directions
            out["tp_act"] = coll
            if plan.vocab_shard:
                lg = tokens_local * cfg.d_model * BF16
                out["vocab"] = 2 * lg * (tp - 1) / tp * (3 if train else 1)
        # --- MoE all-to-all ---
        if cfg.is_moe and plan.moe_mode == "ep" and tp > 1:
            ep = min(tp, cfg.n_experts)
            a2a = tokens_local * cfg.experts_per_token * 1.25 * cfg.d_model * BF16
            wire = 2 * a2a * (ep - 1) / ep  # dispatch + combine
            out["moe_a2a"] = wire * (3 if train else 1)
        total = sum(out.values())
        return total, out

    # ------------------------------------------------------------------
    def _ctx(self) -> _EvalContext:
        ctx = self._batch_ctx
        if ctx is None:
            ctx = self._batch_ctx = _EvalContext(self)
        return ctx

    def terms(
        self, plan: SchedulePlan, _ctx: Optional[_EvalContext] = None
    ) -> RooflineTerms:
        """Roofline terms for one plan.

        Columnar mode (the default) prices through the same kernel
        dispatch as ``cost_batch`` — a batch of one lands below
        ``columnar_min_batch``, so it runs the certified scalar replay
        over the shared persistent context (force ``columnar_min_batch=1``
        to exercise the column kernel itself).  ``columnar=False`` (or an
        explicit ``_ctx``, the pre-columnar batch protocol) replays the
        per-plan scalar arithmetic with a fresh context, exactly as before
        the refactor; values are bit-identical every way.
        """
        self.n_evals += 1
        if _ctx is not None or not self.columnar:
            return self._terms_scalar(plan, _ctx)
        if self.columnar_min_batch <= 1:
            cols = PlanColumns.from_plans([plan])
            return self._assemble_terms(
                self._terms_columnar(cols, self._ctx()), 0
            )
        return self._terms_scalar(plan, self._ctx())

    def _terms_scalar(
        self, plan: SchedulePlan, _ctx: Optional[_EvalContext] = None
    ) -> RooflineTerms:
        """The pre-columnar per-plan arithmetic — kept verbatim as the
        oracle ``_terms_columnar`` is certified against.  Scalar calls
        build a fresh ``_EvalContext``; the (pre-columnar) batch path
        passes its persistent context so plan-independent accounting
        amortizes — bit-identical either way (see ``_EvalContext``)."""
        ctx = _ctx if _ctx is not None else _EvalContext(self)
        cfg, shape, hw = self.cfg, self.shape, self.hw
        chips = self.mesh.size
        dp, tp, fsdp, tp_on = self._sizes(plan)
        train = shape.kind == "train"
        n_mb = max(plan.microbatches, 1)

        # ---- compute ----
        fwd = ctx.fwd_flops()
        if train:
            remat_mult = {"none": 3.0, "dots": 3.35, "full": 4.0}[plan.remat]
            flops = fwd * remat_mult + 10.0 * ctx.param_count()
        else:
            flops = fwd
        # kernel-tile efficiency: MXU alignment + grid overhead
        bq, bkv = plan.attn_block
        eff = (bq / (bq + 64.0)) * (bkv / (bkv + 64.0)) / (512.0 / 576.0) ** 2
        eff = min(eff, 1.0)
        if cfg.n_heads:
            if ctx.vmem_spills(bq, bkv):
                eff *= 0.5
        mb_eff = 1.0 - 0.015 * math.log2(n_mb) if n_mb > 1 else 1.0
        overlap_tax = 1.05 if plan.overlap >= 0.9 else 1.0
        compute_s = flops / (chips * hw.peak_flops) / (eff * mb_eff) * overlap_tax
        if cfg.is_ssm:
            # sequential scan: chunk too small -> grid overhead, too large -> VMEM
            chunk = plan.scan_chunk
            grid_steps = (shape.tokens / max(dp, 1)) / chunk * (cfg.d_inner / 256.0)
            compute_s += grid_steps * 0.3e-6 / max(chips / dp, 1)

        # ---- memory (HBM traffic, accounted per chip) ----
        p_tp_mem = self._sharded_param_bytes(plan, tp, ctx)
        # each chip streams its (TP-sharded, ZeRO-gathered) weights per
        # microbatch pass; fwd + bwd for training
        weight_reads = p_tp_mem * n_mb * (2 if train else 1)
        opt_traffic = 0.0
        if train:
            sbytes = self._state_bytes_per_param(plan)
            params_per_chip = p_tp_mem / BF16 / fsdp
            opt_traffic = params_per_chip * (2 * sbytes + 4)  # rw states + grad
        act_traffic = (
            shape.tokens / min(dp, max(shape.global_batch, 1))
            * cfg.d_model * BF16 * cfg.n_layers
            * (6 if train else 3)
        )
        if train and plan.remat != "none":
            act_traffic *= 1.35  # recompute re-streams activations
        kv_traffic = self._kv_cache_bytes_per_chip(plan, dp, tp, ctx)
        per_chip_traffic = weight_reads + opt_traffic + act_traffic + kv_traffic
        hbm_bytes = per_chip_traffic * chips
        memory_s = per_chip_traffic / hw.hbm_bw

        # ---- collectives ----
        coll_per_chip, coll_parts = self._collective_bytes_per_chip(
            plan, dp, tp, fsdp, ctx
        )
        link = hw.link_bw
        if self.mesh.multi_pod and plan.batch_axes == "pod_data":
            # DP collectives cross the pod boundary at lower bandwidth
            pod_frac = coll_parts.get("grad_allreduce", 0) + coll_parts.get("zero3", 0)
            link_eff = (
                (coll_per_chip - pod_frac) / max(coll_per_chip, 1e-9) * hw.link_bw
                + pod_frac / max(coll_per_chip, 1e-9) * hw.pod_link_bw
            )
            link = max(link_eff, hw.pod_link_bw)
        collective_s = coll_per_chip / link

        # ---- capacity ----
        p_tp = self._sharded_param_bytes(plan, tp, ctx)
        params_per_chip = p_tp / BF16 / fsdp
        resident = params_per_chip * (
            self._state_bytes_per_param(plan) if train else BF16
        )
        per_chip = (
            resident
            + self._activation_bytes_resident(plan, dp, tp, ctx)
            + self._kv_cache_bytes_per_chip(plan, dp, tp, ctx)
        )
        feasible = per_chip <= hw.hbm_bytes * 0.92  # fragmentation headroom

        step_s = max(compute_s, memory_s) + (1.0 - plan.overlap) * collective_s
        if not feasible:
            step_s *= 100.0 * (1.0 + per_chip / hw.hbm_bytes)

        n_active = cfg.active_param_count()
        model_flops = 6.0 * n_active * shape.tokens if train else 2.0 * n_active * shape.tokens
        details = dict(coll_parts)
        details["eff"] = eff
        details["mfu"] = model_flops / (step_s * chips * hw.peak_flops)
        return RooflineTerms(
            compute_s=compute_s,
            memory_s=memory_s,
            collective_s=collective_s,
            step_s=step_s,
            flops=flops,
            hbm_bytes=hbm_bytes,
            coll_bytes_per_chip=coll_per_chip,
            hbm_per_chip=per_chip,
            feasible=feasible,
            model_flops=model_flops,
            details=details,
        )

    # ------------------------------------------------------------------
    # The columnar kernel
    # ------------------------------------------------------------------
    def _terms_columnar(self, cols: PlanColumns, ctx: _EvalContext) -> dict:
        """Every roofline term for a whole encoded batch, as numpy column
        math — the single pricing kernel behind ``cost``, ``cost_batch``
        and ``cost_columns``.

        Bit-identity with ``_terms_scalar`` is engineered, not hoped for:
        every column expression performs the scalar path's IEEE-754
        operations on the same operands in the same association order
        (elementwise float64 ops are correctly rounded, so ``a op b`` is
        the same double either way); branch-dependent constants are
        gathered per discrete key with the values the scalar dict lookups
        produce; and parts a plan's branches skip contribute exact ``0.0``
        addends (``x + 0.0 == x`` for the non-negative quantities summed
        here).  The differential grid and the hypothesis properties
        assert the resulting equality on every value."""
        cfg, shape, hw, mesh = self.cfg, self.shape, self.hw, self.mesh
        n = cols.n
        train = shape.kind == "train"
        decode = shape.kind == "decode"
        chips = mesh.size
        gbm = max(shape.global_batch, 1)

        # ---- mesh sizes (ints, exact in float64) ----
        dp = np.full(n, mesh.axis("data"), dtype=np.int64)
        if mesh.multi_pod:
            dp = np.where(cols.pod_data, dp * mesh.axis("pod"), dp)
        tp = np.where(cols.tp_on, mesh.axis("model"), 1)
        fsdp = np.where(cols.fsdp_on, dp, 1)
        n_mb = np.maximum(cols.microbatches, 1)
        dp_eff = np.minimum(dp, gbm)

        # ---- compute ----
        fwd = ctx.fwd_flops()
        if train:
            flops = fwd * _REMAT_MULT[cols.remat] + 10.0 * ctx.param_count()
        else:
            flops = np.full(n, float(fwd))
        k_tile = (512.0 / 576.0) ** 2
        eff = (cols.bq / (cols.bq + 64.0)) * (cols.bkv / (cols.bkv + 64.0)) / k_tile
        eff = np.minimum(eff, 1.0)
        if cfg.n_heads:
            pairs = set(zip(cols.bq.tolist(), cols.bkv.tolist()))
            if len(pairs) == 1:
                if ctx.vmem_spills(*next(iter(pairs))):
                    eff = eff * 0.5
            else:
                spill = np.zeros(n, dtype=bool)
                for q, k in pairs:
                    spill[(cols.bq == q) & (cols.bkv == k)] = ctx.vmem_spills(
                        q, k
                    )
                eff = np.where(spill, eff * 0.5, eff)
        mb_eff = np.where(n_mb > 1, 1.0 - 0.015 * np.log2(n_mb), 1.0)
        tax = np.where(cols.overlap >= 0.9, 1.05, 1.0)
        compute_s = flops / (chips * hw.peak_flops) / (eff * mb_eff) * tax
        if cfg.is_ssm:
            grid_steps = (
                shape.tokens / np.maximum(dp, 1) / cols.scan_chunk
                * (cfg.d_inner / 256.0)
            )
            compute_s = compute_s + grid_steps * 0.3e-6 / np.maximum(chips / dp, 1)

        # ---- sharded parameter bytes (shared by memory/collectives/capacity)
        g = ctx.param_groups()
        tp_gt1 = tp > 1
        tot = g["mixer"] / np.where(cols.mixer_tp & tp_gt1, tp, 1)
        tot = tot + g["ffn"] / np.where(cols.ffn_tp & tp_gt1, tp, 1)
        if g["moe"]:
            moe_div = np.where(
                cols.moe_ep & tp_gt1, np.minimum(tp, cfg.n_experts),
                np.where(cols.moe_tp & tp_gt1, tp, 1),
            )
            tot = tot + g["moe"] / moe_div
        vs_ok = cfg.vocab_size % mesh.axis("model") == 0  # tp>1 => tp==model ax
        vshard = np.where(cols.vocab_shard & tp_gt1 & vs_ok, tp, 1)
        tot = tot + g["vocab"] / vshard
        tot = tot + g["other"]
        p_tp = tot * BF16

        # ---- memory (HBM traffic, accounted per chip) ----
        weight_reads = p_tp * n_mb * (2 if train else 1)
        ppc = p_tp / BF16 / fsdp  # params per chip (post-FSDP)
        if train:
            sbytes = np.where(cols.opt_int8, _SBYTES_INT8, _SBYTES_F32)
            opt_traffic = ppc * (2 * sbytes + 4)
        else:
            opt_traffic = 0.0
        tl = shape.tokens / dp_eff  # tokens per (batch-limited) data shard
        act_traffic = tl * cfg.d_model * BF16 * cfg.n_layers * (6 if train else 3)
        if train:
            act_traffic = np.where(cols.remat != 0, act_traffic * 1.35, act_traffic)
        if decode:
            kvt = np.empty(n)
            if bool(cols.kv_int8.any()):
                kvt[cols.kv_int8] = ctx.kv_total(1.06)
            if not bool(cols.kv_int8.all()):
                kvt[~cols.kv_int8] = ctx.kv_total(BF16)
            kvt = kvt * ctx.n_periods()
            shard = dp_eff
            seq_mult = (dp // dp_eff) * np.where(~cols.mixer_tp, tp, 1)
            shard = np.where(cols.seq_shard, shard * seq_mult, shard)
            kv_heads = np.minimum(tp, max(cfg.n_kv_heads, 1))
            shard = np.where(cols.mixer_tp & cols.tp_on, shard * kv_heads, shard)
            kv_col = kvt / shard
        else:
            kv_col = 0.0
        per_chip_traffic = weight_reads + opt_traffic + act_traffic + kv_col
        hbm_bytes = per_chip_traffic * chips
        memory_s = per_chip_traffic / hw.hbm_bw

        # ---- collectives ----
        parts = []
        if train:
            shard_bytes = p_tp / fsdp
            ag = shard_bytes * (fsdp - 1)
            rs = ag * _GRAD_SCALE_ZERO3[cols.grad_comm]
            zero3 = (2 * ag + rs) * n_mb
            grad_ar = 2 * p_tp * (dp - 1) / dp * _GRAD_SCALE_AR[cols.grad_comm]
            fsdp_on = fsdp > 1
            param_part = np.where(fsdp_on, zero3, grad_ar)
            pod_part = param_part  # the DP collectives that cross pods
            parts.append(("zero3", fsdp_on, zero3))
            parts.append(("grad_allreduce", ~fsdp_on, grad_ar))
        else:
            wg_mask = cols.tp2d & (fsdp > 1)
            wg = p_tp / fsdp * (fsdp - 1)
            param_part = np.where(wg_mask, wg, 0.0)
            pod_part = np.zeros(n)
            parts.append(("weight_gather", wg_mask, wg))
        act = tl * cfg.d_model * BF16
        n_attn, n_mamba, n_dense, n_moe = ctx.layer_counts()
        n_ar = (
            np.where(cols.mixer_tp, n_attn + n_mamba, 0)
            + np.where(cols.ffn_tp, n_dense, 0)
            + np.where(cols.moe_tp, n_moe, 0)
        ) * ctx.n_periods()
        wire_one = 2 * act * (tp - 1) / tp
        wire_one = np.where(cols.seq_shard, wire_one * 0.5, wire_one)
        tp_act = n_ar * wire_one
        if train:
            tp_act = tp_act * 3
        tp_act = np.where(tp_gt1, tp_act, 0.0)
        parts.append(("tp_act", tp_gt1, tp_act))
        vocab_part = 2 * act * (tp - 1) / tp * (3 if train else 1)
        vocab_mask = tp_gt1 & cols.vocab_shard
        vocab_part = np.where(vocab_mask, vocab_part, 0.0)
        parts.append(("vocab", vocab_mask, vocab_part))
        if cfg.is_moe:
            ep = np.minimum(tp, cfg.n_experts)
            a2a = tl * cfg.experts_per_token * 1.25 * cfg.d_model * BF16
            moe_part = 2 * a2a * (ep - 1) / ep * (3 if train else 1)
            moe_mask = cols.moe_ep & tp_gt1
            moe_part = np.where(moe_mask, moe_part, 0.0)
            parts.append(("moe_a2a", moe_mask, moe_part))
            coll = param_part + tp_act + vocab_part + moe_part
        else:
            coll = param_part + tp_act + vocab_part
        if mesh.multi_pod:
            denom = np.maximum(coll, 1e-9)
            link_eff = (
                (coll - pod_part) / denom * hw.link_bw
                + pod_part / denom * hw.pod_link_bw
            )
            link = np.where(
                cols.pod_data, np.maximum(link_eff, hw.pod_link_bw), hw.link_bw
            )
        else:
            link = hw.link_bw
        collective_s = coll / link

        # ---- capacity ----
        resident = ppc * (sbytes if train else BF16)
        if train:
            tl2 = shape.tokens / dp / n_mb
            tp_vals = set(tp.tolist())
            if len(tp_vals) == 1:
                f_mult, m_mult = ctx.act_mults(next(iter(tp_vals)))
                fm = np.full(n, f_mult)
                mm = np.full(n, m_mult)
            else:
                fm = np.empty(n)
                mm = np.empty(n)
                for v in tp_vals:
                    f_mult, m_mult = ctx.act_mults(v)
                    mask = tp == v
                    fm[mask] = f_mult
                    mm[mask] = m_mult
            d = cfg.d_model
            stored_mult = np.where(
                cols.remat == 2, float(d),
                np.where(cols.remat == 1, d * 4 + mm * 0.5 + fm * 0.5,
                         d * 6 + mm + fm),
            )
            stored = tl2 * stored_mult * ctx.n_periods()
            logits = tl2 * cfg.vocab_size / np.where(cols.vocab_shard, tp, 1)
            logits = np.where(cols.remat == 0, logits, 0.0)
            act_res = stored * BF16 + logits * BF16
        else:
            act_res = 0.0
        per_chip = resident + act_res + kv_col
        feasible = per_chip <= hw.hbm_bytes * 0.92

        step_s = np.maximum(compute_s, memory_s) + (1.0 - cols.overlap) * collective_s
        step_s = np.where(
            feasible, step_s, step_s * (100.0 * (1.0 + per_chip / hw.hbm_bytes))
        )

        n_active = ctx.active_param_count()
        model_flops = (
            6.0 * n_active * shape.tokens if train
            else 2.0 * n_active * shape.tokens
        )
        mfu = model_flops / (step_s * chips * hw.peak_flops)
        return {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "step_s": step_s,
            "flops": flops,
            "hbm_bytes": hbm_bytes,
            "coll_bytes_per_chip": coll + np.zeros(n),
            "hbm_per_chip": per_chip,
            "feasible": feasible,
            "model_flops": model_flops,
            "eff": eff,
            "mfu": mfu,
            "parts": parts,
        }

    def _assemble_terms(self, out: dict, i: int) -> RooflineTerms:
        """One plan's ``RooflineTerms`` from the kernel's column output —
        the same fields (and the same ``details`` keys, in the same
        insertion order) the scalar path produces."""
        details = {
            name: float(vals[i])
            for name, mask, vals in out["parts"] if mask[i]
        }
        details["eff"] = float(out["eff"][i])
        details["mfu"] = float(out["mfu"][i])
        return RooflineTerms(
            compute_s=float(out["compute_s"][i]),
            memory_s=float(out["memory_s"][i]),
            collective_s=float(out["collective_s"][i]),
            step_s=float(out["step_s"][i]),
            flops=float(out["flops"][i]),
            hbm_bytes=float(out["hbm_bytes"][i]),
            coll_bytes_per_chip=float(out["coll_bytes_per_chip"][i]),
            hbm_per_chip=float(out["hbm_per_chip"][i]),
            feasible=bool(out["feasible"][i]),
            model_flops=float(out["model_flops"]),
            details=details,
        )

    # ------------------------------------------------------------------
    # The jitted kernel (pricing="jit")
    # ------------------------------------------------------------------
    def _step_batch(self, cols: PlanColumns) -> np.ndarray:
        """``step_s`` for an encoded batch through the selected kernel —
        the one dispatch ``cost``/``cost_batch``/``cost_columns`` share, so
        the scalar and batched signals cannot drift within a pricing
        path."""
        if self.pricing == "jit":
            return self._terms_jitted(cols, self._ctx())
        return self._terms_columnar(cols, self._ctx())["step_s"]

    def _terms_jitted(self, cols: PlanColumns, ctx: _EvalContext) -> np.ndarray:
        """``step_s`` for a whole encoded batch via the jax-jitted kernel.

        The discrete, plan-keyed lookups the columnar kernel resolves
        through ``_EvalContext`` (VMEM spill per flash-block pair,
        activation multipliers per TP degree, KV totals per dtype) are
        gathered host-side into plain numeric columns; everything else is
        one jitted elementwise float64 program over columns padded to the
        next power of two (bounded compile cache) and sliced back to
        ``n``.  Agreement with ``_terms_columnar``: within ``JIT_RTOL``
        (see module notes on the tolerance contract and pricing tag)."""
        jax, _, enable_x64 = _jax_mods()
        fn = self._jit_fn
        if fn is None:
            fn = self._jit_fn = _build_jit_kernel(self, ctx)
        inp = self._jit_inputs(cols, ctx, _pad_pow2(cols.n))
        with enable_x64():
            out = fn(**inp)
        return np.asarray(out)[: cols.n]

    def _jit_inputs(
        self, cols: PlanColumns, ctx: _EvalContext, pad: int
    ) -> Dict[str, np.ndarray]:
        """Host-side gather + pad: the same per-discrete-key context
        lookups ``_terms_columnar`` performs, emitted as numeric columns
        the jitted program can consume."""
        cfg, shape = self.cfg, self.shape
        n = cols.n
        # VMEM spill per distinct (bq, bkv) pair — same gather as columnar
        spill = np.zeros(n, dtype=bool)
        if cfg.n_heads:
            for q, k in set(zip(cols.bq.tolist(), cols.bkv.tolist())):
                spill[(cols.bq == q) & (cols.bkv == k)] = ctx.vmem_spills(q, k)
        # stored-activation multipliers per distinct TP degree (train only)
        fm = np.zeros(n)
        mm = np.zeros(n)
        if shape.kind == "train":
            tp = np.where(cols.tp_on, self.mesh.axis("model"), 1)
            for v in set(tp.tolist()):
                f_mult, m_mult = ctx.act_mults(int(v))
                fm[tp == v] = f_mult
                mm[tp == v] = m_mult
        # whole-model KV bytes per dtype, before the n_periods multiply
        kvt = np.zeros(n)
        if shape.kind == "decode":
            if bool(cols.kv_int8.any()):
                kvt[cols.kv_int8] = ctx.kv_total(1.06)
            if not bool(cols.kv_int8.all()):
                kvt[~cols.kv_int8] = ctx.kv_total(BF16)
        inp = {
            "pod_data": cols.pod_data, "tp_on": cols.tp_on,
            "fsdp_on": cols.fsdp_on, "tp2d": cols.tp2d,
            "mixer_tp": cols.mixer_tp, "seq_shard": cols.seq_shard,
            "ffn_tp": cols.ffn_tp, "moe_ep": cols.moe_ep,
            "moe_tp": cols.moe_tp, "vocab_shard": cols.vocab_shard,
            "opt_int8": cols.opt_int8, "remat": cols.remat,
            "grad_comm": cols.grad_comm, "microbatches": cols.microbatches,
            "bq": cols.bq, "bkv": cols.bkv, "scan_chunk": cols.scan_chunk,
            "overlap": cols.overlap, "spill": spill, "fm": fm, "mm": mm,
            "kvt": kvt,
        }
        return {k: _pad_edge(v, pad) for k, v in inp.items()}

    # ------------------------------------------------------------------
    def cost(self, plan: SchedulePlan) -> float:
        """Scalar cost (estimated step seconds, with infeasibility penalty).
        Columnar/jit modes route through the same dispatch as
        ``cost_batch`` (a batch of one), so the scalar and batched signals
        cannot drift."""
        if self.columnar:
            self.n_evals += 1
            if self.columnar_min_batch <= 1:
                cols = PlanColumns.from_plans([plan])
                return float(self._step_batch(cols)[0])
            return self._terms_scalar(plan, self._ctx()).step_s
        return self.terms(plan).step_s

    def cost_batch(self, plans) -> List[float]:
        """Batched pricing: ``cost_batch(plans) == [cost(p) for p in plans]``,
        element-for-element and bit-for-bit.

        Columnar mode encodes the unique plans once (``PlanColumns``) and
        prices the whole batch in one vectorized kernel pass
        (``_terms_columnar``); batches smaller than ``columnar_min_batch``
        dispatch to the certified-identical scalar replay instead (column
        dispatch overhead dominates there — see ``__init__``).  Duplicate
        plans inside the batch — common when concurrent MCTS rollouts
        collide on a schedule — are priced once (``n_evals`` counts each
        *unique* evaluation once; values are unaffected).

        ``columnar=False`` replays the pre-columnar protocol: the scalar
        arithmetic per unique plan, with the plan-independent accounting
        amortized through one persistent ``_EvalContext``."""
        if not plans:
            return []
        if self.columnar:
            index: Dict[SchedulePlan, int] = {}
            uniq: List[SchedulePlan] = []
            for p in plans:
                if p not in index:
                    index[p] = len(uniq)
                    uniq.append(p)
            if len(uniq) >= self.columnar_min_batch:
                step = self.cost_columns(PlanColumns.from_plans(uniq))
            else:  # below the kernel crossover: skip the encode entirely
                self.n_evals += len(uniq)
                ctx = self._ctx()
                step = [self._terms_scalar(p, ctx).step_s for p in uniq]
            if len(uniq) == len(plans):
                return step
            return [step[index[p]] for p in plans]
        ctx = self._batch_ctx
        if ctx is None:
            ctx = self._batch_ctx = _EvalContext(self)
        out: List[float] = []
        memo: Dict[SchedulePlan, float] = {}
        for plan in plans:
            c = memo.get(plan)
            if c is None:
                c = memo[plan] = self.terms(plan, ctx).step_s
            out.append(c)
        return out

    def cost_columns(self, cols: PlanColumns) -> List[float]:
        """Price an already-encoded batch — the seam the serving layer
        uses so one ``PlanColumns`` encode feeds either the learned MLP or
        this kernel.  No dedup here: callers hand deduplicated miss
        batches (``CachedMDP``); every column is one evaluation."""
        if not self.columnar:  # oracle mode: the pre-columnar replay
            return self.cost_batch(cols.plans)
        self.n_evals += cols.n
        if cols.n < self.columnar_min_batch:
            ctx = self._ctx()
            return [self._terms_scalar(p, ctx).step_s for p in cols.plans]
        return [float(v) for v in self._step_batch(cols)]

    def partial_cost(self, actions, space: ScheduleSpace) -> float:
        """The (unreliable) cost of an INCOMPLETE schedule: complete the
        remaining stages with defaults (memoized per space) and evaluate —
        this is exactly what beam search must do at every depth, and what
        the paper shows is misleading (Fig. 1/2)."""
        defaults = space.default_actions()
        full = list(actions) + defaults[len(actions):]
        return self.cost(space.plan_from_actions(full))


def _build_jit_kernel(model: AnalyticCostModel, ctx: _EvalContext):
    """Compile-ready jitted ``step_s`` kernel for one (cfg, shape, mesh, hw)
    cell.

    Every cell-constant quantity — structural FLOP/param accounting, mesh
    axes, hardware numbers, kind flags — is resolved here (through the same
    ``_EvalContext`` the columnar kernel uses) and closed over as Python
    scalars, so the traced program is pure elementwise column math: the
    ``_terms_columnar`` arithmetic, operation for operation, on float64
    (traced and executed under ``enable_x64``).  Only ``step_s`` is
    computed — the jitted path prices searches; full term breakdowns stay
    on the exact kernels."""
    jax, jnp, enable_x64 = _jax_mods()
    cfg, shape, hw, mesh = model.cfg, model.shape, model.hw, model.mesh
    train = shape.kind == "train"
    decode = shape.kind == "decode"
    chips = mesh.size
    gbm = max(shape.global_batch, 1)
    mesh_data = mesh.axis("data")
    mesh_model = mesh.axis("model")
    multi_pod = mesh.multi_pod
    mesh_pod = mesh.axis("pod") if multi_pod else 1
    fwd = ctx.fwd_flops()
    param_count = ctx.param_count()
    g = dict(ctx.param_groups())
    n_attn, n_mamba, n_dense, n_moe = ctx.layer_counts()
    n_periods = ctx.n_periods()
    vs_ok = cfg.vocab_size % mesh_model == 0
    n_kv_heads = max(cfg.n_kv_heads, 1)
    has_heads = bool(cfg.n_heads)
    is_ssm, is_moe = cfg.is_ssm, cfg.is_moe
    tokens = shape.tokens
    d_model, d_inner = cfg.d_model, cfg.d_inner
    n_layers, vocab_size = cfg.n_layers, cfg.vocab_size
    n_experts, ept = cfg.n_experts, cfg.experts_per_token
    k_tile = (512.0 / 576.0) ** 2
    remat_mult = tuple(float(x) for x in _REMAT_MULT)
    gs_zero3 = tuple(float(x) for x in _GRAD_SCALE_ZERO3)
    gs_ar = tuple(float(x) for x in _GRAD_SCALE_AR)

    def kernel(pod_data, tp_on, fsdp_on, tp2d, mixer_tp, seq_shard, ffn_tp,
               moe_ep, moe_tp, vocab_shard, opt_int8, remat, grad_comm,
               microbatches, bq, bkv, scan_chunk, overlap, spill, fm, mm,
               kvt):
        # ---- mesh sizes (ints, exact in float64) ----
        dp = jnp.full(remat.shape, mesh_data, dtype=remat.dtype)
        if multi_pod:
            dp = jnp.where(pod_data, dp * mesh_pod, dp)
        tp = jnp.where(tp_on, mesh_model, 1)
        fsdp = jnp.where(fsdp_on, dp, 1)
        n_mb = jnp.maximum(microbatches, 1)
        dp_eff = jnp.minimum(dp, gbm)

        # ---- compute ----
        if train:
            flops = fwd * jnp.asarray(remat_mult)[remat] + 10.0 * param_count
        else:
            flops = jnp.full(remat.shape, float(fwd))
        eff = (bq / (bq + 64.0)) * (bkv / (bkv + 64.0)) / k_tile
        eff = jnp.minimum(eff, 1.0)
        if has_heads:
            eff = jnp.where(spill, eff * 0.5, eff)
        mb_eff = jnp.where(n_mb > 1, 1.0 - 0.015 * jnp.log2(n_mb), 1.0)
        tax = jnp.where(overlap >= 0.9, 1.05, 1.0)
        compute_s = flops / (chips * hw.peak_flops) / (eff * mb_eff) * tax
        if is_ssm:
            grid_steps = (
                tokens / jnp.maximum(dp, 1) / scan_chunk * (d_inner / 256.0)
            )
            compute_s = compute_s + grid_steps * 0.3e-6 / jnp.maximum(
                chips / dp, 1
            )

        # ---- sharded parameter bytes ----
        tp_gt1 = tp > 1
        tot = g["mixer"] / jnp.where(mixer_tp & tp_gt1, tp, 1)
        tot = tot + g["ffn"] / jnp.where(ffn_tp & tp_gt1, tp, 1)
        if g["moe"]:
            moe_div = jnp.where(
                moe_ep & tp_gt1, jnp.minimum(tp, n_experts),
                jnp.where(moe_tp & tp_gt1, tp, 1),
            )
            tot = tot + g["moe"] / moe_div
        vs_mask = (vocab_shard & tp_gt1) if vs_ok else jnp.zeros_like(tp_gt1)
        tot = tot + g["vocab"] / jnp.where(vs_mask, tp, 1)
        tot = tot + g["other"]
        p_tp = tot * BF16

        # ---- memory (HBM traffic, accounted per chip) ----
        weight_reads = p_tp * n_mb * (2 if train else 1)
        ppc = p_tp / BF16 / fsdp
        if train:
            sbytes = jnp.where(opt_int8, _SBYTES_INT8, _SBYTES_F32)
            opt_traffic = ppc * (2 * sbytes + 4)
        else:
            opt_traffic = 0.0
        tl = tokens / dp_eff
        act_traffic = tl * d_model * BF16 * n_layers * (6 if train else 3)
        if train:
            act_traffic = jnp.where(remat != 0, act_traffic * 1.35, act_traffic)
        if decode:
            kvt_full = kvt * n_periods
            shard = dp_eff
            seq_mult = (dp // dp_eff) * jnp.where(~mixer_tp, tp, 1)
            shard = jnp.where(seq_shard, shard * seq_mult, shard)
            kv_heads = jnp.minimum(tp, n_kv_heads)
            shard = jnp.where(mixer_tp & tp_on, shard * kv_heads, shard)
            kv_col = kvt_full / shard
        else:
            kv_col = 0.0
        per_chip_traffic = weight_reads + opt_traffic + act_traffic + kv_col
        memory_s = per_chip_traffic / hw.hbm_bw

        # ---- collectives ----
        if train:
            shard_bytes = p_tp / fsdp
            ag = shard_bytes * (fsdp - 1)
            rs = ag * jnp.asarray(gs_zero3)[grad_comm]
            zero3 = (2 * ag + rs) * n_mb
            grad_ar = 2 * p_tp * (dp - 1) / dp * jnp.asarray(gs_ar)[grad_comm]
            param_part = jnp.where(fsdp > 1, zero3, grad_ar)
            pod_part = param_part
        else:
            wg_mask = tp2d & (fsdp > 1)
            wg = p_tp / fsdp * (fsdp - 1)
            param_part = jnp.where(wg_mask, wg, 0.0)
            pod_part = jnp.zeros_like(param_part)
        act = tl * d_model * BF16
        n_ar = (
            jnp.where(mixer_tp, n_attn + n_mamba, 0)
            + jnp.where(ffn_tp, n_dense, 0)
            + jnp.where(moe_tp, n_moe, 0)
        ) * n_periods
        wire_one = 2 * act * (tp - 1) / tp
        wire_one = jnp.where(seq_shard, wire_one * 0.5, wire_one)
        tp_act = n_ar * wire_one
        if train:
            tp_act = tp_act * 3
        tp_act = jnp.where(tp_gt1, tp_act, 0.0)
        vocab_part = 2 * act * (tp - 1) / tp * (3 if train else 1)
        vocab_part = jnp.where(tp_gt1 & vocab_shard, vocab_part, 0.0)
        coll = param_part + tp_act + vocab_part
        if is_moe:
            ep = jnp.minimum(tp, n_experts)
            a2a = tl * ept * 1.25 * d_model * BF16
            moe_part = 2 * a2a * (ep - 1) / ep * (3 if train else 1)
            coll = coll + jnp.where(moe_ep & tp_gt1, moe_part, 0.0)
        if multi_pod:
            denom = jnp.maximum(coll, 1e-9)
            link_eff = (
                (coll - pod_part) / denom * hw.link_bw
                + pod_part / denom * hw.pod_link_bw
            )
            link = jnp.where(
                pod_data, jnp.maximum(link_eff, hw.pod_link_bw), hw.link_bw
            )
        else:
            link = hw.link_bw
        collective_s = coll / link

        # ---- capacity ----
        resident = ppc * (sbytes if train else BF16)
        if train:
            tl2 = tokens / dp / n_mb
            stored_mult = jnp.where(
                remat == 2, float(d_model),
                jnp.where(remat == 1, d_model * 4 + mm * 0.5 + fm * 0.5,
                          d_model * 6 + mm + fm),
            )
            stored = tl2 * stored_mult * n_periods
            logits = tl2 * vocab_size / jnp.where(vocab_shard, tp, 1)
            logits = jnp.where(remat == 0, logits, 0.0)
            act_res = stored * BF16 + logits * BF16
        else:
            act_res = 0.0
        per_chip = resident + act_res + kv_col
        feasible = per_chip <= hw.hbm_bytes * 0.92

        step_s = jnp.maximum(compute_s, memory_s) + (1.0 - overlap) * collective_s
        return jnp.where(
            feasible, step_s,
            step_s * (100.0 * (1.0 + per_chip / hw.hbm_bytes)),
        )

    return jax.jit(kernel)
