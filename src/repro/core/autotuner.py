"""Top-level ProTuner API: ``autotune(arch, shape, algo, ...)``.

Algorithms (paper §5 protocol):
  mcts_*    — ProTuner ensemble (15 standard + 1 greedy MCTS), Table-1 variants
  beam      — beam search, size 32, 5 passes (Adams et al. baseline)
  greedy    — beam size 1
  random    — random search (no cost model)

``measure=True`` adds real measurement (subprocess XLA compile) at every
root synchronization — the ``mcts_cost+real_*`` configurations.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Callable, Optional

from repro.configs import get_config, get_shape
from repro.core.beam import beam_search, greedy_search
from repro.core.cost_model import AnalyticCostModel
from repro.core.ensemble import ProTuner, TuneResult
from repro.core.mcts import MCTSConfig
from repro.core.mdp import ScheduleMDP
from repro.core.random_search import random_search
from repro.core.space import MULTI_POD, SINGLE_POD, ScheduleSpace


class NoisyCostModel:
    """Deterministic multiplicative log-normal noise on top of the analytic
    model — simulates a learned cost model's error (paper §3); per-plan noise
    is a pure hash so search remains reproducible."""

    def __init__(self, inner: AnalyticCostModel, sigma: float = 0.0, seed: int = 0):
        self.inner = inner
        self.sigma = sigma
        self.seed = seed

    @property
    def n_evals(self):
        return self.inner.n_evals

    def _noise(self, plan) -> float:
        if not self.sigma:
            return 1.0
        h = hashlib.blake2b(
            (str(self.seed) + repr(plan)).encode(), digest_size=8
        ).digest()
        u = int.from_bytes(h, "big") / 2**64
        # Box-Muller-ish deterministic gaussian
        import math as m

        z = m.sqrt(-2.0 * m.log(max(u, 1e-12))) * m.cos(
            2 * m.pi * ((int.from_bytes(h[:4], "big") / 2**32) or 0.5)
        )
        return m.exp(self.sigma * z)

    def cost(self, plan) -> float:
        return self.inner.cost(plan) * self._noise(plan)

    def partial_cost(self, actions, space) -> float:
        defaults = space.default_actions()
        full = list(actions) + defaults[len(actions):]
        return self.cost(space.plan_from_actions(full))

    def terms(self, plan):
        return self.inner.terms(plan)


def make_mdp(
    arch: str,
    shape_name: str,
    mesh: str = "single",
    noise_sigma: float = 0.0,
    noise_seed: int = 0,
) -> ScheduleMDP:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mspec = MULTI_POD if mesh == "multi" else SINGLE_POD
    space = ScheduleSpace(cfg, shape, mspec)
    cm = AnalyticCostModel(cfg, shape, mspec)
    if noise_sigma:
        cm = NoisyCostModel(cm, noise_sigma, noise_seed)
    return ScheduleMDP(space, cm)


# Table 1 configurations (time budgets scaled: the paper's 30s/10s/1s per
# decision assume a C++ cost model; ours exposes both iteration- and
# second-based budgets).
TABLE1 = {
    "mcts_30s": MCTSConfig(ucb="paper", iters_per_decision=384),
    "mcts_10s": MCTSConfig(ucb="paper", iters_per_decision=128),
    "mcts_1s": MCTSConfig(ucb="paper", iters_per_decision=16),
    "mcts_Cp10_30s": MCTSConfig(ucb="cp10", iters_per_decision=384),
    "mcts_sqrt2_30s": MCTSConfig(ucb="sqrt2", iters_per_decision=384),
    "mcts_cost+real_30s": MCTSConfig(ucb="paper", iters_per_decision=384),
    "mcts_cost+real_1s": MCTSConfig(ucb="paper", iters_per_decision=16),
    "mcts_binary_30s": MCTSConfig(
        ucb="paper", reward_mode="binary", iters_per_decision=384
    ),  # §4.1 0/1-reward ablation (paper: 9% worse)
}


def autotune(
    arch: str,
    shape_name: str,
    *,
    algo: str = "mcts_30s",
    mesh: str = "single",
    seed: int = 0,
    n_standard: int = 15,
    n_greedy: int = 1,
    measure_fn: Optional[Callable] = None,
    time_budget_s: Optional[float] = None,
    noise_sigma: float = 0.0,
    mdp: Optional[ScheduleMDP] = None,
) -> TuneResult:
    mdp = mdp or make_mdp(arch, shape_name, mesh, noise_sigma, seed)
    if algo == "beam":
        res = beam_search(mdp, beam_size=32, passes=5, seed=seed,
                          time_budget_s=time_budget_s)
    elif algo == "greedy":
        res = greedy_search(mdp, seed=seed, time_budget_s=time_budget_s)
    elif algo == "random":
        res = random_search(mdp, seed=seed, time_budget_s=time_budget_s,
                            measure_fn=measure_fn)
    elif algo in TABLE1 or algo == "mcts":
        mc = TABLE1.get(algo, TABLE1["mcts_30s"])
        mc = dataclasses.replace(mc, seed=seed)
        use_measure = measure_fn if "real" in algo else None
        tuner = ProTuner(
            mdp,
            n_standard=n_standard,
            n_greedy=n_greedy,
            mcts_config=mc,
            measure_fn=use_measure,
            seed=seed,
        )
        res = tuner.run(time_budget_s=time_budget_s)
        res.algo = algo
    else:
        raise ValueError(f"unknown algo {algo!r}")
    return res
