"""Top-level ProTuner API: ``autotune(arch, shape, algo, ...)``.

Algorithms (paper §5 protocol, plus the complete-plan portfolio):
  mcts_*    — ProTuner ensemble (15 standard + 1 greedy MCTS), Table-1 variants
  beam      — beam search, size 32, 5 passes (Adams et al. baseline)
  greedy    — beam size 1
  random    — random search (no cost model)
  evolve    — evolutionary search over complete plans (core/evolve.py);
              with a plan_store, seeded from the cell's recorded plans
  portfolio — race evolve/mcts/beam/random on one shared transposition
              cache and eval budget (core/evolve.py)

``measure=True`` adds real measurement (subprocess XLA compile) at every
root synchronization — the ``mcts_cost+real_*`` configurations.
"""
from __future__ import annotations

import hashlib
import math
from typing import Callable, Optional

from repro.configs import get_config, get_shape
from repro.core.cost_model import AnalyticCostModel
from repro.core.engine import ENGINES
from repro.core.engine.backend import TABLE1, SearchBackend, resolve_backend
from repro.core.ensemble import TuneResult
from repro.core.mdp import ScheduleMDP
from repro.core.space import MULTI_POD, SINGLE_POD, ScheduleSpace


class NoisyCostModel:
    """Deterministic multiplicative log-normal noise on top of the analytic
    model — simulates a learned cost model's error (paper §3); per-plan noise
    is a pure hash so search remains reproducible."""

    def __init__(self, inner: AnalyticCostModel, sigma: float = 0.0, seed: int = 0):
        self.inner = inner
        self.sigma = sigma
        self.seed = seed

    @property
    def n_evals(self):
        return self.inner.n_evals

    def _noise(self, plan) -> float:
        if not self.sigma:
            return 1.0
        # Box-Muller from two INDEPENDENT uniforms: disjoint halves of a
        # 16-byte digest (a single 8-byte digest reused for both radius and
        # angle correlates them and skews the distribution off log-normal)
        h = hashlib.blake2b(
            (str(self.seed) + repr(plan)).encode(), digest_size=16
        ).digest()
        u1 = int.from_bytes(h[:8], "big") / 2**64
        u2 = int.from_bytes(h[8:16], "big") / 2**64
        z = math.sqrt(-2.0 * math.log(max(u1, 1e-12))) * math.cos(2 * math.pi * u2)
        return math.exp(self.sigma * z)

    def cost(self, plan) -> float:
        return self.inner.cost(plan) * self._noise(plan)

    def cost_batch(self, plans) -> list:
        """Batched pricing: inner costs amortize through the analytic
        model's batch path, then the same deterministic per-plan noise is
        applied — ``cost_batch(plans) == [cost(p) for p in plans]``."""
        base = self.inner.cost_batch(plans)
        return [b * self._noise(p) for b, p in zip(base, plans)]

    def partial_cost(self, actions, space) -> float:
        defaults = space.default_actions()
        full = list(actions) + defaults[len(actions):]
        return self.cost(space.plan_from_actions(full))

    def terms(self, plan):
        return self.inner.terms(plan)


def make_mdp(
    arch: str,
    shape_name: str,
    mesh: str = "single",
    noise_sigma: float = 0.0,
    noise_seed: int = 0,
    pricing: Optional[str] = None,
) -> ScheduleMDP:
    """Build one cell's MDP.  ``pricing`` selects the analytic kernel:
    None/"columnar" (exact, default), "scalar" (the exact oracle replay),
    or "jit" (the jax-jitted kernel — JIT_RTOL tolerance contract and a
    versioned pricing tag; see cost_model.py)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mspec = MULTI_POD if mesh == "multi" else SINGLE_POD
    space = ScheduleSpace(cfg, shape, mspec)
    cm = AnalyticCostModel(cfg, shape, mspec, pricing=pricing)
    if noise_sigma:
        cm = NoisyCostModel(cm, noise_sigma, noise_seed)
    return ScheduleMDP(space, cm)


# TABLE1 lives in repro.core.engine.backend (imported above) — re-exported
# here for backward compatibility with existing callers/tests.


def autotune(
    arch: str,
    shape_name: str,
    *,
    algo: str = "mcts_30s",
    mesh: str = "single",
    seed: int = 0,
    n_standard: int = 15,
    n_greedy: int = 1,
    measure_fn: Optional[Callable] = None,
    measure_backend=None,
    time_budget_s: Optional[float] = None,
    noise_sigma: float = 0.0,
    mdp: Optional[ScheduleMDP] = None,
    engine: str = "array",
    parallel: bool = False,
    cache: Optional[bool] = None,
    batch: Optional[bool] = None,
    cost: str = "analytic",
    n_workers: Optional[int] = None,
    worker_pool=None,
    shm: Optional[bool] = None,
    worker_batch: Optional[bool] = None,
    plan_store=None,
    pricing: Optional[str] = None,
    controller=None,
    resume: Optional[dict] = None,
) -> TuneResult:
    """Tune one (arch × shape × mesh) cell.

    ``engine`` selects the MCTS tree representation — the default is the
    vectorized ``"array"`` engine with batched leaf evaluation and the
    shared transposition cache, certified bit-identical to the paper-
    faithful ``"reference"`` engine by ``tests/test_differential.py``;
    ``parallel`` runs ensemble trees across persistent pinned worker
    processes (``repro.core.engine.workers``; per-round deltas in both
    directions, payload bytes surfaced on ``TuneResult``, ``n_workers``
    caps the pool — default one worker per core up to the tree count);
    ``cache`` forces the shared transposition cache on/off (default: on
    for the array engine); ``batch`` forces lockstep batched leaf
    evaluation on/off (default: on for the array engine); ``shm`` forces
    the pool's shared-memory cache transport on/off (default: auto — on
    for pure-analytic parallel runs where POSIX shared memory exists);
    ``worker_batch`` forces in-worker lockstep batching of each pinned
    subset on/off (default: follow ``batch``).  All algorithms dispatch
    through the ``SearchBackend`` protocol
    (``repro.core.engine.backend``).

    ``cost`` selects the serving layer of the cost stack for MCTS runs:
    ``"analytic"`` (default — exact, bit-identical to the certified PR-2
    path), ``"learned"`` (serve the online-trained §3 MLP once it exists),
    or ``"hybrid"`` (serve it only while its holdout Spearman clears the
    confidence gate; exact-analytic fallback otherwise).  A pre-configured
    ``HybridCostBackend`` is also accepted.  See
    ``repro.core.engine.serving`` and ``docs/architecture.md``.

    ``measure_backend`` threads a fleet-bound measurement adapter
    (``MeasurementFleet.bind(...)``, see ``repro.core.measure_fleet``)
    through to the ensemble: ``mcts_cost+real_*`` runs then batch each
    root synchronization's candidate measurements through the fleet's
    workers instead of blocking the search loop on serial subprocess
    compiles, and a failed measurement degrades that candidate to its
    exact analytic cost (counted on ``TuneResult.n_measure_failures``)
    instead of aborting the run.

    ``controller`` mounts a round-boundary ``RunController``
    (``repro.core.run_control``): a deadline or cancel finishes the
    current decision round and returns best-so-far with
    ``TuneResult.stats["interrupted"]`` provenance; ``resume`` restores a
    ``ProTuner.snapshot()`` checkpoint so the run replays the remaining
    rounds bit-identically.  An uninterrupted run with a controller
    mounted is bit-identical to one without.  An interrupted (partial)
    result is never recorded into ``plan_store``."""
    assert engine in ENGINES, engine
    store_req = None
    if plan_store is not None:
        # persistent PlanStore (repro.service.store): answer a repeat
        # request from disk (from_store=True, zero evals), record a cold
        # result after the run.  The store key covers the value-affecting
        # settings of THIS signature — a caller passing a custom ``mdp``
        # must guarantee it matches them (the daemon does; see
        # service/daemon.py for cell-cache warm start on top of this).
        from repro.service.store import canonical_request

        store_req = canonical_request(
            arch, shape_name, mesh=mesh, algo=algo, seed=seed,
            time_budget_s=time_budget_s, n_standard=n_standard,
            n_greedy=n_greedy, noise_sigma=noise_sigma, cost=cost,
            pricing=pricing,
        )
        hit = plan_store.lookup(store_req)
        if hit is not None:
            return hit
    seed_plans = None
    if plan_store is not None and algo in ("evolve", "portfolio"):
        # warm-start the evolutionary population from the store's recorded
        # plans for this cell (any algo/seed — a good plan is a good seed);
        # non-evolutionary backends ignore seed_plans
        seed_plans = plan_store.seed_plans(
            arch=arch, shape=shape_name, mesh=mesh
        )
    mdp = mdp or make_mdp(arch, shape_name, mesh, noise_sigma, seed,
                          pricing=pricing)
    backend: SearchBackend = resolve_backend(algo, engine=engine)
    res = backend.run(
        mdp,
        seed=seed,
        time_budget_s=time_budget_s,
        measure_fn=measure_fn,
        measure_backend=measure_backend,
        n_standard=n_standard,
        n_greedy=n_greedy,
        parallel=parallel,
        cache=cache,
        batch=batch,
        cost=cost,
        n_workers=n_workers,
        worker_pool=worker_pool,
        shm=shm,
        worker_batch=worker_batch,
        seed_plans=seed_plans,
        controller=controller,
        resume=resume,
    )
    if plan_store is not None:
        plan_store.record(store_req, res)
    return res
