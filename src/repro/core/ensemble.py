"""ProTuner ensemble: N standard + M greedy MCTSes with synchronized roots
(paper §4.1/§4.2, pseudocode Fig. 6).

Every decision round each tree spends its budget from the shared current
root; the winning next root is the tree whose subtree found the best
complete schedule — by cost model, or by **real measurement** of each
tree's best candidate when ``measure_fn`` is given (``mcts_cost+real_*``).
All trees then advance to the same child (keeping their subtrees).

Engine layer: trees are built by ``repro.core.engine.make_tree`` —
``engine="array"`` (the default: flat-array ``ArrayMCTS``, identical
results, batched UCB) or ``engine="reference"`` (the paper-faithful
``Node`` trees, kept as the oracle).  With ``cache=True`` (the default for
the array engine) all trees share one ``TranspositionCache`` so a schedule
any tree has ever priced is never re-evaluated — across trees *and* across
decision rounds.  With ``batch=True`` (also the array default) sequential
decision rounds run the trees in LOCKSTEP: each step's K concurrent
simulations queue their pending leaves into one ``terminal_cost_batch``
call (``repro.core.engine.batch``) — results are identical to the
per-tree loop, and with the cache on so are the aggregate cache/eval
counters (uncached, in-batch dedup can only lower ``n_evals``).
``parallel=True`` runs each tree's decision round in PERSISTENT PINNED
workers (``engine/workers.py``): each worker process holds its subset of
the trees plus one serve-only ``CachedMDP`` for the whole run, and the
per-round traffic is a delta in BOTH directions — the master submits only
the root-advance action, the siblings' new cache entries since the
worker's last submit, and model params when the fit generation changed;
the worker returns the per-round tree delta (new/updated node slices +
this round's new cache entries).  Payload bytes at the pickle boundary
are counted and surfaced on ``TuneResult``
(``submit_bytes``/``return_bytes``/``snapshot_bytes`` + per-round lists).
Reference trees keep the stateless whole-tree ``ProcessPoolExecutor``
round trip.  Search results — plan, cost, and the decision sequence — are
identical to the sequential path for a fixed seed, and survive worker
deaths (the master reseeds a replacement from its canonical trees); the
``n_evals``/``cache_*`` counters can differ slightly when the cache is
on, because workers run against round-start cache snapshots and may
re-evaluate states a sibling priced in the same round.

Cost serving layer: ``cost="learned"|"hybrid"`` mounts a
``HybridCostBackend`` (``engine/serving.py``) inside the shared
``CachedMDP`` — the online trainer refits the §3 MLP on the cache's
analytic terminal entries at round boundaries, and the trained (confident)
model prices each miss batch in one jitted forward pass.  In parallel mode
workers serve but never refit (pickled backends are serve-only); the
master refits on the merged cache after each round and ships the new model
with the next round's submissions.  ``cost="analytic"`` (the default)
mounts nothing and stays bit-identical to the certified PR-2 path.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.engine import (
    CachedMDP,
    TranspositionCache,
    make_cost_backend,
    make_tree,
)
from repro.core.engine.array_mcts import ArrayMCTS
from repro.core.engine.batch import run_decision_batch
from repro.core.engine.workers import PinnedWorkerPool, pick_mp_context
from repro.core.mcts import MCTSConfig
from repro.core.mdp import ScheduleMDP, State
from repro.core.space import SchedulePlan

INF = float("inf")

# ProTuner.snapshot() schema version (round-boundary checkpoints; bump on
# any change to the snapshot dict's shape so stale checkpoints are ignored
# instead of mis-restored)
SNAPSHOT_VERSION = 1

logger = logging.getLogger(__name__)


@dataclass
class TuneResult:
    plan: SchedulePlan
    cost: float  # EXACT analytic cost of the final schedule (all cost modes)
    measured: Optional[float]  # real-measured step time (if measuring)
    n_evals: int  # cost-model evaluations
    n_measurements: int
    wall_time_s: float
    decisions: List[dict] = field(default_factory=list)
    algo: str = ""
    engine: str = "reference"
    cache_hits: int = 0
    cache_misses: int = 0
    # learned-cost serving (engine/serving.py); analytic runs keep defaults
    cost_mode: str = "analytic"
    model_version: int = 0  # serving model's fit generation at run end
    n_fits: int = 0
    learned_evals: int = 0  # plans priced by the learned model
    # pinned process-pool payload accounting (parallel array runs; zeros
    # otherwise): pickled bytes crossing the pool boundary, so the
    # O(round) transport claim is a measured number (engine/workers.py)
    submit_bytes: int = 0    # master -> workers, per-round forward deltas
    return_bytes: int = 0    # workers -> master, per-round reverse deltas
    snapshot_bytes: int = 0  # init + worker-death resync shipments
    submit_bytes_rounds: List[int] = field(default_factory=list)
    return_bytes_rounds: List[int] = field(default_factory=list)
    n_worker_restarts: int = 0
    # pinned-pool serving stats (engine/workers.PinnedWorkerPool.stats):
    # per-worker hit/miss/dedup counters, the shm-vs-export serving split,
    # and the per-round cross-worker duplicate-eval counts; empty for
    # non-pool runs
    stats: dict = field(default_factory=dict)
    # candidates whose real measurement failed and were re-ranked by their
    # exact analytic cost instead (mcts_cost+real_* graceful degradation)
    n_measure_failures: int = 0
    # served from the persistent PlanStore (repro.service) without a
    # search — n_evals is 0 and decisions are the stored run's
    from_store: bool = False

    def to_dict(self):
        d = dataclasses.asdict(self)
        d["plan"] = self.plan.to_dict()
        return d


def _tree_decision(tree):
    """Worker task (reference engine): run one tree's per-decision budget;
    ship the mutated tree back so its subtree (and cache entries) survive
    the round.  Cache counters travel as plain ints —
    ``TranspositionCache.__getstate__`` zeroes them on every pickle, so the
    worker's counts are exactly this round's activity but would be lost on
    the return trip otherwise.  Serving-backend pricing counters travel the
    same way (``HybridCostBackend.__getstate__`` zeroes them)."""
    res = tree.run_decision()
    stats = serving = None
    if isinstance(tree.mdp, CachedMDP):
        stats = (tree.mdp.cache.hits, tree.mdp.cache.misses)
        if tree.mdp.cost_backend is not None:
            serving = tree.mdp.cost_backend.counters()
    return tree, res, stats, serving


class ProTuner:
    def __init__(
        self,
        mdp: ScheduleMDP,
        *,
        n_standard: int = 15,
        n_greedy: int = 1,
        mcts_config: MCTSConfig = MCTSConfig(),
        measure_fn: Optional[Callable[[SchedulePlan], float]] = None,
        measure_backend=None,
        parallel: bool = False,
        seed: int = 0,
        engine: str = "array",
        cache: Optional[bool] = None,
        batch: Optional[bool] = None,
        cost: str = "analytic",
        n_workers: Optional[int] = None,
        worker_pool: Optional[PinnedWorkerPool] = None,
        shm: Optional[bool] = None,
        worker_batch: Optional[bool] = None,
        controller=None,
        resume: Optional[dict] = None,
    ):
        # parallel-transport levers (engine/workers.py): ``shm`` backs the
        # forward cache delta with a shared-memory log (None = auto: on
        # for pure-analytic runs where shared memory exists);
        # ``worker_batch`` runs each worker's pinned subset through ONE
        # lockstep run_decision_batch per round (None = follow ``batch``,
        # so the two batching levers compose by default on the array
        # engine)
        self.shm = shm
        # measure_backend: a fleet-bound FleetMeasure (core/measure_fleet).
        # It is callable with the same plan -> seconds contract, so it can
        # stand in for measure_fn wholesale; when present, candidate
        # batches additionally prefetch through its measure_plans fan-out
        # so the re-rank blocks on ONE round trip instead of N serial
        # compiles.
        self.measure_backend = measure_backend
        if measure_fn is None and measure_backend is not None:
            measure_fn = measure_backend
        self.measure_fn = measure_fn
        self.parallel = parallel or worker_pool is not None
        self.n_workers = n_workers
        # an externally owned PinnedWorkerPool (the tuner daemon shares one
        # pool across runs): rebind it to this run's trees instead of
        # spawning, and never shut it down
        self._ext_pool = worker_pool
        self.engine = engine
        # learned-cost serving: cost="learned"|"hybrid" (or a ready-made
        # HybridCostBackend) mounts the serving layer inside CachedMDP;
        # "analytic" mounts nothing — the PR-2 bit-identical path.  A
        # backend already mounted on a passed-in CachedMDP wins whatever
        # ``cost`` says: it IS pricing misses, so reporting/exact-repricing
        # must see it.
        if isinstance(mdp, CachedMDP) and mdp.cost_backend is not None:
            backend = mdp.cost_backend  # mounted backend wins over cost=
        else:
            backend = make_cost_backend(cost, mdp.space)
        self.cost_backend = backend
        self.cost_mode = backend.mode if backend is not None else "analytic"
        if cache is None:
            # the cache is the serving seam AND the training set, so a
            # cost backend turns it on for any engine
            cache = engine == "array" or backend is not None
        if batch is None:
            batch = engine == "array"
        self.batch = batch
        self.worker_batch = batch if worker_batch is None else worker_batch
        if backend is not None and not cache and not isinstance(mdp, CachedMDP):
            raise ValueError(
                "cost='learned'/'hybrid' requires the transposition cache "
                "(it is both the training set and the serving seam); "
                "drop the explicit cache=False or use cost='analytic'"
            )
        if (cache or backend is not None) and not isinstance(mdp, CachedMDP):
            mdp = CachedMDP(mdp, cost_backend=backend)
        elif (backend is not None and isinstance(mdp, CachedMDP)
              and mdp.cost_backend is None):
            mdp.cost_backend = backend
            backend.bind(mdp.cache)
        self.mdp = mdp
        self.cache: Optional[TranspositionCache] = (
            mdp.cache if isinstance(mdp, CachedMDP) else None
        )
        self.trees = []
        self.greedy_flags: List[bool] = []
        for i in range(n_standard):
            cfg = dataclasses.replace(mcts_config, simulation="random", seed=seed * 1000 + i)
            self.trees.append(make_tree(mdp, cfg, engine))
            self.greedy_flags.append(False)
        for i in range(n_greedy):
            cfg = dataclasses.replace(
                mcts_config, simulation="greedy", seed=seed * 1000 + 500 + i
            )
            self.trees.append(make_tree(mdp, cfg, engine))
            self.greedy_flags.append(True)
        self._measure_cache: Dict[State, float] = {}
        self._measure_failed: set = set()  # states re-ranked by analytic cost
        self.n_measurements = 0
        self.n_measure_failures = 0
        self._extra_evals = 0  # worker-side evals (parallel mode)
        self._pool: Optional[PinnedWorkerPool] = None
        self._pending_advance: Optional[int] = None  # last root-sync action
        # per-tree counter baseline at submission time; -1 = the tree was
        # reattached to the shared mdp, so next round's baseline is the
        # master counter (uncached trees keep private mdp copies whose
        # counters accumulate across rounds)
        self._sent_evals: Optional[List[int]] = None
        # round-boundary run control (core/run_control.py): deadline /
        # cancel / checkpoint hooks.  ``decisions`` lives on the instance
        # so snapshot()/restore round-trip the full decision trace.
        self.controller = controller
        self.decisions: List[dict] = []
        if resume is not None:
            self._restore(resume)

    # -- round-boundary checkpointing (core/run_control.py) ------------
    def snapshot(self) -> dict:
        """Everything a fresh ``ProTuner`` (built from the same request)
        needs to replay the remaining rounds bit-identically: the live
        trees (each carries its own ``random.Random`` and stat arrays; in
        parallel mode the MASTER trees are canonical, reverse deltas land
        every round), the decision trace, and the measurement memo.  The
        caller pickles the dict — the trees' shared ``mdp`` (and cache)
        dedups inside one ``dumps``.  Learned-cost runs are not
        snapshot-eligible (trainer state is not restorable); the run loop
        passes no thunk for them."""
        return {
            "version": SNAPSHOT_VERSION,
            "engine": self.engine,
            "round": len(self.decisions),
            "decisions": list(self.decisions),
            "trees": self.trees,
            "measure_cache": dict(self._measure_cache),
            "measure_failed": set(self._measure_failed),
            "n_measurements": self.n_measurements,
            "n_measure_failures": self.n_measure_failures,
        }

    def _restore(self, snap: dict) -> None:
        """Adopt a ``snapshot()`` (typically pickle-round-tripped through
        the plan store's checkpoint tier).  A snapshot that doesn't match
        this run's shape is ignored — the run starts fresh, which is
        always correct, just slower."""
        trees = snap.get("trees") if isinstance(snap, dict) else None
        if (
            not isinstance(snap, dict)
            or snap.get("version") != SNAPSHOT_VERSION
            or not trees
            or len(trees) != len(self.trees)
            or snap.get("engine") != self.engine
        ):
            logger.warning("checkpoint does not match this run; starting fresh")
            return
        old_mdp = trees[0].mdp
        if isinstance(old_mdp, CachedMDP) and isinstance(self.mdp, CachedMDP):
            # warm entries priced before the interrupt survive it; a pure
            # memo of exact values never changes plan/cost/decisions
            self.mdp.cache.merge(old_mdp.cache)
        for t in trees:
            t.mdp = self.mdp  # reattach this run's (shared) mdp + cache
        self.trees = trees
        self.decisions = list(snap["decisions"])
        self._measure_cache = dict(snap["measure_cache"])
        self._measure_failed = set(snap["measure_failed"])
        self.n_measurements = snap["n_measurements"]
        self.n_measure_failures = snap["n_measure_failures"]

    # ------------------------------------------------------------------
    def _exact_cost(self, state: State) -> float:
        """EXACT analytic terminal cost.  With a learned server mounted,
        the cache (and any miss pricing through ``self.mdp``) may return
        model predictions — bypass both and price on the inner MDP; with
        no server, the cached value IS exact, so go through the cache as
        the PR-2 path always did (hit counters unchanged)."""
        if self.cost_backend is not None and isinstance(self.mdp, CachedMDP):
            return self.mdp.mdp.terminal_cost(state)
        return self.mdp.terminal_cost(state)

    # ------------------------------------------------------------------
    def _degrade(self, state: State, why: str) -> float:
        """A failed measurement must not kill the run: re-rank this
        candidate by its EXACT analytic cost, count it, and keep going."""
        self.n_measure_failures += 1
        t = self._exact_cost(state)
        self._measure_cache[state] = t
        self._measure_failed.add(state)
        logger.warning(
            "measurement failed (candidate degraded to analytic cost "
            "%.6gs): %s", t, why,
        )
        return t

    def _measure_state(self, state: State) -> float:
        if state in self._measure_cache:
            return self._measure_cache[state]
        try:
            t = self.measure_fn(self.mdp.plan(state))
        except Exception as e:  # noqa: BLE001 - degrade, never abort the run
            return self._degrade(state, repr(e))
        self._measure_cache[state] = t
        self.n_measurements += 1
        return t

    def _prefetch_measurements(self, states: List[State]) -> None:
        """Batch the round's candidate measurements through the fleet
        (one ``measure_many`` fan-out over the workers) so the
        re-ranking ``min()`` below only ever hits the local cache."""
        todo = [s for s in states if s not in self._measure_cache]
        if not todo or self.measure_backend is None:
            return
        plans = [self.mdp.plan(s) for s in todo]
        try:
            times = self.measure_backend.measure_plans(plans)
        except Exception as e:  # noqa: BLE001 - fall back to per-state path
            logger.warning("fleet prefetch failed (%r); measuring serially", e)
            return
        for st, t in zip(todo, times):
            if t is None:
                self._degrade(st, "fleet measurement failed")
            else:
                self._measure_cache[st] = t
                self.n_measurements += 1

    # ------------------------------------------------------------------
    def _round_sequential(self):
        if self.batch and all(isinstance(t, ArrayMCTS) for t in self.trees):
            # lockstep pending-leaf round: the K trees' concurrent
            # simulations price through ONE terminal_cost_batch call per
            # step — results identical to the per-tree loop (engine/batch)
            return run_decision_batch(self.trees, self.mdp,
                                      controller=self.controller)
        return [t.run_decision() for t in self.trees]

    def _round_pinned(self):
        """One decision round through the persistent pinned workers
        (``engine/workers.py``): forward deltas out (root advance +
        sibling cache entries + generation-keyed params), reverse deltas
        back, merged deterministically onto the master's canonical trees
        and cache.  The master-side refit point stays here: workers never
        refit (their backends shipped serve-only), so the merged cache is
        scored after the round and the new generation ships with the next
        round's forward deltas."""
        results = self._pool.round(self._pending_advance)
        self._pending_advance = None
        self._extra_evals += self._pool.extra_evals
        self._pool.extra_evals = 0
        if isinstance(self.mdp, CachedMDP):
            self.mdp.on_round_end()
        return results

    def _round_parallel(self, executor: ProcessPoolExecutor):
        """One decision round across stateless executor workers (the
        reference engine's whole-tree round trip); deterministic merge:
        results and tree updates happen in tree-index order regardless of
        completion order, so output is identical to the sequential path.
        Array trees never take this path — they run in the pinned pool
        (``_round_pinned``)."""
        base_evals = getattr(self.mdp.cost_model, "n_evals", None)
        if base_evals is not None and self._sent_evals is None:
            self._sent_evals = [base_evals] * len(self.trees)
        futures = [executor.submit(_tree_decision, t) for t in self.trees]
        results = []
        for i, fut in enumerate(futures):
            tree, res, stats, serving = fut.result()
            if serving is not None and self.cost_backend is not None:
                self.cost_backend.merge_counters(serving)
            if base_evals is not None:
                sent = self._sent_evals[i]
                if sent < 0:  # was reattached: baseline is the master counter
                    sent = base_evals
                worker_evals = getattr(tree.mdp.cost_model, "n_evals", sent)
                self._extra_evals += max(worker_evals - sent, 0)
            else:
                worker_evals = None
            reattach = self.cache is not None and isinstance(tree.mdp, CachedMDP)
            if reattach:
                self.cache.merge(tree.mdp.cache)
                if stats is not None:
                    self.cache.hits += stats[0]
                    self.cache.misses += stats[1]
                tree.mdp = self.mdp  # reattach the shared cache for next round
            if base_evals is not None:
                self._sent_evals[i] = -1 if reattach else worker_evals
            self.trees[i] = tree
            results.append(res)
        # master-side refit point: workers never refit (their pickled
        # backends are serve-only), so the merged cache is scored here and
        # the refreshed model ships with the next round's submissions
        if isinstance(self.mdp, CachedMDP):
            self.mdp.on_round_end()
        return results

    def run(self, time_budget_s: Optional[float] = None) -> TuneResult:
        t0 = time.perf_counter()
        decisions = self.decisions  # non-empty on a checkpoint resume
        controller = self.controller
        # checkpoint eligibility: learned-cost serving carries trainer
        # state (fit generations, model params) that a snapshot can't
        # restore bit-identically — those runs keep deadline/cancel
        # support but never checkpoint (a replay restarts from scratch,
        # which is deterministic and therefore still correct)
        snapshot_thunk = self.snapshot if self.cost_backend is None else None
        interrupted: Optional[dict] = None
        executor: Optional[ProcessPoolExecutor] = None
        try:
            if self.parallel:
                if self._ext_pool is not None:
                    assert all(isinstance(t, ArrayMCTS) for t in self.trees), \
                        "a shared worker pool requires the array engine"
                    self._ext_pool.rebind(
                        self.trees, self.mdp, shm=self.shm,
                        worker_batch=self.worker_batch,
                    )
                    self._pool = self._ext_pool
                elif all(isinstance(t, ArrayMCTS) for t in self.trees):
                    # persistent pinned workers: trees + serve-only mdp
                    # ship ONCE; every round after that is a delta in
                    # both directions (engine/workers.py)
                    self._pool = PinnedWorkerPool(
                        self.trees, self.mdp, n_workers=self.n_workers,
                        shm=self.shm, worker_batch=self.worker_batch,
                    )
                else:
                    # reference engine: stateless whole-tree round trips
                    executor = ProcessPoolExecutor(
                        max_workers=min(
                            len(self.trees),
                            self.n_workers or os.cpu_count() or 2,
                        ),
                        mp_context=pick_mp_context(),
                    )
            while not self.trees[0].done:
                if time_budget_s and time.perf_counter() - t0 > time_budget_s:
                    break
                if controller is not None:
                    controller.begin_round()
                if self._pool is not None:
                    results = self._round_pinned()
                elif executor is not None:
                    results = self._round_parallel(executor)
                else:
                    results = self._round_sequential()

                # winner: best complete schedule across trees; optionally
                # re-rank the (deduped) candidates by real measurement
                # (paper Fig. 6's commented line).
                if self.measure_fn is not None:
                    ranked = sorted(
                        range(len(results)), key=lambda i: results[i].best_cost
                    )
                    seen: Dict[State, int] = {}
                    for i in ranked:
                        st = results[i].best_state
                        if st is not None and st not in seen:
                            seen[st] = i
                    self._prefetch_measurements(list(seen))
                    best_i = min(
                        seen.values(),
                        key=lambda i: self._measure_state(results[i].best_state),
                    )
                else:
                    best_i = min(
                        range(len(results)), key=lambda i: results[i].best_cost
                    )
                win = results[best_i]
                decisions.append(
                    {
                        "depth": len(self.trees[0].root_state),
                        "stage": self.mdp.space.stages[len(self.trees[0].root_state)].name,
                        "action": win.action,
                        "winner_tree": best_i,
                        "winner_greedy": self.greedy_flags[best_i],
                        "best_cost": win.best_cost,
                    }
                )
                for t in self.trees:
                    t.advance_root(win.action)
                # pinned workers are one advance behind the master's
                # canonical trees until the next round's forward delta
                self._pending_advance = win.action

                if controller is not None:
                    # a cancel can truncate the round mid-iteration
                    # (engine/batch.py); a truncated boundary is NOT
                    # canonical, so it is neither counted, delayed, nor
                    # checkpointed — the last cadence checkpoint (all full
                    # rounds) stays the resume point
                    truncated = controller.round_truncated
                    if not truncated:
                        controller.round_done(snapshot_thunk)
                    reason = controller.should_stop()
                    if reason is not None and not self.trees[0].done:
                        ckpt = False
                        if not truncated:
                            # final boundary checkpoint (idempotent with a
                            # cadence checkpoint on the same round)
                            ckpt = controller.checkpoint(snapshot_thunk)
                        interrupted = {
                            "reason": reason,
                            "rounds_done": len(decisions),
                            "rounds_total": len(self.mdp.space.stages),
                            "round_truncated": truncated,
                            "checkpointed": bool(ckpt),
                        }
                        break
        finally:
            if self._pool is not None and self._pool is not self._ext_pool:
                self._pool.shutdown()
            if executor is not None:
                # wait=True: with wait=False the queue-feeder thread can
                # block forever on the large pickled-tree payloads still in
                # the call queue after a pool failure, hanging interpreter
                # exit
                executor.shutdown(wait=True, cancel_futures=True)

        # final schedule: the best complete state any tree ever saw
        best_tree = min(self.trees, key=lambda t: t.global_best)
        final_state = best_tree.global_best_state
        final_cost = best_tree.global_best
        if self.cost_backend is not None and final_state is not None:
            # a learned server picked the winner by its ESTIMATES; report
            # the exact analytic cost of that schedule so TuneResult.cost
            # is comparable across cost modes
            final_cost = self._exact_cost(final_state)
        measured = None
        if self.measure_fn is not None and final_state is not None:
            # winner by real time among all measured candidates + final
            cands = dict(self._measure_cache)
            cands[final_state] = self._measure_state(final_state)
            final_state = min(cands, key=cands.get)
            # a degraded candidate's entry is its analytic cost, not a
            # real measurement — never report it as one
            if final_state not in self._measure_failed:
                measured = cands[final_state]
            final_cost = self._exact_cost(final_state)
        n_evals = getattr(self.mdp.cost_model, "n_evals", 0) + self._extra_evals
        serving = self.cost_backend.stats() if self.cost_backend else None
        pool = self._pool
        stats = pool.stats() if pool else {}
        if interrupted is not None:
            # best-so-far provenance: callers (the daemon, the plan store)
            # must treat this result as partial — never record it as THE
            # answer for the request
            stats["interrupted"] = interrupted
        return TuneResult(
            plan=self.mdp.plan(final_state),
            cost=final_cost,
            measured=measured,
            n_evals=n_evals,
            n_measurements=self.n_measurements,
            wall_time_s=time.perf_counter() - t0,
            decisions=decisions,
            algo="mcts",
            engine=self.engine,
            cache_hits=self.cache.hits if self.cache else 0,
            cache_misses=self.cache.misses if self.cache else 0,
            cost_mode=self.cost_mode,
            model_version=serving["model_version"] if serving else 0,
            n_fits=serving["n_fits"] if serving else 0,
            learned_evals=serving["learned_plans"] if serving else 0,
            submit_bytes=pool.submit_bytes if pool else 0,
            return_bytes=pool.return_bytes if pool else 0,
            snapshot_bytes=pool.snapshot_bytes if pool else 0,
            submit_bytes_rounds=list(pool.submit_bytes_rounds) if pool else [],
            return_bytes_rounds=list(pool.return_bytes_rounds) if pool else [],
            n_worker_restarts=pool.n_worker_restarts if pool else 0,
            stats=stats,
            n_measure_failures=self.n_measure_failures,
        )


@dataclass
class MCTSEnsembleBackend:
    """``SearchBackend`` adapter for the ProTuner ensemble (see
    ``repro.core.engine.backend``)."""

    algo: str = "mcts"
    config: MCTSConfig = field(default_factory=MCTSConfig)
    engine: str = "array"
    cost: str = "analytic"  # learned-cost serving mode (engine/serving.py)
    name: str = "mcts"

    def run(
        self,
        mdp,
        *,
        seed: int = 0,
        time_budget_s: Optional[float] = None,
        measure_fn: Optional[Callable] = None,
        measure_backend=None,
        n_standard: int = 15,
        n_greedy: int = 1,
        parallel: bool = False,
        cache: Optional[bool] = None,
        batch: Optional[bool] = None,
        cost=None,  # None -> the backend's configured self.cost
        n_workers: Optional[int] = None,
        worker_pool=None,
        shm: Optional[bool] = None,
        worker_batch: Optional[bool] = None,
        controller=None,
        resume: Optional[dict] = None,
        **_,
    ) -> TuneResult:
        mc = dataclasses.replace(self.config, seed=seed)
        # paper protocol: only the cost+real_* variants re-rank by real
        # measurement at root synchronization
        use_measure = measure_fn if "real" in self.algo else None
        use_backend = measure_backend if "real" in self.algo else None
        tuner = ProTuner(
            mdp,
            n_standard=n_standard,
            n_greedy=n_greedy,
            mcts_config=mc,
            measure_fn=use_measure,
            measure_backend=use_backend,
            parallel=parallel,
            seed=seed,
            engine=self.engine,
            cache=cache,
            batch=batch,
            cost=cost if cost is not None else self.cost,
            n_workers=n_workers,
            worker_pool=worker_pool,
            shm=shm,
            worker_batch=worker_batch,
            controller=controller,
            resume=resume,
        )
        res = tuner.run(time_budget_s=time_budget_s)
        res.algo = self.algo
        return res
