"""ProTuner ensemble: N standard + M greedy MCTSes with synchronized roots
(paper §4.1/§4.2, pseudocode Fig. 6).

Every decision round each tree spends its budget from the shared current
root; the winning next root is the tree whose subtree found the best
complete schedule — by cost model, or by **real measurement** of each
tree's best candidate when ``measure_fn`` is given (``mcts_cost+real_*``).
All trees then advance to the same child (keeping their subtrees).

Engine layer: trees are built by ``repro.core.engine.make_tree`` —
``engine="array"`` (the default: flat-array ``ArrayMCTS``, identical
results, batched UCB) or ``engine="reference"`` (the paper-faithful
``Node`` trees, kept as the oracle).  With ``cache=True`` (the default for
the array engine) all trees share one ``TranspositionCache`` so a schedule
any tree has ever priced is never re-evaluated — across trees *and* across
decision rounds.  With ``batch=True`` (also the array default) sequential
decision rounds run the trees in LOCKSTEP: each step's K concurrent
simulations queue their pending leaves into one ``terminal_cost_batch``
call (``repro.core.engine.batch``) — results are identical to the
per-tree loop, and with the cache on so are the aggregate cache/eval
counters (uncached, in-batch dedup can only lower ``n_evals``).
``parallel=True`` runs each tree's decision in a ``ProcessPoolExecutor``
(the old ThreadPool path was GIL-bound): results are merged in tree-index
order regardless of completion order.  Array trees return per-round tree
DELTAS (new/updated node slices + this round's new cache entries) instead
of whole pickled trees — the return payload that made the pool lose to
sequential below ~4 cores; reference trees keep the whole-tree round trip.
Search results — plan, cost, and the decision sequence — are identical to
the sequential path for a fixed seed; the ``n_evals``/``cache_*`` counters
can differ slightly when the cache is on, because workers run against
round-start cache snapshots and may re-evaluate states a sibling priced in
the same round.
"""
from __future__ import annotations

import dataclasses
import itertools
import multiprocessing
import os
import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.engine import CachedMDP, TranspositionCache, make_tree
from repro.core.engine.array_mcts import ArrayMCTS
from repro.core.engine.batch import run_decision_batch
from repro.core.mcts import MCTSConfig
from repro.core.mdp import ScheduleMDP, State
from repro.core.space import SchedulePlan

INF = float("inf")


@dataclass
class TuneResult:
    plan: SchedulePlan
    cost: float  # cost-model cost of the final schedule
    measured: Optional[float]  # real-measured step time (if measuring)
    n_evals: int  # cost-model evaluations
    n_measurements: int
    wall_time_s: float
    decisions: List[dict] = field(default_factory=list)
    algo: str = ""
    engine: str = "reference"
    cache_hits: int = 0
    cache_misses: int = 0

    def to_dict(self):
        d = dataclasses.asdict(self)
        d["plan"] = self.plan.to_dict()
        return d


def _tree_decision(tree):
    """Worker task (reference engine): run one tree's per-decision budget;
    ship the mutated tree back so its subtree (and cache entries) survive
    the round.  Cache counters travel as plain ints —
    ``TranspositionCache.__getstate__`` zeroes them on every pickle, so the
    worker's counts are exactly this round's activity but would be lost on
    the return trip otherwise."""
    res = tree.run_decision()
    stats = None
    if isinstance(tree.mdp, CachedMDP):
        stats = (tree.mdp.cache.hits, tree.mdp.cache.misses)
    return tree, res, stats


def _tree_decision_delta(tree):
    """Worker task (array engine): run one tree's per-decision budget and
    return the round's TREE DELTA — the new/updated node slices — instead
    of the whole pickled tree (the whole-tree return trip is what made the
    pool lose to sequential below ~4 cores).  New cache entries ship as
    plain dict slices: entries are append-only and insertion-ordered, so
    everything past the round-start lengths is exactly this round's
    additions."""
    cached = isinstance(tree.mdp, CachedMDP)
    if cached:
        cache = tree.mdp.cache
        base_t, base_p = len(cache.terminal), len(cache.partial)
    tree.begin_delta()
    res = tree.run_decision()
    delta = tree.collect_delta()
    stats = cache_new = None
    if cached:
        stats = (cache.hits, cache.misses)
        cache_new = (
            dict(itertools.islice(cache.terminal.items(), base_t, None)),
            dict(itertools.islice(cache.partial.items(), base_p, None)),
        )
    n_evals = getattr(tree.mdp.cost_model, "n_evals", None)
    return delta, res, stats, cache_new, n_evals


class ProTuner:
    def __init__(
        self,
        mdp: ScheduleMDP,
        *,
        n_standard: int = 15,
        n_greedy: int = 1,
        mcts_config: MCTSConfig = MCTSConfig(),
        measure_fn: Optional[Callable[[SchedulePlan], float]] = None,
        parallel: bool = False,
        seed: int = 0,
        engine: str = "array",
        cache: Optional[bool] = None,
        batch: Optional[bool] = None,
    ):
        self.measure_fn = measure_fn
        self.parallel = parallel
        self.engine = engine
        if cache is None:
            cache = engine == "array"
        if batch is None:
            batch = engine == "array"
        self.batch = batch
        if cache and not isinstance(mdp, CachedMDP):
            mdp = CachedMDP(mdp)
        self.mdp = mdp
        self.cache: Optional[TranspositionCache] = (
            mdp.cache if isinstance(mdp, CachedMDP) else None
        )
        self.trees = []
        self.greedy_flags: List[bool] = []
        for i in range(n_standard):
            cfg = dataclasses.replace(mcts_config, simulation="random", seed=seed * 1000 + i)
            self.trees.append(make_tree(mdp, cfg, engine))
            self.greedy_flags.append(False)
        for i in range(n_greedy):
            cfg = dataclasses.replace(
                mcts_config, simulation="greedy", seed=seed * 1000 + 500 + i
            )
            self.trees.append(make_tree(mdp, cfg, engine))
            self.greedy_flags.append(True)
        self._measure_cache: Dict[State, float] = {}
        self.n_measurements = 0
        self._extra_evals = 0  # worker-side evals (parallel mode)
        # per-tree counter baseline at submission time; -1 = the tree was
        # reattached to the shared mdp, so next round's baseline is the
        # master counter (uncached trees keep private mdp copies whose
        # counters accumulate across rounds)
        self._sent_evals: Optional[List[int]] = None

    # ------------------------------------------------------------------
    def _measure_state(self, state: State) -> float:
        if state in self._measure_cache:
            return self._measure_cache[state]
        t = self.measure_fn(self.mdp.plan(state))
        self._measure_cache[state] = t
        self.n_measurements += 1
        return t

    # ------------------------------------------------------------------
    def _round_sequential(self):
        if self.batch and all(isinstance(t, ArrayMCTS) for t in self.trees):
            # lockstep pending-leaf round: the K trees' concurrent
            # simulations price through ONE terminal_cost_batch call per
            # step — results identical to the per-tree loop (engine/batch)
            return run_decision_batch(self.trees, self.mdp)
        return [t.run_decision() for t in self.trees]

    def _round_parallel(self, executor: ProcessPoolExecutor):
        """One decision round across workers; deterministic merge: results
        and tree updates happen in tree-index order regardless of
        completion order, so output is identical to the sequential path.
        Array trees travel one-way: the worker returns a per-round tree
        delta applied to the master's kept tree object; reference trees
        keep the PR-1 whole-tree round trip."""
        base_evals = getattr(self.mdp.cost_model, "n_evals", None)
        if base_evals is not None and self._sent_evals is None:
            self._sent_evals = [base_evals] * len(self.trees)
        futures = [
            executor.submit(
                _tree_decision_delta if isinstance(t, ArrayMCTS)
                else _tree_decision,
                t,
            )
            for t in self.trees
        ]
        results = []
        for i, fut in enumerate(futures):
            got = fut.result()
            if isinstance(self.trees[i], ArrayMCTS):
                # delta path: the master's tree object persists
                delta, res, stats, cache_new, worker_evals = got
                self.trees[i].apply_delta(delta)
                if self.cache is not None and cache_new is not None:
                    self.cache.terminal.update(cache_new[0])
                    self.cache.partial.update(cache_new[1])
                    if stats is not None:
                        self.cache.hits += stats[0]
                        self.cache.misses += stats[1]
                if base_evals is not None and worker_evals is not None:
                    sent = self._sent_evals[i]
                    if sent < 0:  # master counter at submit is the baseline
                        sent = base_evals
                    self._extra_evals += max(worker_evals - sent, 0)
                    self._sent_evals[i] = -1
                results.append(res)
                continue
            tree, res, stats = got
            if base_evals is not None:
                sent = self._sent_evals[i]
                if sent < 0:  # was reattached: baseline is the master counter
                    sent = base_evals
                worker_evals = getattr(tree.mdp.cost_model, "n_evals", sent)
                self._extra_evals += max(worker_evals - sent, 0)
            else:
                worker_evals = None
            reattach = self.cache is not None and isinstance(tree.mdp, CachedMDP)
            if reattach:
                self.cache.merge(tree.mdp.cache)
                if stats is not None:
                    self.cache.hits += stats[0]
                    self.cache.misses += stats[1]
                tree.mdp = self.mdp  # reattach the shared cache for next round
            if base_evals is not None:
                self._sent_evals[i] = -1 if reattach else worker_evals
            self.trees[i] = tree
            results.append(res)
        return results

    def run(self, time_budget_s: Optional[float] = None) -> TuneResult:
        t0 = time.perf_counter()
        decisions: List[dict] = []
        executor: Optional[ProcessPoolExecutor] = None
        try:
            if self.parallel:
                # forkserver: workers start from a clean process (forking a
                # jax-threaded parent can deadlock) and stay cheap to spawn —
                # schedule pricing is deliberately jax-free (kernels/geometry)
                methods = multiprocessing.get_all_start_methods()
                method = next(
                    (m for m in ("forkserver", "fork") if m in methods), None
                )
                executor = ProcessPoolExecutor(
                    max_workers=min(len(self.trees), os.cpu_count() or 2),
                    mp_context=multiprocessing.get_context(method),
                )
            while not self.trees[0].done:
                if time_budget_s and time.perf_counter() - t0 > time_budget_s:
                    break
                if executor is not None:
                    results = self._round_parallel(executor)
                else:
                    results = self._round_sequential()

                # winner: best complete schedule across trees; optionally
                # re-rank the (deduped) candidates by real measurement
                # (paper Fig. 6's commented line).
                if self.measure_fn is not None:
                    ranked = sorted(
                        range(len(results)), key=lambda i: results[i].best_cost
                    )
                    seen: Dict[State, int] = {}
                    for i in ranked:
                        st = results[i].best_state
                        if st is not None and st not in seen:
                            seen[st] = i
                    best_i = min(
                        seen.values(),
                        key=lambda i: self._measure_state(results[i].best_state),
                    )
                else:
                    best_i = min(
                        range(len(results)), key=lambda i: results[i].best_cost
                    )
                win = results[best_i]
                decisions.append(
                    {
                        "depth": len(self.trees[0].root_state),
                        "stage": self.mdp.space.stages[len(self.trees[0].root_state)].name,
                        "action": win.action,
                        "winner_tree": best_i,
                        "winner_greedy": self.greedy_flags[best_i],
                        "best_cost": win.best_cost,
                    }
                )
                for t in self.trees:
                    t.advance_root(win.action)
        finally:
            if executor is not None:
                # wait=True: with wait=False the queue-feeder thread can
                # block forever on the large pickled-tree payloads still in
                # the call queue after a pool failure, hanging interpreter
                # exit
                executor.shutdown(wait=True, cancel_futures=True)

        # final schedule: the best complete state any tree ever saw
        best_tree = min(self.trees, key=lambda t: t.global_best)
        final_state = best_tree.global_best_state
        final_cost = best_tree.global_best
        measured = None
        if self.measure_fn is not None and final_state is not None:
            # winner by real time among all measured candidates + final
            cands = dict(self._measure_cache)
            cands[final_state] = self._measure_state(final_state)
            final_state = min(cands, key=cands.get)
            measured = cands[final_state]
            final_cost = self.mdp.terminal_cost(final_state)
        n_evals = getattr(self.mdp.cost_model, "n_evals", 0) + self._extra_evals
        return TuneResult(
            plan=self.mdp.plan(final_state),
            cost=final_cost,
            measured=measured,
            n_evals=n_evals,
            n_measurements=self.n_measurements,
            wall_time_s=time.perf_counter() - t0,
            decisions=decisions,
            algo="mcts",
            engine=self.engine,
            cache_hits=self.cache.hits if self.cache else 0,
            cache_misses=self.cache.misses if self.cache else 0,
        )


@dataclass
class MCTSEnsembleBackend:
    """``SearchBackend`` adapter for the ProTuner ensemble (see
    ``repro.core.engine.backend``)."""

    algo: str = "mcts"
    config: MCTSConfig = field(default_factory=MCTSConfig)
    engine: str = "array"
    name: str = "mcts"

    def run(
        self,
        mdp,
        *,
        seed: int = 0,
        time_budget_s: Optional[float] = None,
        measure_fn: Optional[Callable] = None,
        n_standard: int = 15,
        n_greedy: int = 1,
        parallel: bool = False,
        cache: Optional[bool] = None,
        batch: Optional[bool] = None,
        **_,
    ) -> TuneResult:
        mc = dataclasses.replace(self.config, seed=seed)
        # paper protocol: only the cost+real_* variants re-rank by real
        # measurement at root synchronization
        use_measure = measure_fn if "real" in self.algo else None
        tuner = ProTuner(
            mdp,
            n_standard=n_standard,
            n_greedy=n_greedy,
            mcts_config=mc,
            measure_fn=use_measure,
            parallel=parallel,
            seed=seed,
            engine=self.engine,
            cache=cache,
            batch=batch,
        )
        res = tuner.run(time_budget_s=time_budget_s)
        res.algo = self.algo
        return res
