"""ProTuner ensemble: N standard + M greedy MCTSes with synchronized roots
(paper §4.1/§4.2, pseudocode Fig. 6).

Every decision round each tree spends its budget from the shared current
root; the winning next root is the tree whose subtree found the best
complete schedule — by cost model, or by **real measurement** of each
tree's best candidate when ``measure_fn`` is given (``mcts_cost+real_*``).
All trees then advance to the same child (keeping their subtrees).
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.mcts import MCTS, MCTSConfig
from repro.core.mdp import ScheduleMDP, State
from repro.core.space import SchedulePlan

INF = float("inf")


@dataclass
class TuneResult:
    plan: SchedulePlan
    cost: float  # cost-model cost of the final schedule
    measured: Optional[float]  # real-measured step time (if measuring)
    n_evals: int  # cost-model evaluations
    n_measurements: int
    wall_time_s: float
    decisions: List[dict] = field(default_factory=list)
    algo: str = ""

    def to_dict(self):
        d = dataclasses.asdict(self)
        d["plan"] = self.plan.to_dict()
        return d


class ProTuner:
    def __init__(
        self,
        mdp: ScheduleMDP,
        *,
        n_standard: int = 15,
        n_greedy: int = 1,
        mcts_config: MCTSConfig = MCTSConfig(),
        measure_fn: Optional[Callable[[SchedulePlan], float]] = None,
        parallel: bool = False,
        seed: int = 0,
    ):
        self.mdp = mdp
        self.measure_fn = measure_fn
        self.parallel = parallel
        self.trees: List[MCTS] = []
        self.greedy_flags: List[bool] = []
        for i in range(n_standard):
            cfg = dataclasses.replace(mcts_config, simulation="random", seed=seed * 1000 + i)
            self.trees.append(MCTS(mdp, cfg))
            self.greedy_flags.append(False)
        for i in range(n_greedy):
            cfg = dataclasses.replace(
                mcts_config, simulation="greedy", seed=seed * 1000 + 500 + i
            )
            self.trees.append(MCTS(mdp, cfg))
            self.greedy_flags.append(True)
        self._measure_cache: Dict[State, float] = {}
        self.n_measurements = 0

    # ------------------------------------------------------------------
    def _measure_state(self, state: State) -> float:
        if state in self._measure_cache:
            return self._measure_cache[state]
        t = self.measure_fn(self.mdp.plan(state))
        self._measure_cache[state] = t
        self.n_measurements += 1
        return t

    def run(self, time_budget_s: Optional[float] = None) -> TuneResult:
        t0 = time.perf_counter()
        decisions: List[dict] = []
        while not self.trees[0].done:
            if time_budget_s and time.perf_counter() - t0 > time_budget_s:
                break
            if self.parallel:
                with ThreadPoolExecutor(max_workers=len(self.trees)) as ex:
                    results = list(ex.map(lambda t: t.run_decision(), self.trees))
            else:
                results = [t.run_decision() for t in self.trees]

            # winner: best complete schedule across trees; optionally re-rank
            # the (deduped) candidates by real measurement (paper Fig. 6's
            # commented line).
            if self.measure_fn is not None:
                ranked = sorted(
                    range(len(results)), key=lambda i: results[i].best_cost
                )
                seen: Dict[State, int] = {}
                for i in ranked:
                    st = results[i].best_state
                    if st is not None and st not in seen:
                        seen[st] = i
                best_i = min(
                    seen.values(),
                    key=lambda i: self._measure_state(results[i].best_state),
                )
            else:
                best_i = min(
                    range(len(results)), key=lambda i: results[i].best_cost
                )
            win = results[best_i]
            decisions.append(
                {
                    "depth": len(self.trees[0].root_state),
                    "stage": self.mdp.space.stages[len(self.trees[0].root_state)].name,
                    "action": win.action,
                    "winner_tree": best_i,
                    "winner_greedy": self.greedy_flags[best_i],
                    "best_cost": win.best_cost,
                }
            )
            for t in self.trees:
                t.advance_root(win.action)

        # final schedule: the best complete state any tree ever saw
        best_tree = min(self.trees, key=lambda t: t.global_best)
        final_state = best_tree.global_best_state
        final_cost = best_tree.global_best
        measured = None
        if self.measure_fn is not None and final_state is not None:
            # winner by real time among all measured candidates + final
            cands = dict(self._measure_cache)
            cands[final_state] = self._measure_state(final_state)
            final_state = min(cands, key=cands.get)
            measured = cands[final_state]
            final_cost = self.mdp.terminal_cost(final_state)
        n_evals = getattr(self.mdp.cost_model, "n_evals", 0)
        return TuneResult(
            plan=self.mdp.plan(final_state),
            cost=final_cost,
            measured=measured,
            n_evals=n_evals,
            n_measurements=self.n_measurements,
            wall_time_s=time.perf_counter() - t0,
            decisions=decisions,
            algo="mcts",
        )
