"""Round-boundary run control: deadlines, cancellation, checkpoints.

``RunController`` is the seam between a *search* (the ProTuner ensemble's
decision-round loop, or the evolutionary backend's generation loop) and
the *runtime* that owns it (the tuner daemon, a test harness, a signal
handler).  The engine consults the controller at round boundaries only —
between boundaries a search is a pure deterministic function of its
inputs, so:

* an **uninterrupted** run with a controller mounted is bit-identical to
  a run without one (the controller reads a clock and an event; it never
  touches search state), and
* every **checkpoint** is taken at a round boundary of a *fully
  completed* round, so a resumed run replays the exact tail of the
  uninterrupted one — plan/cost/decisions bit-identical (certified by
  ``tests/test_run_control.py`` and the SIGKILL daemon test).

Contract (what the engine calls, in order, once per decision round):

1. ``begin_round()`` — reset the per-round truncation flag.
2. mid-round (optional, inside ``engine/batch.py``'s iteration loop):
   ``abort_round()`` — True once ``cancel()`` was called; the engine may
   then cut the round short (fewer simulations).  Deadlines never
   truncate a round: a deadline interrupt always lands on a canonical
   boundary, so its final checkpoint is resumable.
3. ``round_done(snapshot_thunk)`` — count the round, apply the
   fault-injection delay, and take a cadence checkpoint every
   ``checkpoint_every`` rounds (the thunk builds the snapshot lazily, so
   rounds between checkpoints pay nothing).  Skipped by the engine when
   the round was truncated — a truncated round must never be
   checkpointed.
4. ``should_stop()`` — ``"cancelled"`` / ``"deadline"`` / ``None``.  On a
   stop the engine writes a final boundary checkpoint via
   ``checkpoint(thunk)`` (idempotent per round), attaches
   ``TuneResult.stats["interrupted"]`` provenance, and returns
   best-so-far.

``deadline_s`` is relative wall time measured on an injectable monotonic
``clock`` (tests pass a fake).  ``cancel()`` is thread-safe — the daemon's
socket threads call it against an in-flight search.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class RunController:
    """Deadline + cancel flag + checkpoint hook, consulted by the search
    engine at decision-round boundaries (see module doc for the exact
    call protocol)."""

    def __init__(
        self,
        *,
        deadline_s: Optional[float] = None,
        checkpoint_every: int = 0,
        checkpoint_fn: Optional[Callable[[dict], None]] = None,
        round_delay_s: float = 0.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        self._clock = clock
        self.deadline = clock() + deadline_s if deadline_s else None
        self.checkpoint_every = checkpoint_every
        self.checkpoint_fn = checkpoint_fn
        # deterministic fault injection: sleep this long after every round
        # (tests/benchmarks stretch a search so deadlines and SIGKILLs land
        # mid-run at controllable points; production leaves it at 0)
        self.round_delay_s = round_delay_s
        self._cancel = threading.Event()
        self.n_rounds = 0
        self.n_checkpoints = 0
        self.round_truncated = False
        self._ckpt_round = -1  # last round a checkpoint was written for

    # -- cancellation (thread-safe) ------------------------------------
    def cancel(self) -> None:
        self._cancel.set()

    @property
    def cancelled(self) -> bool:
        return self._cancel.is_set()

    def abort_round(self) -> bool:
        """Mid-round poll (engine/batch.py): True once cancelled — the
        engine may cut the round's remaining iterations.  Deadlines are
        deliberately NOT checked here (see module doc)."""
        if self._cancel.is_set():
            self.round_truncated = True
            return True
        return False

    # -- round-boundary protocol ---------------------------------------
    def begin_round(self) -> None:
        self.round_truncated = False

    def should_stop(self) -> Optional[str]:
        if self._cancel.is_set():
            return "cancelled"
        if self.deadline is not None and self._clock() >= self.deadline:
            return "deadline"
        return None

    def round_done(self, snapshot_thunk: Optional[Callable[[], dict]] = None) -> None:
        self.n_rounds += 1
        if self.round_delay_s:
            time.sleep(self.round_delay_s)
        if (
            snapshot_thunk is not None
            and self.checkpoint_every
            and self.n_rounds % self.checkpoint_every == 0
        ):
            self.checkpoint(snapshot_thunk)

    def checkpoint(self, snapshot_thunk: Optional[Callable[[], dict]]) -> bool:
        """Persist a snapshot through ``checkpoint_fn``; idempotent per
        round (a final interrupt checkpoint on a cadence round writes
        once).  Returns whether a checkpoint exists for this round."""
        if self.checkpoint_fn is None or snapshot_thunk is None:
            return False
        if self._ckpt_round == self.n_rounds:
            return True
        self.checkpoint_fn(snapshot_thunk())
        self.n_checkpoints += 1
        self._ckpt_round = self.n_rounds
        return True
