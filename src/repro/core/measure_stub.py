"""XLA-free measurement target for tests, the fleet gate, and sweep smokes.

``stub_measure`` has the exact signature the fleet dispatches to
(``request dict -> record dict``) but prices the plan with the analytic
roofline model instead of a subprocess XLA compile — deterministic,
jax-free, and microseconds instead of seconds.  The record carries NO
wall-clock fields, so a fleet run and a serial ``measure_cell`` run of
the same request produce byte-identical cache files (the perf-smoke
fleet gate's acceptance check).

Fault injection rides in ``req["extras"]["inject"]`` (transport-only —
never part of the cache key)::

    {"marker": "/tmp/x.marker", "kind": "kill"}            # SIGKILL self
    {"marker": "/tmp/y.marker", "kind": "sleep", "sleep_s": 5}

The injection fires exactly once: the first attempt creates the marker
file and then dies (or stalls past the watchdog deadline); the retry
sees the marker and measures normally.  That makes worker-death and
timeout recovery deterministic enough for CI.
"""
from __future__ import annotations

import os
import signal
import time

from repro.configs import get_config, get_shape
from repro.core.cost_model import AnalyticCostModel
from repro.core.space import MULTI_POD, SINGLE_POD, SchedulePlan


def _fire_injection(extras) -> None:
    inject = (extras or {}).get("inject")
    if not inject:
        return
    marker = inject["marker"]
    if os.path.exists(marker):
        return  # already fired — this is the retry; measure normally
    with open(marker, "w") as f:
        f.write(inject["kind"])
    if inject["kind"] == "kill":
        os.kill(os.getpid(), signal.SIGKILL)
    elif inject["kind"] == "sleep":
        time.sleep(float(inject.get("sleep_s", 60.0)))


def failing_measure(req: dict) -> dict:
    """Target that always fails — exercises the retry-exhaustion path."""
    raise RuntimeError("deliberate failure")


def stub_measure(req: dict) -> dict:
    """Deterministic analytic 'measurement' of one request dict."""
    _fire_injection(req.get("extras"))
    cfg = get_config(req["arch"])
    shape = get_shape(req["shape"])
    mspec = MULTI_POD if req["mesh"] == "multi" else SINGLE_POD
    plan = (
        SchedulePlan.from_dict(req["plan"])
        if req.get("plan") is not None
        else SchedulePlan()
    )
    t = AnalyticCostModel(cfg, shape, mspec).terms(plan)
    return {
        "arch": req["arch"],
        "shape": req["shape"],
        "mesh": req["mesh"],
        "devices": req.get("devices"),
        "plan": plan.to_dict(),
        "compute_s": t.compute_s,
        "memory_s": t.memory_s,
        "collective_s": t.collective_s,
        "step_s": t.step_s,
        "dominant": t.dominant,
        "mfu": t.mfu,
        "feasible": t.feasible,
        "source": "stub",
    }
