"""Learned cost model (paper §3): a small MLP trained on random COMPLETE
schedules, in pure JAX.

Reproduces the paper's observation (Fig. 1/2): a model trained on complete
schedules ranks complete schedules well but mis-ranks partial ones (their
default-completion features are off-distribution), which is what poisons
beam search at every depth.
"""
from __future__ import annotations

import random as _random
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import AnalyticCostModel
from repro.core.space import SchedulePlan, ScheduleSpace


def featurize(plan: SchedulePlan, space: ScheduleSpace) -> np.ndarray:
    """One-hot per stage + numeric knobs (log-scaled)."""
    feats: List[float] = []
    for stage in space.stages:
        val = getattr(plan, stage.name)
        for opt in stage.options:
            feats.append(1.0 if opt == val else 0.0)
    feats.append(np.log2(plan.microbatches))
    feats.append(np.log2(plan.attn_block[0]))
    feats.append(np.log2(plan.attn_block[1]))
    feats.append(np.log2(plan.scan_chunk))
    feats.append(plan.overlap)
    return np.asarray(feats, np.float32)


@dataclass
class LearnedCostModel:
    params: dict
    space: ScheduleSpace
    mean: float
    std: float
    n_evals: int = 0

    def cost(self, plan: SchedulePlan) -> float:
        self.n_evals += 1
        x = jnp.asarray(featurize(plan, self.space))
        y = _mlp_apply(self.params, x[None])[0, 0]
        return float(jnp.exp(y * self.std + self.mean))

    def partial_cost(self, actions, space) -> float:
        defaults = space.default_actions()
        full = list(actions) + defaults[len(actions):]
        return self.cost(space.plan_from_actions(full))


def _mlp_init(key, d_in: int, hidden: int = 64) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s = lambda k, a, b: jax.random.normal(k, (a, b)) * (2.0 / a) ** 0.5
    return {
        "w1": s(k1, d_in, hidden), "b1": jnp.zeros(hidden),
        "w2": s(k2, hidden, hidden), "b2": jnp.zeros(hidden),
        "w3": s(k3, hidden, 1), "b3": jnp.zeros(1),
    }


def _mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(x @ p["w1"] + p["b1"])
    h = jax.nn.relu(h @ p["w2"] + p["b2"])
    return h @ p["w3"] + p["b3"]


def train_learned_cost(
    space: ScheduleSpace,
    oracle: AnalyticCostModel,
    *,
    n_samples: int = 512,
    steps: int = 400,
    lr: float = 3e-3,
    seed: int = 0,
) -> LearnedCostModel:
    """Train on random complete schedules against the oracle's cost
    (the paper trains against measured runtimes of random programs)."""
    rng = _random.Random(seed)
    plans = [space.random_plan(rng) for _ in range(n_samples)]
    X = np.stack([featurize(p, space) for p in plans])
    y = np.asarray([oracle.cost(p) for p in plans], np.float32)
    logy = np.log(np.maximum(y, 1e-9))
    mean, std = float(logy.mean()), float(logy.std() + 1e-6)
    Y = (logy - mean) / std

    params = _mlp_init(jax.random.PRNGKey(seed), X.shape[1])
    Xj, Yj = jnp.asarray(X), jnp.asarray(Y)[:, None]

    @jax.jit
    def step(params, _):
        def loss_fn(p):
            pred = _mlp_apply(p, Xj)
            return jnp.mean((pred - Yj) ** 2)

        loss, g = jax.value_and_grad(loss_fn)(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        return params, loss

    params, losses = jax.lax.scan(step, params, jnp.arange(steps))
    return LearnedCostModel(params=params, space=space, mean=mean, std=std)


def ranking_correlation(
    model, oracle: AnalyticCostModel, space: ScheduleSpace, *,
    n: int = 128, seed: int = 1, partial_depth: Optional[int] = None,
) -> float:
    """Spearman rank correlation model-vs-oracle on complete schedules, or on
    partial prefixes (default-completed) when ``partial_depth`` is given."""
    rng = _random.Random(seed)
    preds, golds = [], []
    for _ in range(n):
        actions = space.random_actions(rng)
        if partial_depth is not None:
            prefix = actions[:partial_depth]
            defaults = space.default_actions()
            full_actions = prefix + defaults[len(prefix):]
            # the model scores its (misleading) default completion; the
            # oracle scores the TRUE eventual schedule (the random one)
            preds.append(model.cost(space.plan_from_actions(full_actions)))
            golds.append(oracle.cost(space.plan_from_actions(actions)))
        else:
            plan = space.plan_from_actions(actions)
            preds.append(model.cost(plan))
            golds.append(oracle.cost(plan))
    return _spearman(np.asarray(preds), np.asarray(golds))


def _spearman(a: np.ndarray, b: np.ndarray) -> float:
    ra = np.argsort(np.argsort(a)).astype(np.float64)
    rb = np.argsort(np.argsort(b)).astype(np.float64)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra**2).sum() * (rb**2).sum())
    return float((ra * rb).sum() / denom) if denom else 0.0
