"""Learned cost model (paper §3): a small MLP trained on COMPLETE
schedules, in pure JAX.

Two roles in this repo:

* **Reproduction** (Fig. 1/2): a model trained on complete schedules ranks
  complete schedules well but mis-ranks partial ones (their
  default-completion features are off-distribution), which is what poisons
  beam search at every depth — see ``benchmarks/fig12_partial_cost.py``.
* **Serving** (engine layer): the same MLP is refit online on
  transposition-cache contents and prices cache-miss batches in one
  batched forward pass — see ``repro.core.engine.serving`` and
  ``docs/architecture.md`` for the serving seam.

The forward pass is jitted ONCE at module level (``_mlp_apply_jit``) and
reused by both the scalar and batched entry points; batches are padded to
the next power of two so the number of distinct compiled shapes is
logarithmic in the largest batch ever seen, not linear in the number of
distinct batch sizes.
"""
from __future__ import annotations

import random as _random
from dataclasses import dataclass
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import AnalyticCostModel, PlanColumns
from repro.core.space import SchedulePlan, ScheduleSpace


def featurize(plan: SchedulePlan, space: ScheduleSpace) -> np.ndarray:
    """One-hot per stage + numeric knobs (log-scaled).

    Width = sum(len(stage.options) for the cell's stages) + 4 log-scaled
    knobs + the overlap scalar; exactly one 1.0 inside each stage's one-hot
    block (tested in ``tests/test_learned_cost.py``)."""
    feats: List[float] = []
    for stage in space.stages:
        val = getattr(plan, stage.name)
        for opt in stage.options:
            feats.append(1.0 if opt == val else 0.0)
    feats.append(np.log2(plan.microbatches))
    feats.append(np.log2(plan.attn_block[0]))
    feats.append(np.log2(plan.attn_block[1]))
    feats.append(np.log2(plan.scan_chunk))
    feats.append(plan.overlap)
    return np.asarray(feats, np.float32)


def featurize_batch(
    plans: Sequence[SchedulePlan], space: ScheduleSpace
) -> np.ndarray:
    """``stack([featurize(p) for p in plans])`` as one (N, d) f32 matrix."""
    return np.stack([featurize(p, space) for p in plans])


def featurize_columns(cols: PlanColumns, space: ScheduleSpace) -> np.ndarray:
    """``featurize_batch`` from a ``PlanColumns`` encoding — element-for-
    element equal to featurizing the plan objects (tested), built entirely
    from the same structure-of-arrays the analytic columnar kernel prices.
    This is what lets the serving layer encode a miss batch ONCE and hand
    the encoding to whichever cost backend wins: the MLP featurizes the
    columns, the analytic kernel prices them, no per-plan re-walk either
    way."""
    blocks: List[np.ndarray] = []
    for stage in space.stages:
        for onehot in cols.stage_onehots(stage):
            blocks.append(onehot.astype(np.float32))
    blocks.append(np.log2(cols.microbatches).astype(np.float32))
    blocks.append(np.log2(cols.bq).astype(np.float32))
    blocks.append(np.log2(cols.bkv).astype(np.float32))
    blocks.append(np.log2(cols.scan_chunk).astype(np.float32))
    blocks.append(cols.overlap.astype(np.float32))
    return np.stack(blocks, axis=1)


def _pad_len(n: int) -> int:
    """Next power of two ≥ n: bounds the jit compile-cache growth."""
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


@dataclass
class LearnedCostModel:
    params: dict
    space: ScheduleSpace
    mean: float
    std: float
    n_evals: int = 0
    version: int = 1  # fit generation (bumped by the online trainer)
    n_forward: int = 0  # jitted MLP invocations; a whole batch counts ONCE

    def cost(self, plan: SchedulePlan) -> float:
        return self.cost_batch([plan])[0]

    def cost_batch(self, plans: Sequence[SchedulePlan]) -> List[float]:
        """Price the whole batch in ONE jitted forward pass.

        Contract: ``cost_batch(plans) ≈ [cost(p) for p in plans]`` to
        float32 round-off (XLA may fuse the padded matmul differently per
        batch shape, so this seam — unlike the analytic ``cost_batch`` — is
        an approximate-parity contract, not a bit-exact one)."""
        if len(plans) == 0:
            return []
        return self._predict(featurize_batch(plans, self.space))

    def cost_columns(self, cols: PlanColumns) -> List[float]:
        """``cost_batch`` from a shared ``PlanColumns`` encoding (the
        serving seam: one encode per miss batch, whichever backend
        prices it).  Same values as ``cost_batch(cols.plans)`` — the
        feature matrix is element-identical (``featurize_columns``)."""
        if cols.n == 0:
            return []
        return self._predict(featurize_columns(cols, self.space))

    def _predict(self, X: np.ndarray) -> List[float]:
        """One jitted forward pass over a feature matrix, padded to the
        next power of two so compiled shapes stay logarithmic."""
        n = X.shape[0]
        pad = _pad_len(n)
        if pad > n:
            X = np.concatenate(
                [X, np.zeros((pad - n, X.shape[1]), np.float32)]
            )
        y = np.asarray(_mlp_apply_jit(self.params, X))[:n, 0]
        self.n_evals += n
        self.n_forward += 1
        out = np.exp(y.astype(np.float64) * self.std + self.mean)
        return [float(v) for v in out]

    def partial_cost(self, actions, space) -> float:
        defaults = space.default_actions()
        full = list(actions) + defaults[len(actions):]
        return self.cost(space.plan_from_actions(full))


def _mlp_init(key, d_in: int, hidden: int = 64) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s = lambda k, a, b: jax.random.normal(k, (a, b)) * (2.0 / a) ** 0.5
    return {
        "w1": s(k1, d_in, hidden), "b1": jnp.zeros(hidden),
        "w2": s(k2, hidden, hidden), "b2": jnp.zeros(hidden),
        "w3": s(k3, hidden, 1), "b3": jnp.zeros(1),
    }


def _mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(x @ p["w1"] + p["b1"])
    h = jax.nn.relu(h @ p["w2"] + p["b2"])
    return h @ p["w3"] + p["b3"]


# jitted once, reused by every model instance; recompiles only per input
# SHAPE (batches are padded to powers of two by cost_batch)
_mlp_apply_jit = jax.jit(_mlp_apply)


@partial(jax.jit, static_argnames=("steps",))
def _fit_params(params, X, Y, W, steps: int, lr):
    """``steps`` of full-batch weighted-MSE gradient descent (one compiled
    scan; ``W`` masks padding rows so datasets can pad to power-of-two
    sizes without corrupting the loss)."""

    def step(p, _):
        def loss_fn(p):
            err = (_mlp_apply(p, X) - Y) ** 2
            return jnp.sum(err[:, 0] * W) / jnp.sum(W)

        loss, g = jax.value_and_grad(loss_fn)(p)
        p = jax.tree.map(lambda a, gg: a - lr * gg, p, g)
        return p, loss

    params, _ = jax.lax.scan(step, params, jnp.arange(steps))
    return params


def fit_learned_cost(
    space: ScheduleSpace,
    plans: Sequence[SchedulePlan],
    costs: Sequence[float],
    *,
    params: Optional[dict] = None,
    steps: int = 200,
    lr: float = 3e-3,
    seed: int = 0,
) -> LearnedCostModel:
    """Fit (or warm-start refit, via ``params``) the MLP on explicit
    ``(plan, cost)`` pairs.  Normalization (log-cost mean/std) is recomputed
    from THIS dataset — the per-fit renormalization the online trainer
    requires as the cache's cost distribution shifts during search."""
    X = featurize_batch(plans, space)
    logy = np.log(np.maximum(np.asarray(costs, np.float32), 1e-9))
    mean, std = float(logy.mean()), float(logy.std() + 1e-6)
    Y = ((logy - mean) / std).astype(np.float32)
    n = X.shape[0]
    pad = _pad_len(n)
    W = np.zeros(pad, np.float32)
    W[:n] = 1.0
    if pad > n:
        X = np.concatenate([X, np.zeros((pad - n, X.shape[1]), np.float32)])
        Y = np.concatenate([Y, np.zeros(pad - n, np.float32)])
    if params is None:
        params = _mlp_init(jax.random.PRNGKey(seed), X.shape[1])
    params = _fit_params(params, X, Y[:, None], W, steps, lr)
    return LearnedCostModel(params=params, space=space, mean=mean, std=std)


def train_learned_cost(
    space: ScheduleSpace,
    oracle: AnalyticCostModel,
    *,
    n_samples: int = 512,
    steps: int = 400,
    lr: float = 3e-3,
    seed: int = 0,
) -> LearnedCostModel:
    """Train on random complete schedules against the oracle's cost
    (the paper trains against measured runtimes of random programs).
    Labels price through ``cost_batch`` — one columnar-kernel pass for
    the whole training set, values identical to a scalar sweep."""
    rng = _random.Random(seed)
    plans = [space.random_plan(rng) for _ in range(n_samples)]
    y = oracle.cost_batch(plans)
    return fit_learned_cost(space, plans, y, steps=steps, lr=lr, seed=seed)


def ranking_correlation(
    model, oracle: AnalyticCostModel, space: ScheduleSpace, *,
    n: int = 128, seed: int = 1, partial_depth: Optional[int] = None,
) -> float:
    """Spearman rank correlation model-vs-oracle on complete schedules, or on
    partial prefixes (default-completed) when ``partial_depth`` is given.

    Both legs price through the batch seam (``cost_batch`` — one MLP
    forward pass / one columnar kernel pass for all ``n`` samples), the
    same path the fig-12 artifact and the serving layer exercise; models
    without a batch entry point fall back to a scalar sweep."""
    rng = _random.Random(seed)
    pred_plans, gold_plans = [], []
    for _ in range(n):
        actions = space.random_actions(rng)
        if partial_depth is not None:
            prefix = actions[:partial_depth]
            defaults = space.default_actions()
            full_actions = prefix + defaults[len(prefix):]
            # the model scores its (misleading) default completion; the
            # oracle scores the TRUE eventual schedule (the random one)
            pred_plans.append(space.plan_from_actions(full_actions))
            gold_plans.append(space.plan_from_actions(actions))
        else:
            plan = space.plan_from_actions(actions)
            pred_plans.append(plan)
            gold_plans.append(plan)

    def price(m, plans):
        batch = getattr(m, "cost_batch", None)
        if batch is not None:
            return batch(plans)
        return [m.cost(p) for p in plans]

    preds = price(model, pred_plans)
    golds = price(oracle, gold_plans)
    return _spearman(np.asarray(preds), np.asarray(golds))


def _spearman(a: np.ndarray, b: np.ndarray) -> float:
    ra = np.argsort(np.argsort(a)).astype(np.float64)
    rb = np.argsort(np.argsort(b)).astype(np.float64)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra**2).sum() * (rb**2).sum())
    return float((ra * rb).sum() / denom) if denom else 0.0
