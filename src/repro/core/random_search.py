"""Random search baseline (paper §5: no cost model; best *measured* schedule
within the time budget — ours measures via the compile-based evaluator when
given one, else falls back to the cost model).

Cost-model evaluation routes through ``mdp.terminal_cost`` (not the cost
model directly) so a ``CachedMDP``-wrapped MDP dedupes re-sampled schedules
for free; sampled plans and costs are unchanged (``random_actions`` consumes
the RNG exactly as ``random_plan`` did)."""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.engine import CachedMDP
from repro.core.ensemble import TuneResult
from repro.core.mdp import ScheduleMDP


def random_search(
    mdp: ScheduleMDP,
    *,
    n_samples: int = 256,
    time_budget_s: Optional[float] = None,
    measure_fn: Optional[Callable] = None,
    seed: int = 0,
) -> TuneResult:
    t0 = time.perf_counter()
    rng = random.Random(seed)
    best_cost = float("inf")
    best_state = None
    n_meas = 0
    i = 0
    while True:
        if time_budget_s is not None:
            if time.perf_counter() - t0 > time_budget_s:
                break
        elif i >= n_samples:
            break
        state = tuple(mdp.space.random_actions(rng))
        if measure_fn is not None:
            c = measure_fn(mdp.plan(state))
        else:
            c = mdp.terminal_cost(state)
        n_meas += 1
        if c < best_cost:
            best_cost, best_state = c, state
        i += 1
    return TuneResult(
        plan=mdp.plan(best_state),
        cost=mdp.terminal_cost(best_state),
        measured=best_cost if measure_fn else None,
        n_evals=getattr(mdp.cost_model, "n_evals", 0),
        n_measurements=n_meas if measure_fn else 0,
        wall_time_s=time.perf_counter() - t0,
        algo="random",
    )


# ---------------------------------------------------------------------------
# SearchBackend adapter (repro.core.engine.backend protocol)
# ---------------------------------------------------------------------------
@dataclass
class RandomBackend:
    n_samples: int = 256
    name: str = "random"

    def run(self, mdp, *, seed=0, time_budget_s=None, measure_fn=None,
            cache: bool = False, **_) -> TuneResult:
        if cache and not isinstance(mdp, CachedMDP):
            mdp = CachedMDP(mdp)
        res = random_search(
            mdp,
            n_samples=self.n_samples,
            time_budget_s=time_budget_s,
            measure_fn=measure_fn,
            seed=seed,
        )
        if isinstance(mdp, CachedMDP):
            res.cache_hits = mdp.cache.hits
            res.cache_misses = mdp.cache.misses
        return res
