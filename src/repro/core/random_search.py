"""Random search baseline (paper §5: no cost model; best *measured* schedule
within the time budget — ours measures via the compile-based evaluator when
given one, else falls back to the cost model)."""
from __future__ import annotations

import random
import time
from typing import Callable, Optional

from repro.core.ensemble import TuneResult
from repro.core.mdp import ScheduleMDP


def random_search(
    mdp: ScheduleMDP,
    *,
    n_samples: int = 256,
    time_budget_s: Optional[float] = None,
    measure_fn: Optional[Callable] = None,
    seed: int = 0,
) -> TuneResult:
    t0 = time.perf_counter()
    rng = random.Random(seed)
    evaluate = measure_fn or mdp.cost_model.cost
    best_cost = float("inf")
    best_plan = None
    n_meas = 0
    i = 0
    while True:
        if time_budget_s is not None:
            if time.perf_counter() - t0 > time_budget_s:
                break
        elif i >= n_samples:
            break
        plan = mdp.space.random_plan(rng)
        c = evaluate(plan)
        n_meas += 1
        if c < best_cost:
            best_cost, best_plan = c, plan
        i += 1
    return TuneResult(
        plan=best_plan,
        cost=mdp.cost_model.cost(best_plan),
        measured=best_cost if measure_fn else None,
        n_evals=getattr(mdp.cost_model, "n_evals", 0),
        n_measurements=n_meas if measure_fn else 0,
        wall_time_s=time.perf_counter() - t0,
        algo="random",
    )
