"""Real measurement: compile the actual step on the target mesh and derive
roofline terms from the XLA artifact.

This is the paper's "real execution time measurement" (§4.2): expensive
(an XLA compile in a fresh subprocess, seconds) versus the ~100 µs analytic
cost model, and authoritative — FLOPs/bytes come from ``cost_analysis()``
of the compiled SPMD module and collective bytes from parsing the
post-optimization HLO.  The subprocess is required because the production
mesh needs ``xla_force_host_platform_device_count=512``, which must be set
before jax initializes (and must NOT leak into tests/benches).

Conventions (documented in EXPERIMENTS.md):
* ``cost_analysis()`` FLOPs/bytes are per-device for the SPMD program;
  whole-fleet totals multiply by ``chips``.
* collective bytes = Σ operand bytes of all-reduce/all-gather/
  reduce-scatter/all-to-all/collective-permute ops in the per-device HLO.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import subprocess
import sys
import tempfile
import uuid
from typing import Dict, Optional

from repro.core.cost_model import HW, HardwareSpec
from repro.core.space import SchedulePlan

# v2: the cache key now includes ``devices`` (a pre-fix key collapsed all
# device counts of a cell onto one record) — the versioned subdirectory
# namespaces the corrected entries so a stale pre-fix cache is never served.
CACHE_DIR = os.path.join(
    os.environ.get(
        "REPRO_MEASURE_CACHE",
        os.path.join(os.getcwd(), "experiments", "measure_cache"),
    ),
    "v2",
)

# the subprocess module a measurement spawns; tests point this at
# ``repro.launch.dryrun_stub`` (same CLI, analytic record, no XLA compile)
DRYRUN_MODULE = "repro.launch.dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# op line looks like:
#   %all-gather.74 = f32[2048,128]{1,0} all-gather(%x), channel_id=1,
#       replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}, ...
# (post-optimization HLO prints operands WITHOUT type annotations, so operand
# bytes are derived from the OUTPUT shape + the op's semantics + group size)
_COLL_LINE_RE = re.compile(
    r"=\s+((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    m = _GROUPS_V1_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective payload from post-SPMD optimized HLO.

    Returns {kind: operand_bytes} plus ``_wire`` (ring wire-byte estimate per
    device) and ``_counts``.  Operand bytes per op:
      all-reduce / all-to-all / collective-permute : output bytes
      all-gather                                   : output / group
      reduce-scatter                               : output × group
    Ring wire bytes per device:
      all-reduce: 2·S·(g-1)/g   all-gather/reduce-scatter: S_full·(g-1)/g
      all-to-all: S·(g-1)/g     collective-permute: S
    """
    out: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_LINE_RE.search(line)
        if m is None:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out_bytes = sum(_shape_bytes(t, d) for t, d in _SHAPE_RE.findall(shape_str))
        g = max(_group_size(line), 1)
        if kind == "all-gather":
            operand = out_bytes / g
            wire += out_bytes * (g - 1) / g
        elif kind == "reduce-scatter":
            operand = out_bytes * g
            wire += out_bytes * (g - 1)
        elif kind == "all-reduce":
            operand = out_bytes
            wire += 2 * out_bytes * (g - 1) / g
        elif kind == "all-to-all":
            operand = out_bytes
            wire += out_bytes * (g - 1) / g
        else:  # collective-permute
            operand = out_bytes
            wire += out_bytes
        out[kind] = out.get(kind, 0) + operand
        counts[kind] = counts.get(kind, 0) + 1
    out["_counts"] = counts  # type: ignore[assignment]
    out["_wire"] = wire  # type: ignore[assignment]
    return out


def combine_terms(
    flops_total: float,
    hbm_bytes_total: float,
    coll_bytes_per_chip: float,
    chips: int,
    overlap: float,
    hw: HardwareSpec = HW,
) -> Dict[str, float]:
    compute_s = flops_total / (chips * hw.peak_flops)
    memory_s = hbm_bytes_total / (chips * hw.hbm_bw)
    collective_s = coll_bytes_per_chip / hw.link_bw
    step_s = max(compute_s, memory_s) + (1.0 - overlap) * collective_s
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "step_s": step_s,
    }


# ---------------------------------------------------------------------------
# Subprocess measurement client (with on-disk cache)
# ---------------------------------------------------------------------------
# Cache-key contract (docs/architecture.md §8): the key is a content hash
# of EVERY input that can change the record — key version, arch, shape,
# mesh, DEVICE COUNT, and the full plan dict.  ``devices`` was missing
# before v2: measuring the same (arch, shape, mesh) at a different forced
# device count silently returned the first count's record.
KEY_VERSION = 2


def _cache_key(
    arch: str, shape: str, mesh: str, plan: Optional[dict],
    devices: Optional[int] = None,
) -> str:
    blob = json.dumps(
        [KEY_VERSION, arch, shape, mesh, devices, plan], sort_keys=True
    )
    return hashlib.sha1(blob.encode()).hexdigest()[:20]


def make_request(
    arch: str,
    shape: str,
    mesh: str = "single",
    plan=None,
    devices: Optional[int] = None,
    timeout: float = 1800.0,
    module: Optional[str] = None,
    extras: Optional[dict] = None,
) -> dict:
    """Normalize one measurement request to the plain-dict form every
    measurement path (serial ``measure_cell``, the fleet, the sweep
    harness) shares.  ``extras`` is transport-only — it never enters the
    cache key (fault-injection hooks for tests live there)."""
    if plan is not None and not isinstance(plan, dict):
        plan = plan.to_dict()
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "plan": plan,
        "devices": devices, "timeout": timeout,
        "module": module or DRYRUN_MODULE, "extras": extras,
    }


def request_key(req: dict) -> str:
    return _cache_key(
        req["arch"], req["shape"], req["mesh"], req["plan"],
        req.get("devices"),
    )


def load_record(path: str) -> Optional[dict]:
    """Validated cache read.  A corrupt or truncated entry (a crashed
    writer, a pre-atomic-rename cache) is QUARANTINED — deleted so the
    next call re-measures — instead of being served as a hit or raising
    on every lookup forever."""
    try:
        with open(path) as f:
            rec = json.load(f)
    except FileNotFoundError:
        return None
    except (OSError, ValueError):
        rec = None
    if isinstance(rec, dict) and "step_s" in rec:
        return rec
    try:
        os.remove(path)
    except OSError:
        pass
    return None


def write_record(path: str, record: dict) -> None:
    """Atomic publish: write to a sibling tmp file, ``os.replace`` into
    place.  Readers can never observe a partial record."""
    tmp = f"{path}.tmp.{os.getpid()}.{uuid.uuid4().hex[:8]}"
    try:
        with open(tmp, "w") as f:
            json.dump(record, f, indent=1)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def _tail(text, n: int = 2000) -> str:
    return (text or "")[-n:]


def measure_request(req: dict) -> dict:
    """Pure measurement of one request: spawn the dryrun subprocess, point
    its ``--json-out`` at a PRIVATE tmp file, and return the parsed
    record.  No cache interaction and no on-disk residue on any failure
    path — a killed or timed-out compile can never poison a cache entry,
    because the final cache path is only ever written by the caller's
    atomic ``write_record``."""
    arch, shape, mesh = req["arch"], req["shape"], req["mesh"]
    timeout = req.get("timeout") or 1800.0
    tmp = os.path.join(
        tempfile.gettempdir(), f"repro-measure-{os.getpid()}-{uuid.uuid4().hex}.json"
    )
    cmd = [
        sys.executable,
        "-m",
        req.get("module") or DRYRUN_MODULE,
        "--arch", arch,
        "--shape", shape,
        "--mesh", mesh,
        "--json-out", tmp,
    ]
    if req.get("plan") is not None:
        cmd += ["--plan-json", json.dumps(req["plan"])]
    if req.get("devices") is not None:
        cmd += ["--devices", str(req["devices"])]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in [env.get("PYTHONPATH"), _src_path()] if p]
    )
    try:
        try:
            proc = subprocess.run(
                cmd, capture_output=True, text=True, timeout=timeout, env=env
            )
        except subprocess.TimeoutExpired as e:
            # surface the same RuntimeError path as a failed compile, with
            # whatever partial output the subprocess produced
            out = e.stdout.decode() if isinstance(e.stdout, bytes) else e.stdout
            err = e.stderr.decode() if isinstance(e.stderr, bytes) else e.stderr
            raise RuntimeError(
                f"measurement timed out after {timeout:.0f}s for "
                f"{arch}×{shape}×{mesh}:\n"
                f"stdout: {_tail(out)}\nstderr: {_tail(err)}"
            ) from None
        rec = load_record(tmp) if proc.returncode == 0 else None
        if rec is None:
            raise RuntimeError(
                f"measurement failed for {arch}×{shape}×{mesh} "
                f"(exit {proc.returncode}):\n"
                f"stdout: {_tail(proc.stdout)}\nstderr: {_tail(proc.stderr)}"
            )
        return rec
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def measure_cell(
    arch: str,
    shape: str,
    mesh: str = "single",
    plan: Optional[SchedulePlan] = None,
    cache_dir: str = CACHE_DIR,
    timeout: float = 1800.0,
    devices: Optional[int] = None,
    target=None,
) -> dict:
    """Compile (arch, shape, plan) on the target mesh in a subprocess and
    return the measured roofline record.  Results are cached on disk —
    re-measuring a schedule is free, exactly like the paper's compiled-
    binary cache.  Corrupt cache entries are quarantined and re-measured;
    the cache file itself is only ever written atomically.  ``target``
    overrides the measurement function (default: the real subprocess
    ``measure_request``; tests pass an XLA-free stub)."""
    req = make_request(arch, shape, mesh, plan, devices, timeout)
    key = request_key(req)
    os.makedirs(cache_dir, exist_ok=True)
    path = os.path.join(cache_dir, key + ".json")
    rec = load_record(path)
    if rec is not None:
        return rec
    rec = (target or measure_request)(req)
    write_record(path, rec)
    # return the JSON round-trip of what was stored, so a fresh
    # measurement and a later cache hit are structurally identical
    # (e.g. tuples in the plan normalize to lists)
    return load_record(path)


def measured_step_time(
    arch: str, shape: str, mesh: str = "single", plan: Optional[SchedulePlan] = None,
    **kw,
) -> float:
    return measure_cell(arch, shape, mesh, plan, **kw)["step_s"]


def make_measure_fn(arch: str, shape: str, mesh: str = "single", **kw):
    def fn(plan: SchedulePlan) -> float:
        return measured_step_time(arch, shape, mesh, plan, **kw)

    return fn


def _src_path() -> str:
    here = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    return here
