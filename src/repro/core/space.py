"""The TPU schedule space: ProTuner's MDP states/actions, re-targeted.

The paper schedules a Halide pipeline stage-by-stage (tiling, vectorize,
parallel, compute-at).  Here a *schedule* is the complete set of distribution
and kernel decisions for one (architecture × input-shape × mesh) cell; the
MDP assigns one decision **stage** at a time, in a fixed order, so a state is
a prefix of decisions and a terminal state is a complete ``SchedulePlan`` —
only terminal states are costed, exactly as in the paper.

Stages that are inapplicable to a cell (``moe_mode`` on a dense arch,
``microbatches`` on a decode shape) collapse to their single legal action, so
every cell presents a well-formed MDP (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import itertools
import random as _random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import InputShape, ModelConfig


@dataclass(frozen=True)
class MeshSpec:
    """Abstract mesh: axis names + sizes (no jax device state needed)."""

    names: Tuple[str, ...]
    shape: Tuple[int, ...]

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def axis(self, name: str) -> int:
        return self.shape[self.names.index(name)]

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.names


SINGLE_POD = MeshSpec(("data", "model"), (16, 16))
MULTI_POD = MeshSpec(("pod", "data", "model"), (2, 16, 16))


@dataclass(frozen=True)
class SchedulePlan:
    """A complete schedule: one value per stage."""

    batch_axes: str = "data"  # "data" | "pod_data"
    param_strategy: str = "fsdp_tp"  # replicated | tp | fsdp | fsdp_tp
    mixer_tp: bool = True  # shard attention heads / mamba d_inner over model
    seq_shard: bool = False  # sequence-parallel activations / KV-cache seq
    ffn_tp: bool = True
    moe_mode: str = "dense"  # ep | tp | dense (dense = replicated experts)
    vocab_shard: bool = True
    remat: str = "dots"  # none | dots | full
    microbatches: int = 1
    attn_block: Tuple[int, int] = (256, 256)  # flash (block_q, block_kv)
    scan_chunk: int = 128  # mamba time chunk
    grad_comm: str = "fp32"  # fp32 | int8 | rs_ag
    overlap: float = 0.5  # collective/compute overlap factor
    opt_dtype: str = "float32"  # float32 | int8 Adam moments
    kv_dtype: str = "bf16"  # bf16 | int8 KV cache (decode shapes)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "SchedulePlan":
        d = dict(d)
        if isinstance(d.get("attn_block"), list):
            d["attn_block"] = tuple(d["attn_block"])
        return SchedulePlan(**d)


@dataclass(frozen=True)
class Stage:
    name: str
    options: Tuple


class ScheduleSpace:
    """Per-cell stage list; builds plans from action sequences."""

    def __init__(self, cfg: ModelConfig, shape: InputShape, mesh: MeshSpec):
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        self.stages: List[Stage] = self._build_stages()
        self._default_actions: Optional[List[int]] = None

    # -- MDP geometry --------------------------------------------------------
    def _build_stages(self) -> List[Stage]:
        cfg, shape, mesh = self.cfg, self.shape, self.mesh
        train = shape.kind == "train"
        st: List[Stage] = []

        st.append(
            Stage(
                "batch_axes",
                ("data", "pod_data") if mesh.multi_pod else ("data",),
            )
        )
        if train:
            st.append(Stage("param_strategy", ("replicated", "tp", "fsdp", "fsdp_tp")))
        else:
            # inference: no optimizer state; "tp2d" shards weights over BOTH
            # mesh axes (gather-on-use) — required for ≥70B archs and for
            # batch-1 long-context decode where the data axis is idle.
            st.append(Stage("param_strategy", ("replicated", "tp", "tp2d")))
        if cfg.is_attention_free or cfg.n_heads > 0:
            st.append(Stage("mixer_tp", (False, True)))
        st.append(Stage("seq_shard", (False, True)))
        st.append(Stage("ffn_tp", (False, True) if cfg.d_ff else (False,)))
        st.append(
            Stage("moe_mode", ("ep", "tp", "dense") if cfg.is_moe else ("dense",))
        )
        st.append(Stage("vocab_shard", (False, True)))
        st.append(Stage("remat", ("none", "dots", "full") if train else ("none",)))
        st.append(
            Stage(
                "microbatches",
                (1, 2, 4, 8, 16) if train else (1,),
            )
        )
        if cfg.n_heads > 0 and shape.kind != "decode":
            st.append(
                Stage(
                    "attn_block",
                    tuple(itertools.product((128, 256, 512), (128, 256, 512))),
                )
            )
        if cfg.is_ssm and shape.kind != "decode":
            st.append(Stage("scan_chunk", (64, 128, 256)))
        if shape.kind == "decode" and cfg.n_heads > 0:
            st.append(Stage("kv_dtype", ("bf16", "int8")))
        if train:
            st.append(Stage("grad_comm", ("fp32", "int8", "rs_ag")))
        st.append(Stage("overlap", (0.0, 0.5, 0.9)))
        if train:
            st.append(Stage("opt_dtype", ("float32", "int8")))
        return st

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def n_complete(self) -> int:
        n = 1
        for s in self.stages:
            n *= len(s.options)
        return n

    def n_actions(self, depth: int) -> int:
        return len(self.stages[depth].options)

    # -- plan construction ---------------------------------------------------
    def plan_from_actions(self, actions: Sequence[int]) -> SchedulePlan:
        assert len(actions) == self.n_stages, (len(actions), self.n_stages)
        kv = {
            s.name: s.options[a] for s, a in zip(self.stages, actions)
        }
        return SchedulePlan(**{**_plan_defaults(self), **kv})

    def default_actions(self) -> List[int]:
        """The paper-faithful baseline plan's action indices (a sane default
        schedule, analogous to Halide's master autoscheduler output).

        Memoized per space and returned by reference: the default
        completion is the hot constant of every ``partial_cost`` — beam
        and greedy sweeps call it at every depth — so rebuilding the
        default ``SchedulePlan`` per call was pure overhead.  Treat the
        returned list as read-only (every in-repo caller copies via
        slicing/concatenation)."""
        if self._default_actions is None:
            base = _plan_defaults(self)
            default = SchedulePlan(**base)
            out = []
            for s in self.stages:
                want = getattr(default, s.name)
                out.append(s.options.index(want) if want in s.options else 0)
            self._default_actions = out
        return self._default_actions

    def random_actions(self, rng: _random.Random) -> List[int]:
        return [rng.randrange(len(s.options)) for s in self.stages]

    def random_plan(self, rng: _random.Random) -> SchedulePlan:
        return self.plan_from_actions(self.random_actions(rng))


def _plan_defaults(space: ScheduleSpace) -> dict:
    """Values for stages absent from this cell's MDP (single legal action)."""
    cfg, shape, mesh = space.cfg, space.shape, space.mesh
    train = shape.kind == "train"
    # big models can't replicate the model axis at inference: default to 2D
    big = cfg.param_count() * 2 / mesh.axis("model") > 8 * 2**30
    small_batch = shape.global_batch < mesh.axis("data")
    return dict(
        batch_axes="pod_data" if mesh.multi_pod else "data",
        param_strategy="fsdp_tp" if train else ("tp2d" if (big or small_batch) else "tp"),
        mixer_tp=True,
        ffn_tp=bool(cfg.d_ff),
        moe_mode="ep" if cfg.is_moe else "dense",
        vocab_shard=True,
        remat="dots" if train else "none",
        microbatches=8 if train else 1,
        seq_shard=bool(not train and small_batch),
        attn_block=(256, 256),
        scan_chunk=128,
        grad_comm="fp32",
        overlap=0.5,
        opt_dtype="float32",
        kv_dtype="bf16",
    )
