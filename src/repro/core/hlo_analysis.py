"""Mini HLO cost analysis with correct while-loop trip-count folding.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop body ONCE
(verified: a scan of 10 matmuls reports the FLOPs of 1), which would make
every scanned-layer model's roofline meaningless.  Instead of unrolling
(a 40-layer × 8-microbatch unroll took >9 min to compile), we parse the
post-optimization HLO text ourselves:

* computations are parsed into per-computation symbol tables (every value
  definition line carries its shape);
* ``while`` ops carry ``backend_config={"known_trip_count":{"n": K}}`` —
  multipliers propagate through nested loops / calls;
* dot FLOPs = 2 · |out| · |contracting dims| (looked up from operand shapes);
* collective payload/wire bytes per kind (ring formulas), multiplied by the
  enclosing loops' trip counts;
* HBM byte traffic = Σ (operand + output bytes) over materialized ops
  (fusion interiors excluded — fused intermediates never touch HBM),
  matching XLA's own "bytes accessed" convention.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_DEF_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE_TOK = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPCODE_RE = re.compile(r"^\s*((?:\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)+)\s+([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BATCH = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


@dataclass
class Op:
    name: str
    opcode: str
    out_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    raw: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    is_entry: bool
    shapes: Dict[str, Tuple[str, Tuple[int, ...]]] = field(default_factory=dict)
    ops: List[Op] = field(default_factory=list)


def _parse_shapes(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    return [
        (t, tuple(int(x) for x in dims.split(",") if x))
        for t, dims in _SHAPE_TOK.findall(s)
    ]


def _nbytes(shape: Tuple[str, Tuple[int, ...]]) -> int:
    t, dims = shape
    b = _DTYPE_BYTES.get(t, 0)
    n = 1
    for d in dims:
        n *= d
    return n * b


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line)
            if m:
                cur = Computation(m.group(2), bool(m.group(1)))
                # parameter shapes from the header signature
                sig = line[line.index("(") + 1 : line.rindex(")->") if ")->" in line else line.rindex(") ->")]
                for pm in re.finditer(r"([\w\.\-]+):\s*([a-z0-9]+\[[0-9,]*\])", sig):
                    shapes = _parse_shapes(pm.group(2))
                    if shapes:
                        cur.shapes[pm.group(1)] = shapes[0]
                if cur.is_entry:
                    entry = cur.name
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _DEF_RE.match(line)
        if m is None:
            continue
        name, rhs = m.group(1), m.group(2)
        is_root = line.lstrip().startswith("ROOT ")
        om = _OPCODE_RE.match(rhs)
        if om is None:
            continue
        out_shapes = _parse_shapes(om.group(1))
        opcode = om.group(2)
        # operands: inside the first (...) after the opcode
        start = rhs.index(opcode + "(") + len(opcode) + 1
        depth, end = 1, start
        for i in range(start, len(rhs)):
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERANDS_RE.findall(rhs[start:end])
        op = Op(name, opcode, out_shapes, operands, rhs, is_root)
        cur.ops.append(op)
        if out_shapes:
            cur.shapes[name] = out_shapes[0]
    return comps, entry


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """Execution-count multiplier per computation (nested loops compose).

    The call graph is a DAG; propagate caller multipliers to callees in
    topological order (Kahn on caller→callee edges with trip-count weights).
    """
    edges: Dict[str, List[Tuple[str, float]]] = {name: [] for name in comps}
    indeg: Dict[str, int] = {name: 0 for name in comps}
    for cname, comp in comps.items():
        for op in comp.ops:
            trip = 1.0
            if op.opcode == "while":
                tm = _TRIP_RE.search(op.raw)
                trip = float(tm.group(1)) if tm else 1.0
            for target in _CALLS_RE.findall(op.raw) + _COND_RE.findall(op.raw):
                if target in comps and target != cname:
                    edges[cname].append((target, trip))
                    indeg[target] += 1
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    queue = [n for n in comps if indeg[n] == 0]
    while queue:
        cname = queue.pop()
        for target, trip in edges[cname]:
            mult[target] += mult[cname] * trip
            indeg[target] -= 1
            if indeg[target] == 0:
                queue.append(target)
    return mult


def _dot_flops(op: Op, comp: Computation) -> float:
    if not op.out_shapes:
        return 0.0
    out_elems = 1
    for _, dims in op.out_shapes:
        for d in dims:
            out_elems *= d
        break
    lhs = comp.shapes.get(op.operands[0]) if op.operands else None
    if lhs is None:
        return 0.0
    cm = _LHS_CONTRACT.search(op.raw)
    contract = 1
    if cm and cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs[1]):
                contract *= lhs[1][i]
    return 2.0 * out_elems * contract


_PARAM_IDX = re.compile(r"parameter\((\d+)\)")
_CALLS_ONLY = re.compile(r"calls=%([\w\.\-]+)")


def _effective_op_bytes(op: Op, comp: Computation, comps: Dict[str, Computation]) -> float:
    """HBM traffic of one materialized op, slice-aware.

    XLA's naive convention charges the FULL operand for every access; a
    while-body op that dynamic-slices one layer out of a (40, ...) stacked
    buffer would be charged the whole stack per iteration (40× overcount).
    For fusions we walk the called computation: parameters whose only uses
    are dynamic-slices are charged the slice bytes; a dynamic-update-slice
    root is charged the update bytes.  Direct DS/DUS ops likewise.
    """
    out_b = sum(_nbytes(s) for s in op.out_shapes)
    # producer-pays: a produced tensor is charged once (its output); operand
    # reads are charged only for values NOT produced by compute ops in this
    # computation (i.e., loop-carried/parameter/constant reads — weights,
    # saved-activation stacks), so edges aren't double-counted.
    producers = {
        o.name: o.opcode
        for o in comp.ops
        if o.opcode not in ("parameter", "get-tuple-element", "constant")
    }
    if op.opcode == "dynamic-slice":
        return 2.0 * out_b
    if op.opcode == "dynamic-update-slice":
        upd = comp.shapes.get(op.operands[1]) if len(op.operands) > 1 else None
        return 2.0 * _nbytes(upd) if upd else out_b
    cm = _CALLS_ONLY.search(op.raw)
    if op.opcode == "fusion" and cm and cm.group(1) in comps:
        fcomp = comps[cm.group(1)]
        in_b, o_b = _fusion_bytes(op, comp, fcomp, producers, out_b)
        return o_b + in_b
    in_b = sum(
        _nbytes(comp.shapes[o])
        for o in op.operands
        if o in comp.shapes and o not in producers
    )
    return out_b + in_b


_ELEMENTWISE_UNARY = ("convert", "bitcast", "copy", "reshape", "reduce-precision")


def _fusion_bytes(
    op: Op,
    comp: Computation,
    fcomp: Computation,
    producers: Dict[str, str],
    out_b: float,
) -> Tuple[float, float]:
    """(input_bytes, output_bytes) of a fusion, slice/alias-aware.

    Interior elementwise unary chains (convert/bitcast/copy/reshape) are
    free in a fusion — traffic is determined by what the parameters feed
    *through* them: a parameter consumed only by dynamic-slices is charged
    the slice bytes; a parameter that is the in-place buffer of a
    dynamic-update-slice is charged zero (aliased); a DUS at the (traced)
    root means the fusion writes only the update slice.
    """
    by_name = {o.name: o for o in fcomp.ops}
    uses: Dict[str, List[Op]] = {}
    for fop in fcomp.ops:
        for o in fop.operands:
            uses.setdefault(o, []).append(fop)

    def effective_uses(name: str, depth: int = 0) -> List[Tuple[Op, int]]:
        """(consumer, operand_index) pairs after skipping unary chains."""
        result = []
        for u in uses.get(name, []):
            if u.opcode in _ELEMENTWISE_UNARY and depth < 8:
                result.extend(effective_uses(u.name, depth + 1))
            else:
                result.append((u, u.operands.index(name)))
        return result

    pname: Dict[int, str] = {}
    for fop in fcomp.ops:
        if fop.opcode == "parameter":
            pm = _PARAM_IDX.search(fop.raw)
            if pm:
                pname[int(pm.group(1))] = fop.name

    in_b = 0.0
    for i, operand in enumerate(op.operands):
        if operand in producers:
            continue  # charged at its producer
        full = comp.shapes.get(operand)
        if full is None:
            continue
        interior = pname.get(i)
        if interior is None:
            in_b += _nbytes(full)
            continue
        eff = effective_uses(interior)
        if eff and all(
            (u.opcode == "dynamic-slice")
            or (u.opcode == "dynamic-update-slice" and idx == 0)
            for u, idx in eff
        ):
            # slices read + in-place DUS buffers (charged 0)
            in_b += sum(
                sum(_nbytes(s) for s in u.out_shapes)
                for u, _ in eff
                if u.opcode == "dynamic-slice"
            )
        else:
            in_b += _nbytes(full)

    # trace root through unary chains to detect slice-write fusions
    root = next((f for f in fcomp.ops if f.is_root), None)
    o_b = out_b
    hops = 0
    while root is not None and root.opcode in _ELEMENTWISE_UNARY and hops < 8:
        root = by_name.get(root.operands[0]) if root.operands else None
        hops += 1
    if root is not None and root.opcode == "dynamic-update-slice":
        upd = None
        if len(root.operands) > 1:
            upd = fcomp.shapes.get(root.operands[1])
        if upd:
            o_b = _nbytes(upd)
    return in_b, o_b


def _is_promoted_bf16(op: Op, comp: Computation, comps: Dict[str, Computation]) -> bool:
    """True when an f32 collective's operands all come from bf16 upcasts
    (convert ops or fusions whose float parameters are all bf16)."""
    if not op.out_shapes or not all(t == "f32" for t, _ in op.out_shapes):
        return False
    by_name = {o.name: o for o in comp.ops}
    for operand in op.operands:
        prod = by_name.get(operand)
        if prod is None:
            return False
        if prod.opcode == "convert":
            src = comp.shapes.get(prod.operands[0]) if prod.operands else None
            if src is None or src[0] != "bf16":
                return False
        elif prod.opcode == "fusion":
            cm = _CALLS_ONLY.search(prod.raw)
            if not cm or cm.group(1) not in comps:
                return False
            fcomp = comps[cm.group(1)]
            float_params = [
                s for n, s in fcomp.shapes.items()
                if any(f.opcode == "parameter" and f.name == n for f in fcomp.ops)
                and s[0] in ("f32", "bf16", "f16")
            ]
            if not float_params or not all(s[0] == "bf16" for s in float_params):
                return False
        else:
            return False
    return True


def _group_size(raw: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", raw)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", raw)
    if m:
        return len(m.group(1).split(","))
    return 1


def analyze(text: str) -> dict:
    comps, entry = parse_module(text)
    if entry is None:
        return {"dot_flops": 0.0, "coll": {}, "coll_wire": 0.0, "bytes": 0.0,
                "counts": {}}
    mult = _multipliers(comps, entry)

    dot_flops = 0.0
    byte_traffic = 0.0
    coll: Dict[str, float] = {}
    counts: Dict[str, float] = {}
    wire = 0.0
    # computations reachable as fusion interiors don't touch HBM: bytes only
    # from "materialized" levels = entry + while bodies/conds + call targets
    materialized = set()
    for cname, comp in comps.items():
        for op in comp.ops:
            if op.opcode == "while" or op.opcode == "call" or op.opcode == "conditional":
                for t in _CALLS_RE.findall(op.raw) + _COND_RE.findall(op.raw):
                    materialized.add(t)
    if entry:
        materialized.add(entry)

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for op in comp.ops:
            if op.opcode == "dot":
                dot_flops += m * _dot_flops(op, comp)
            elif (
                op.opcode in COLLECTIVES
                or any(op.opcode == c + "-start" for c in COLLECTIVES)
            ) and "kernel_streamed" not in op.raw:
                # collectives materialized INSIDE a kernel_streamed region are
                # per-timestep SPMD artifacts of the jnp reference scan (the
                # Pallas kernel computes shard-locally; the real cross-shard
                # reduction happens once, outside the scope)
                kind = op.opcode.replace("-start", "")
                out_b = sum(_nbytes(s) for s in op.out_shapes)
                # XLA's CPU backend promotes bf16 all-reduces to f32
                # (verified: psum(bf16) lowers to convert+f32 all-reduce);
                # TPU keeps them bf16 — halve bytes when every producer
                # feeding the collective is semantically bf16.
                if _is_promoted_bf16(op, comp, comps):
                    out_b *= 0.5
                g = max(_group_size(op.raw), 1)
                if kind == "all-gather":
                    operand, w = out_b / g, out_b * (g - 1) / g
                elif kind == "reduce-scatter":
                    operand, w = out_b * g, out_b * (g - 1)
                elif kind == "all-reduce":
                    operand, w = out_b, 2 * out_b * (g - 1) / g
                elif kind == "all-to-all":
                    operand, w = out_b, out_b * (g - 1) / g
                else:
                    operand, w = out_b, out_b
                coll[kind] = coll.get(kind, 0.0) + m * operand
                counts[kind] = counts.get(kind, 0.0) + m
                wire += m * w
            if (
                cname in materialized
                and op.opcode not in (
                    "parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "copy",
                )
                and "kernel_streamed" not in op.raw
            ):
                byte_traffic += m * _effective_op_bytes(op, comp, comps)
    return {
        "dot_flops": dot_flops,
        "coll": coll,
        "coll_wire": wire,
        "bytes": byte_traffic,
        "counts": counts,
        "n_computations": len(comps),
    }
