"""Monte Carlo Tree Search over the scheduling MDP (paper §4, Table 1).

Faithful to the paper:

* Nodes store the running **average** cost (used by the tree policy), the
  **best** cost seen through them, and the complete schedule achieving it.
* The tree policy is the paper's multiplicative UCB
  ``(1/avg_cost)·(1 + Cp·√(ln n / n_j))`` (``ucb="paper"``, Cp=1;
  ``ucb="cp10"``, Cp=10) or the classical additive UCB1 with Cp=√2 on
  normalized rewards (``ucb="sqrt2"``).
* Simulation is uniform-random (standard trees) or purely greedy on the
  cost model (the single greedy tree of §4.1).
* Costs are only ever read from **complete** schedules at simulation end.
* The winning root action is the child whose subtree found the best
  **best-cost** (not average) — §4: "+25% over average".
* Budget per root decision: iteration count (deterministic) or wall-clock
  seconds (paper's 30s/10s/1s/0.5s protocol).
"""
from __future__ import annotations

import math
import random
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.mdp import ScheduleMDP, State

INF = float("inf")


@dataclass(frozen=True)
class MCTSConfig:
    ucb: str = "paper"  # paper | cp10 | sqrt2
    simulation: str = "random"  # random | greedy
    reward_mode: str = "cost"  # cost | binary (§4.1 0/1-reward ablation)
    iters_per_decision: Optional[int] = 128
    seconds_per_decision: Optional[float] = None
    seed: int = 0

    @property
    def cp(self) -> float:
        return 10.0 if self.ucb == "cp10" else 1.0


class Node:
    __slots__ = (
        "action",
        "depth",
        "children",
        "untried",
        "n",
        "sum_cost",
        "sum_reward",
        "best_cost",
        "best_state",
    )

    def __init__(self, action: Optional[int], depth: int, n_actions: int):
        self.action = action
        self.depth = depth
        self.children: Dict[int, "Node"] = {}
        self.untried: List[int] = list(range(n_actions))
        self.n = 0
        self.sum_cost = 0.0
        self.sum_reward = 0.0
        self.best_cost = INF
        self.best_state: Optional[State] = None

    @property
    def avg_cost(self) -> float:
        return self.sum_cost / self.n if self.n else INF


@dataclass
class DecisionResult:
    action: int
    best_cost: float
    best_state: State
    iterations: int


class MCTS:
    """One search tree; ``run_decision`` spends the budget then reports its
    best child (the ensemble synchronizes roots across trees)."""

    def __init__(self, mdp: ScheduleMDP, config: MCTSConfig):
        self.mdp = mdp
        self.cfg = config
        self.rng = random.Random(config.seed)
        self.root_state: State = mdp.initial_state
        self.root = self._make_node(None, self.root_state)
        self.baseline: Optional[float] = None  # reward normalizer (sqrt2 mode)
        self.global_best = INF
        self.global_best_state: Optional[State] = None
        self.sim_time = 0.0  # §5.3 bookkeeping: time generating children
        self.eval_time = 0.0  # time in cost evaluation

    # ------------------------------------------------------------------
    def _make_node(self, action, state: State) -> Node:
        n_act = 0 if self.mdp.is_terminal(state) else self.mdp.n_actions(state)
        return Node(action, len(state), n_act)

    def _ucb_score(self, parent: Node, child: Node) -> float:
        c = self.cfg
        explore = math.sqrt(math.log(max(parent.n, 1)) / child.n)
        if c.ucb in ("paper", "cp10"):
            exploit = 1.0 / child.avg_cost
            return exploit * (1.0 + c.cp * explore)
        if c.ucb == "sqrt2":
            # rewards are normalized (baseline/cost, ~1.0 at baseline) or 0/1
            mean_r = child.sum_reward / child.n
            return mean_r + math.sqrt(2.0) * math.sqrt(
                2.0 * math.log(max(parent.n, 1)) / child.n
            )
        raise ValueError(c.ucb)

    # ------------------------------------------------------------------
    def _select(self) -> Tuple[Node, State, List[Node]]:
        node, state = self.root, self.root_state
        path = [node]
        while not node.untried and node.children:
            node = max(node.children.values(), key=lambda ch: self._ucb_score(node, ch))
            state = self.mdp.step(state, node.action)
            path.append(node)
        return node, state, path

    def _expand(self, node: Node, state: State) -> Tuple[Node, State, Optional[Node]]:
        if self.mdp.is_terminal(state) or not node.untried:
            return node, state, None
        a = node.untried.pop(self.rng.randrange(len(node.untried)))
        child_state = self.mdp.step(state, a)
        child = self._make_node(a, child_state)
        node.children[a] = child
        return child, child_state, child

    def _simulate(self, state: State) -> Tuple[State, float]:
        t0 = time.perf_counter()
        while not self.mdp.is_terminal(state):
            n = self.mdp.n_actions(state)
            if self.cfg.simulation == "greedy":
                # greedy default policy: rank children by (unreliable)
                # default-completed cost; ties to the rng
                best_a, best_c = 0, INF
                for a in range(n):
                    c = self.mdp.partial_cost(self.mdp.step(state, a))
                    if c < best_c or (c == best_c and self.rng.random() < 0.5):
                        best_a, best_c = a, c
                state = self.mdp.step(state, best_a)
            else:
                state = self.mdp.step(state, self.rng.randrange(n))
        self.sim_time += time.perf_counter() - t0
        t1 = time.perf_counter()
        cost = self.mdp.terminal_cost(state)
        self.eval_time += time.perf_counter() - t1
        return state, cost

    def _backprop(self, path: List[Node], terminal: State, cost: float):
        if self.baseline is None:
            self.baseline = cost
        beat_best = cost < self.global_best
        if beat_best:
            self.global_best = cost
            self.global_best_state = terminal
        for node in path:
            node.n += 1
            node.sum_cost += cost
            if self.cfg.reward_mode == "binary":
                node.sum_reward += 1.0 if beat_best else 0.0
            else:
                node.sum_reward += (self.baseline / cost) if cost > 0 else 0.0
            if cost < node.best_cost:
                node.best_cost = cost
                node.best_state = terminal

    def iterate_once(self):
        node, state, path = self._select()
        child, child_state, created = self._expand(node, state)
        if created is not None:
            path.append(created)
        terminal, cost = self._simulate(child_state)
        self._backprop(path, terminal, cost)

    # ------------------------------------------------------------------
    def run_decision(self) -> DecisionResult:
        """Spend the per-decision budget, return the winning child."""
        c = self.cfg
        iters = 0
        t0 = time.perf_counter()
        while True:
            if c.seconds_per_decision is not None:
                if time.perf_counter() - t0 >= c.seconds_per_decision and iters > 0:
                    break
                if iters >= 100000:
                    break
            elif iters >= (c.iters_per_decision or 1):
                break
            self.iterate_once()
            iters += 1
        # winner: best BEST-cost child (paper §4, after [9])
        if not self.root.children:
            self.iterate_once()
            iters += 1
        best_child = min(
            self.root.children.values(), key=lambda ch: (ch.best_cost, ch.action)
        )
        return DecisionResult(
            action=best_child.action,
            best_cost=best_child.best_cost,
            best_state=best_child.best_state,
            iterations=iters,
        )

    def advance_root(self, action: int):
        """Move the root to the (synchronized) winning child, keeping the
        subtree (tree reuse as in the paper's Fig. 6 loop)."""
        self.root_state = self.mdp.step(self.root_state, action)
        child = self.root.children.get(action)
        if child is None:
            child = self._make_node(action, self.root_state)
        self.root = child

    @property
    def done(self) -> bool:
        return self.mdp.is_terminal(self.root_state)
