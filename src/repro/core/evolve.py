"""Evolutionary search over COMPLETE schedules, plus the portfolio
meta-backend that races it against mcts/beam/random on one shared cache.

The paper's central argument — only complete schedules carry a trustworthy
cost — admits more searchers than MCTS.  An openevolve-style
mutate-and-evaluate loop is the natural non-tree member of the family:
individuals are complete action tuples, fitness is the certified
``cost_batch`` path (one deduplicated columnar/jit pricing pass per
generation through ``CachedMDP.terminal_cost_batch``), and no partial
schedule is ever compared (beam's failure mode, Fig. 1/2).

Typed operator catalog (one operator per decision stage, so closure over
``ScheduleSpace`` holds BY CONSTRUCTION — operators move option *indices*,
never raw values):

    flip      2-option stages (bool flags, opt/kv dtype, batch_axes):
              return the other option
    creep     ordered numeric knobs (microbatches, scan_chunk, overlap,
              attn_block): step ±1 through the option list, clamped inward
              at the ends
    resample  unordered categoricals (param_strategy, moe_mode, remat,
              grad_comm): uniform over the OTHER options

Crossover is uniform over stage indices (each gene from either parent), so
it is closed for the same reason.  Both closures are pinned by hypothesis
properties (decoded plan == re-encoded actions) in tests/test_properties.py.

Determinism: one ``random.Random(seed)`` drives sampling in a fixed order,
ties rank by (cost, state tuple), and fitness is the exact batched pricing
path — two runs with the same seed on the same cell are bit-identical
(asserted by tests/test_differential.py).

Seeding from the plan store: ``autotune(..., plan_store=...)`` passes the
store's recorded plans for the same (arch, shape, mesh) cell as
``seed_plans``; every encodable seed joins the initial population ahead of
random fill, so a warm store turns generation 0 into "best known plan so
far" instead of a cold uniform sample.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.engine import CachedMDP
from repro.core.ensemble import TuneResult
from repro.core.space import ScheduleSpace, SchedulePlan, Stage

State = Tuple[int, ...]

# stages whose option tuples are ordered small->large (or lexicographically,
# for the flash-block pairs): ±1 neighbourhood moves are meaningful
ORDERED_STAGES = frozenset(
    {"microbatches", "scan_chunk", "overlap", "attn_block"}
)


def encode_plan(space: ScheduleSpace, plan: SchedulePlan) -> Optional[State]:
    """Action tuple for ``plan`` in ``space``, or None if any field value
    is not among the cell's options (plan stores can hold plans recorded
    under other cells or older space layouts — those simply don't seed)."""
    actions: List[int] = []
    for stage in space.stages:
        value = getattr(plan, stage.name)
        try:
            actions.append(stage.options.index(value))
        except ValueError:
            return None
    return tuple(actions)


def _op_flip(stage: Stage) -> Callable[[int, random.Random], int]:
    def op(idx: int, rng: random.Random) -> int:
        return 1 - idx

    return op


def _op_creep(stage: Stage) -> Callable[[int, random.Random], int]:
    last = len(stage.options) - 1

    def op(idx: int, rng: random.Random) -> int:
        if idx == 0:
            return 1
        if idx == last:
            return last - 1
        return idx + (1 if rng.random() < 0.5 else -1)

    return op


def _op_resample(stage: Stage) -> Callable[[int, random.Random], int]:
    n = len(stage.options)

    def op(idx: int, rng: random.Random) -> int:
        new = rng.randrange(n - 1)
        return new if new < idx else new + 1  # uniform over the others

    return op


def mutation_operators(
    space: ScheduleSpace,
) -> List[Tuple[str, int, Callable[[int, random.Random], int]]]:
    """The cell's typed operator catalog: ``(name, stage_depth, op)`` per
    mutable stage, where ``op(idx, rng)`` returns a DIFFERENT valid option
    index for that stage.  Single-option stages get no operator."""
    ops = []
    for depth, stage in enumerate(space.stages):
        n = len(stage.options)
        if n < 2:
            continue
        if n == 2:
            kind, op = "flip", _op_flip(stage)
        elif stage.name in ORDERED_STAGES:
            kind, op = "creep", _op_creep(stage)
        else:
            kind, op = "resample", _op_resample(stage)
        ops.append((f"{kind}:{stage.name}", depth, op))
    return ops


def mutate(
    actions: Sequence[int],
    rng: random.Random,
    ops: Sequence[Tuple[str, int, Callable]],
    rate: float,
) -> State:
    """Apply each stage's operator with probability ``rate``; if nothing
    fired, force one (a child identical to its parent is a wasted cache
    hit, not exploration)."""
    out = list(actions)
    changed = False
    for _name, depth, op in ops:
        if rng.random() < rate:
            out[depth] = op(out[depth], rng)
            changed = True
    if not changed and ops:
        _name, depth, op = ops[rng.randrange(len(ops))]
        out[depth] = op(out[depth], rng)
    return tuple(out)


def crossover(a: Sequence[int], b: Sequence[int], rng: random.Random) -> State:
    """Uniform crossover over stage indices — each gene from either parent,
    so the child is inside the space whenever the parents are."""
    return tuple(x if rng.random() < 0.5 else y for x, y in zip(a, b))


@dataclass
class EvolutionarySearchBackend:
    """``SearchBackend`` adapter: ``algo="evolve"`` via ``resolve_backend``.

    Population over complete plans; elitist generational loop with
    tournament selection, optional uniform crossover, and the typed
    per-stage mutation catalog above.  Fitness is ALWAYS the certified
    batched pricing path: each generation is one
    ``CachedMDP.terminal_cost_batch`` call, so re-visited individuals are
    cache hits and ``n_evals`` counts each unique plan's pricing exactly
    once for the whole run (the eval-budget accounting the differential
    tests pin).  ``measure_fn`` does not drive fitness (the paper's
    compile-and-run oracle is too slow for thousand-plan generations); if
    given, the final best plan is measured once."""

    population: int = 32
    generations: int = 24
    elite: int = 4
    tournament: int = 3
    crossover_rate: float = 0.5
    mutation_rate: float = 0.15
    name: str = "evolve"

    def run(
        self,
        mdp,
        *,
        seed: int = 0,
        time_budget_s: Optional[float] = None,
        measure_fn: Optional[Callable] = None,
        cache: Optional[bool] = None,
        max_evals: Optional[int] = None,
        seed_plans: Optional[Sequence[SchedulePlan]] = None,
        controller=None,
        **_,
    ) -> TuneResult:
        t0 = time.perf_counter()
        if cache is None:
            cache = True  # the batched fitness path wants the shared cache
        if cache and not isinstance(mdp, CachedMDP):
            mdp = CachedMDP(mdp)
        space = mdp.space
        ops = mutation_operators(space)
        rng = random.Random(seed)
        cost_model = getattr(mdp, "cost_model", None)

        def evals() -> int:
            return getattr(cost_model, "n_evals", 0)

        evals0 = evals()

        # ---- generation 0: defaults + store seeds + random fill ----
        pop: List[State] = []
        seen = set()

        def add(state: State) -> None:
            if state not in seen:
                seen.add(state)
                pop.append(state)

        add(tuple(space.default_actions()))
        for p in seed_plans or ():
            enc = encode_plan(space, p)
            if enc is not None:
                add(enc)
        del pop[self.population:]
        while len(pop) < self.population:
            add(tuple(space.random_actions(rng)))

        best_state: Optional[State] = None
        best_cost = float("inf")
        decisions: List[dict] = []
        interrupted = None
        g = 0
        while True:
            costs = mdp.terminal_cost_batch(pop)
            for s, c in zip(pop, costs):
                if c < best_cost or (
                    c == best_cost and (best_state is None or s < best_state)
                ):
                    best_cost, best_state = c, s
            decisions.append({
                "generation": g,
                "best_cost": best_cost,
                "population": len(pop),
                "n_evals": evals() - evals0,
            })
            g += 1
            if g >= self.generations:
                break
            if (time_budget_s is not None
                    and time.perf_counter() - t0 > time_budget_s):
                break
            if max_evals is not None and evals() - evals0 >= max_evals:
                break
            if controller is not None:
                # generation boundary = this backend's round boundary
                # (core/run_control.py): a deadline/cancel finishes the
                # generation and returns best-so-far.  No checkpoints —
                # an evolve replay from scratch is deterministic and
                # cheap, so resume-from-checkpoint buys nothing here.
                controller.begin_round()
                controller.round_done()
                reason = controller.should_stop()
                if reason is not None:
                    interrupted = {
                        "reason": reason,
                        "rounds_done": g,
                        "rounds_total": self.generations,
                        "checkpointed": False,
                    }
                    break
            # ---- next generation: elites + tournament offspring ----
            ranked = sorted(range(len(pop)), key=lambda i: (costs[i], pop[i]))
            nxt = [pop[i] for i in ranked[: self.elite]]

            def select() -> State:
                best_i = min(
                    (rng.randrange(len(pop)) for _ in range(self.tournament)),
                    key=lambda i: (costs[i], pop[i]),
                )
                return pop[best_i]

            while len(nxt) < self.population:
                parent = select()
                if rng.random() < self.crossover_rate:
                    parent = crossover(parent, select(), rng)
                nxt.append(mutate(parent, rng, ops, self.mutation_rate))
            pop = nxt

        measured = None
        n_meas = 0
        if measure_fn is not None:
            measured = measure_fn(mdp.plan(best_state))
            n_meas = 1
        res = TuneResult(
            plan=mdp.plan(best_state),
            cost=mdp.terminal_cost(best_state),  # warm: a cache hit
            measured=measured,
            n_evals=evals(),
            n_measurements=n_meas,
            wall_time_s=time.perf_counter() - t0,
            decisions=decisions,
            algo="evolve",
        )
        if isinstance(mdp, CachedMDP):
            res.cache_hits = mdp.cache.hits
            res.cache_misses = mdp.cache.misses
        if interrupted is not None:
            res.stats["interrupted"] = interrupted
        return res


@dataclass
class PortfolioBackend:
    """``algo="portfolio"``: race member searchers on ONE shared
    ``TranspositionCache`` under one eval budget.

    Members run sequentially (deterministic, and on the few-core boxes this
    repo targets, concurrency would just interleave the same work) over the
    same ``CachedMDP``: a plan priced by any member is a cache hit for
    every later member, so the TOTAL unique-plan pricing work is shared —
    ``n_evals`` on the returned result counts each unique plan exactly
    once across the whole portfolio.  ``max_evals`` (when given) is
    decremented by each member's unique-eval consumption; members that
    take an explicit budget (evolve, random) receive the remainder, and a
    spent budget skips the members after it.

    The reported winner is the best member's result, bit-for-bit: the
    winning plan/cost are returned unmodified (asserted by the
    differential tests), with each member's summary — including its full
    plan dict — in ``decisions``."""

    members: Tuple[str, ...] = ("evolve", "mcts_1s", "beam", "random")
    name: str = "portfolio"

    def run(
        self,
        mdp,
        *,
        seed: int = 0,
        time_budget_s: Optional[float] = None,
        measure_fn: Optional[Callable] = None,
        cache: bool = True,
        max_evals: Optional[int] = None,
        seed_plans: Optional[Sequence[SchedulePlan]] = None,
        engine: str = "array",
        cost: str = "analytic",
        n_standard: int = 4,
        n_greedy: int = 1,
        **_,
    ) -> TuneResult:
        from repro.core.engine.backend import resolve_backend
        from repro.core.random_search import RandomBackend

        t0 = time.perf_counter()
        if not isinstance(mdp, CachedMDP):
            mdp = CachedMDP(mdp)
        cost_model = getattr(mdp, "cost_model", None)

        def evals() -> int:
            return getattr(cost_model, "n_evals", 0)

        evals0 = evals()
        member_budget_s = (
            time_budget_s / len(self.members) if time_budget_s else None
        )
        results: List[Tuple[str, TuneResult]] = []
        for algo in self.members:
            remaining = (
                None if max_evals is None
                else max_evals - (evals() - evals0)
            )
            if remaining is not None and remaining <= 0:
                break
            opts = dict(cache=True, seed_plans=seed_plans)
            if algo == "evolve":
                backend = EvolutionarySearchBackend()
                opts["max_evals"] = remaining
            elif algo == "random":
                n = 256 if remaining is None else min(256, remaining)
                backend = RandomBackend(n_samples=n)
            else:
                backend = resolve_backend(algo, engine=engine, cost=cost)
                opts.update(n_standard=n_standard, n_greedy=n_greedy)
            res = backend.run(
                mdp, seed=seed, time_budget_s=member_budget_s, **opts
            )
            results.append((algo, res))
        win_i = min(range(len(results)), key=lambda i: (results[i][1].cost, i))
        winner = results[win_i][1]
        decisions = [
            {
                "member": algo,
                "cost": r.cost,
                "n_evals": r.n_evals,
                "wall_time_s": r.wall_time_s,
                "plan": r.plan.to_dict(),
                "winner": i == win_i,
            }
            for i, (algo, r) in enumerate(results)
        ]
        out = TuneResult(
            plan=winner.plan,
            cost=winner.cost,
            measured=winner.measured,
            n_evals=evals(),
            n_measurements=winner.n_measurements,
            wall_time_s=time.perf_counter() - t0,
            decisions=decisions,
            algo="portfolio",
        )
        out.cache_hits = mdp.cache.hits
        out.cache_misses = mdp.cache.misses
        return out
