"""Vectorized search engine layer.

Two interchangeable MCTS engines behind one interface:

* ``"reference"`` — the paper-faithful ``Node``-object tree
  (``repro.core.mcts.MCTS``), kept as the behavioral oracle.
* ``"array"`` — ``ArrayMCTS``: the same algorithm in flat numpy arrays
  with batched UCB scoring, exactly equivalent for fixed seeds.

Plus the shared ``TranspositionCache`` / ``CachedMDP`` that memoizes
``terminal_cost`` / ``partial_cost`` across all ensemble trees and all
decision rounds, and the ``SearchBackend`` protocol (see ``backend.py``)
that ``autotune`` routes every algorithm through.
"""
from __future__ import annotations

from repro.core.engine.array_mcts import ArrayMCTS
from repro.core.engine.cache import CachedMDP, TranspositionCache

ENGINES = ("reference", "array")


def make_tree(mdp, config, engine: str = "reference"):
    """Construct one search tree with the requested engine."""
    if engine == "array":
        return ArrayMCTS(mdp, config)
    if engine == "reference":
        from repro.core.mcts import MCTS

        return MCTS(mdp, config)
    raise ValueError(f"unknown engine {engine!r}; expected one of {ENGINES}")


__all__ = [
    "ArrayMCTS",
    "CachedMDP",
    "TranspositionCache",
    "ENGINES",
    "make_tree",
]
