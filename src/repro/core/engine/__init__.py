"""Vectorized search engine layer.

Two interchangeable MCTS engines behind one interface:

* ``"reference"`` — the paper-faithful ``Node``-object tree
  (``repro.core.mcts.MCTS``), kept as the behavioral oracle.
* ``"array"`` — ``ArrayMCTS``: the same algorithm in flat numpy arrays
  with batched UCB scoring, exactly equivalent for fixed seeds.  **This is
  the default engine everywhere** (``autotune``, ``ProTuner``,
  ``benchmarks.common.run_algo``), certified against the reference across
  the full (UCB variant × simulation policy × reward mode × seed) grid by
  the differential harness in ``tests/test_differential.py``.

Batched leaf evaluation (``engine/batch.py``): ``run_decision_batch`` runs
an ensemble round's K trees in lockstep, queueing each step's K pending
leaves (and the greedy rollouts' per-depth candidate sweeps) into single
batched pricing calls.  The pricing seam it rides on:

* ``AnalyticCostModel.cost_batch(plans)`` — contract:
  ``cost_batch(plans) == [cost(p) for p in plans]`` element-for-element and
  bit-for-bit; duplicate plans are priced once and ``n_evals`` counts each
  unique evaluation once.  Plan-independent accounting amortizes across the
  batch via a persistent evaluation context.
* ``ScheduleMDP.terminal_cost_batch / partial_cost_batch`` — the same
  contract at the state level, falling back to scalar loops for cost
  models without ``cost_batch``.
* ``CachedMDP.terminal_cost_batch / partial_cost_batch`` — additionally
  partitions the batch against the ``TranspositionCache`` and prices ONLY
  the deduplicated misses; ``hits + misses`` advances by exactly the batch
  size, a state appearing twice in one batch is one miss plus one hit, and
  a warm cache never changes returned values (hypothesis-tested in
  ``tests/test_properties.py``).

Plus the shared ``TranspositionCache`` / ``CachedMDP`` that memoizes
``terminal_cost`` / ``partial_cost`` across all ensemble trees and all
decision rounds, and the ``SearchBackend`` protocol (see ``backend.py``)
that ``autotune`` routes every algorithm through.

Parallel execution (``workers.py``): ``parallel=True`` runs ensemble
rounds on PERSISTENT PINNED worker processes — each worker holds its
trees and a serve-only ``CachedMDP`` for the whole run, and per-round
traffic is a delta in both directions (root-advance + incremental cache
export + generation-keyed model params forward; the ``ArrayMCTS`` round
delta back), with payload bytes counted at the pickle boundary and
worker-death resync from the master's canonical trees.

Learned-cost serving (``serving.py``): ``cost="analytic"|"learned"|"hybrid"``
on ``autotune`` / ``ProTuner`` / ``resolve_backend`` mounts a
``HybridCostBackend`` inside ``CachedMDP`` — an ``OnlineCostTrainer``
refits the §3 MLP on the cache's analytic terminal entries, and trained
(confident) models price each deduplicated miss batch in ONE jitted
forward pass, with exact-analytic fallback.  ``cost="analytic"`` (the
default) mounts nothing, so the differential-certified PR-2 path is
untouched.  See ``docs/architecture.md`` for the full seam contracts.
"""
from __future__ import annotations

from repro.core.engine.array_mcts import ArrayMCTS
from repro.core.engine.cache import CachedMDP, TranspositionCache
from repro.core.engine.serving import (
    COST_MODES,
    HybridCostBackend,
    OnlineCostTrainer,
    make_cost_backend,
)
from repro.core.engine.workers import PinnedWorkerPool

ENGINES = ("reference", "array")


def make_tree(mdp, config, engine: str = "reference"):
    """Construct one search tree with the requested engine."""
    if engine == "array":
        return ArrayMCTS(mdp, config)
    if engine == "reference":
        from repro.core.mcts import MCTS

        return MCTS(mdp, config)
    raise ValueError(f"unknown engine {engine!r}; expected one of {ENGINES}")


__all__ = [
    "ArrayMCTS",
    "CachedMDP",
    "PinnedWorkerPool",
    "TranspositionCache",
    "COST_MODES",
    "HybridCostBackend",
    "OnlineCostTrainer",
    "make_cost_backend",
    "ENGINES",
    "make_tree",
]
