"""Shared transposition cache over the scheduling MDP.

The MDP is a deterministic prefix tree: a complete schedule IS its action
tuple, so ``terminal_cost`` is a pure function of the state and
``partial_cost`` a pure function of the prefix.  The reference ensemble
re-prices the same complete schedules thousands of times — every one of the
16 trees re-samples overlapping regions of the space, and tree reuse across
decision rounds revisits the same subtree terminals round after round.
``TranspositionCache`` memoizes both signals once, shared across all trees
and all rounds; ``CachedMDP`` is a drop-in ``ScheduleMDP`` wrapper so every
search backend (MCTS, ArrayMCTS, beam, random) gets the cache for free.

Values are bit-identical to uncached evaluation (it is a pure memo — no
rounding, no eviction), so search trajectories are unchanged; only the
number of cost-model evaluations drops.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

State = Tuple[int, ...]


class TranspositionCache:
    """Memo of {complete action tuple -> terminal cost} and
    {prefix action tuple -> default-completed partial cost}."""

    __slots__ = ("terminal", "partial", "hits", "misses")

    def __init__(self):
        self.terminal: Dict[State, float] = {}
        self.partial: Dict[State, float] = {}
        self.hits = 0
        self.misses = 0

    # -- stats ---------------------------------------------------------
    @property
    def n_entries(self) -> int:
        return len(self.terminal) + len(self.partial)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hit_rate,
            "terminal_entries": len(self.terminal),
            "partial_entries": len(self.partial),
        }

    # -- multiprocess merge --------------------------------------------
    def __getstate__(self):
        # Workers receive the mappings but fresh counters, so the counts a
        # worker reports back are exactly the activity of its round and
        # ``merge`` can sum them without double counting.
        return {"terminal": self.terminal, "partial": self.partial}

    def __setstate__(self, state):
        self.terminal = state["terminal"]
        self.partial = state["partial"]
        self.hits = 0
        self.misses = 0

    def merge(self, other: "TranspositionCache") -> None:
        """Fold a worker-side cache back into this one (deterministic: keys
        map to identical values everywhere, so update order is irrelevant)."""
        self.terminal.update(other.terminal)
        self.partial.update(other.partial)
        self.hits += other.hits
        self.misses += other.misses


class CachedMDP:
    """``ScheduleMDP`` wrapper memoizing ``terminal_cost``/``partial_cost``.

    Everything else delegates to the wrapped MDP, so this nests around any
    object implementing the MDP protocol (including test doubles)."""

    def __init__(self, mdp, cache: TranspositionCache = None):
        self.mdp = mdp
        self.cache = cache if cache is not None else TranspositionCache()

    # -- pure structure: straight delegation ---------------------------
    @property
    def initial_state(self) -> State:
        return self.mdp.initial_state

    @property
    def space(self):
        return self.mdp.space

    @property
    def cost_model(self):
        return self.mdp.cost_model

    def n_actions(self, state: State) -> int:
        return self.mdp.n_actions(state)

    def step(self, state: State, action: int) -> State:
        return self.mdp.step(state, action)

    def is_terminal(self, state: State) -> bool:
        return self.mdp.is_terminal(state)

    def plan(self, state: State):
        return self.mdp.plan(state)

    # -- memoized cost signals -----------------------------------------
    def terminal_cost(self, state: State) -> float:
        tbl = self.cache.terminal
        c = tbl.get(state)
        if c is not None:
            self.cache.hits += 1
            return c
        self.cache.misses += 1
        c = self.mdp.terminal_cost(state)
        tbl[state] = c
        return c

    def partial_cost(self, state: State) -> float:
        if self.mdp.is_terminal(state):
            return self.terminal_cost(state)
        tbl = self.cache.partial
        c = tbl.get(state)
        if c is not None:
            self.cache.hits += 1
            return c
        self.cache.misses += 1
        c = self.mdp.partial_cost(state)
        tbl[state] = c
        return c

    # -- batched cost signals ------------------------------------------
    # Contract (shared by both methods): values equal the scalar methods
    # element-for-element; hits + misses advance by exactly len(states);
    # only MISSES reach the wrapped MDP, deduplicated, in first-occurrence
    # order — a state appearing twice in one batch is one miss plus one
    # hit, exactly as if the batch had been priced sequentially.  A warm
    # cache therefore never changes returned values, only the hit count.

    def _batch(self, states, tbl, price) -> List[float]:
        out: List[Optional[float]] = [None] * len(states)
        pending: Dict[State, None] = {}  # dedup, insertion-ordered
        hits = 0
        for i, s in enumerate(states):
            c = tbl.get(s)
            if c is not None:
                out[i] = c
                hits += 1
            elif s in pending:
                hits += 1  # duplicate miss: sequential order would hit
            else:
                pending[s] = None
        self.cache.hits += hits
        self.cache.misses += len(pending)
        if pending:
            miss_states = list(pending)
            for s, c in zip(miss_states, price(miss_states)):
                tbl[s] = c
            for i, s in enumerate(states):
                if out[i] is None:
                    out[i] = tbl[s]
        return out

    def terminal_cost_batch(self, states: Sequence[State]) -> List[float]:
        price = getattr(self.mdp, "terminal_cost_batch", None)
        if price is None:
            price = lambda miss: [self.mdp.terminal_cost(s) for s in miss]
        return self._batch(states, self.cache.terminal, price)

    def partial_cost_batch(self, states: Sequence[State]) -> List[float]:
        """Mixed batches allowed: terminal states route to the terminal
        table (as the scalar ``partial_cost`` does)."""
        is_terminal = self.mdp.is_terminal
        term_idx = [i for i, s in enumerate(states) if is_terminal(s)]
        if not term_idx:
            price = getattr(self.mdp, "partial_cost_batch", None)
            if price is None:
                price = lambda miss: [self.mdp.partial_cost(s) for s in miss]
            return self._batch(states, self.cache.partial, price)
        term_set = set(term_idx)
        part_idx = [i for i in range(len(states)) if i not in term_set]
        out: List[Optional[float]] = [None] * len(states)
        for i, c in zip(term_idx,
                        self.terminal_cost_batch([states[i] for i in term_idx])):
            out[i] = c
        for i, c in zip(part_idx,
                        self.partial_cost_batch([states[i] for i in part_idx])):
            out[i] = c
        return out

    def __getattr__(self, name):
        # fall through for any extension attribute on the wrapped MDP;
        # dunders (and ``mdp`` itself, pre-__init__ during unpickling) must
        # raise, not recurse
        if name.startswith("_") or name == "mdp":
            raise AttributeError(name)
        return getattr(self.mdp, name)
