"""Shared transposition cache over the scheduling MDP.

The MDP is a deterministic prefix tree: a complete schedule IS its action
tuple, so ``terminal_cost`` is a pure function of the state and
``partial_cost`` a pure function of the prefix.  The reference ensemble
re-prices the same complete schedules thousands of times — every one of the
16 trees re-samples overlapping regions of the space, and tree reuse across
decision rounds revisits the same subtree terminals round after round.
``TranspositionCache`` memoizes both signals once, shared across all trees
and all rounds; ``CachedMDP`` is a drop-in ``ScheduleMDP`` wrapper so every
search backend (MCTS, ArrayMCTS, beam, random) gets the cache for free.

With no cost backend mounted (the default), values are bit-identical to
uncached evaluation (a pure memo — no rounding, no eviction), so search
trajectories are unchanged; only the number of cost-model evaluations
drops.

Learned-cost serving (``repro.core.engine.serving``): a
``HybridCostBackend`` passed as ``cost_backend=`` takes over miss pricing —
a deduplicated miss batch is priced by one learned-model forward pass when
the model is trained (and confident), by the exact analytic path otherwise.
Entries the model priced carry its fit-generation id in
``terminal_version`` / ``partial_version``; absence of a tag ALWAYS means
exact analytic pricing, which is what the online trainer harvests (the
model never trains on its own predictions) and what keeps merged
multi-process caches interpretable.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

State = Tuple[int, ...]

# incremental-export cursor: (mutation epoch, len(terminal), len(partial),
# len(terminal_version), len(partial_version)) — see
# TranspositionCache.watermark/export_since
Watermark = Tuple[int, int, int, int, int]


class TranspositionCache:
    """Memo of {complete action tuple -> terminal cost} and
    {prefix action tuple -> default-completed partial cost}, plus
    per-entry model-version tags for learned-priced entries."""

    __slots__ = (
        "terminal", "partial", "terminal_version", "partial_version",
        "hits", "misses", "dedup", "epoch",
    )

    def __init__(self):
        self.terminal: Dict[State, float] = {}
        self.partial: Dict[State, float] = {}
        # model-version tags, ONLY for learned-priced entries: absence of a
        # key means the entry is exact analytic (version 0)
        self.terminal_version: Dict[State, int] = {}
        self.partial_version: Dict[State, int] = {}
        self.hits = 0
        self.misses = 0
        # subset of ``hits`` served by in-batch deduplication: a state that
        # appeared earlier in the SAME miss batch (priced once, served K
        # times) — the batched engines' structural win over scalar walks
        self.dedup = 0
        # mutation epoch: bumped whenever the tables stop being append-only
        # (an eviction, or an in-place value/tag change during a merge) —
        # any outstanding export watermark from an older epoch is then
        # invalid and ``export_since`` falls back to a full export.  Pure
        # appends and re-inserts of identical values never bump it, so the
        # analytic path stays incremental forever.
        self.epoch = 0

    # -- stats ---------------------------------------------------------
    @property
    def n_entries(self) -> int:
        return len(self.terminal) + len(self.partial)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "dedup": self.dedup,
            "hit_rate": self.hit_rate,
            "terminal_entries": len(self.terminal),
            "partial_entries": len(self.partial),
            "learned_terminal_entries": len(self.terminal_version),
            "learned_partial_entries": len(self.partial_version),
        }

    # -- multiprocess merge --------------------------------------------
    def __getstate__(self):
        # Workers receive the mappings but fresh counters, so the counts a
        # worker reports back are exactly the activity of its round and
        # ``merge`` can sum them without double counting.
        return {
            "terminal": self.terminal,
            "partial": self.partial,
            "terminal_version": self.terminal_version,
            "partial_version": self.partial_version,
        }

    def __setstate__(self, state):
        self.terminal = state["terminal"]
        self.partial = state["partial"]
        self.terminal_version = state.get("terminal_version", {})
        self.partial_version = state.get("partial_version", {})
        self.hits = 0
        self.misses = 0
        self.dedup = 0
        self.epoch = 0

    def _merge_tbl(self, tbl, vtbl, new, vnew) -> None:
        """Fold ``new`` entries (with tags ``vnew``) into ``tbl``/``vtbl``
        under the EXACT-WINS rule: an existing untagged (exact analytic)
        entry is never overwritten by a learned prediction, and an
        incoming exact entry replaces a learned one and clears its tag.
        Sibling workers can race on the same state — one serving the
        model, one auditing analytically — and exact must win regardless
        of merge order.  (Two *predictions* of the same state from
        different model generations resolve last-writer-wins; callers
        merge in tree-index order, so that too is deterministic.)

        Epoch accounting: overwriting an EXISTING key with a different
        value or tag mutates the table in place (the key keeps its dict
        position), which invalidates any outstanding length-based export
        watermark — that bumps ``epoch``.  Appending new keys, or
        re-inserting a key with its identical exact value (the pure-
        analytic fast path — the memo is a pure function of the state, so
        every worker computes the same float), keeps watermarks valid."""
        if not vtbl and not vnew:
            tbl.update(new)  # pure-analytic fast path: everything is exact
            return
        changed = False
        for s, c in new.items():
            if s in tbl and s not in vtbl:
                continue  # existing exact entry wins
            v = vnew.get(s)
            if s in tbl and (tbl[s] != c or vtbl.get(s) != v):
                changed = True  # in-place rewrite: watermarks go stale
            tbl[s] = c
            if v is None:
                vtbl.pop(s, None)  # incoming exact clears any stale tag
            else:
                vtbl[s] = v
        if changed:
            self.epoch += 1

    def merge(self, other: "TranspositionCache") -> None:
        """Fold a worker-side cache back into this one.  With no learned
        entries anywhere, keys map to identical (exact) values in every
        worker, so this is a plain order-independent update; learned
        entries merge under the exact-wins rule (``_merge_tbl``)."""
        self._merge_tbl(self.terminal, self.terminal_version,
                        other.terminal, other.terminal_version)
        self._merge_tbl(self.partial, self.partial_version,
                        other.partial, other.partial_version)
        self.hits += other.hits
        self.misses += other.misses
        self.dedup += other.dedup

    # -- incremental export (pinned-worker forward deltas) -------------
    # The pinned process-pool protocol ships each worker ONLY the cache
    # entries it has not seen yet: the master takes a per-worker
    # ``watermark()`` at every submit and sends ``export_since(wm)`` the
    # next round.  Dicts are insertion-ordered and (absent evictions and
    # in-place rewrites) append-only, so "everything since" is a pair of
    # islices — O(new entries), never a whole-table diff.  The mutation
    # ``epoch`` guards the exceptional cases: a refit eviction or an
    # exact-wins rewrite invalidates length-based cursors, and the next
    # export for every worker degrades to a full-table resync exactly
    # once (the analytic path never bumps the epoch, so it exports
    # incrementally forever).

    def watermark(self) -> Watermark:
        """Cursor for ``export_since``: the current mutation epoch plus
        the four table lengths."""
        return (self.epoch, len(self.terminal), len(self.partial),
                len(self.terminal_version), len(self.partial_version))

    def export_since(self, wm: Optional[Watermark]):
        """Entries added since ``wm`` as ``((terminal, partial,
        terminal_version, partial_version), full)``.  ``full=True`` means
        the watermark was missing or from an older mutation epoch and the
        export is a complete snapshot (receivers should evict any locally
        tagged entries the snapshot no longer certifies — see
        ``HybridCostBackend.apply_params``)."""
        if wm is None or wm[0] != self.epoch:
            return (
                (dict(self.terminal), dict(self.partial),
                 dict(self.terminal_version), dict(self.partial_version)),
                True,
            )
        return (
            (dict(itertools.islice(self.terminal.items(), wm[1], None)),
             dict(itertools.islice(self.partial.items(), wm[2], None)),
             dict(itertools.islice(self.terminal_version.items(), wm[3], None)),
             dict(itertools.islice(self.partial_version.items(), wm[4], None))),
            False,
        )

    def apply_export(self, entries, full: bool = False) -> None:
        """Fold an ``export_since`` payload into this cache (worker side
        of the forward delta).  Merging — not replacing — under the same
        exact-wins rule as ``merge``, so applying a full resync on top of
        local state is always safe."""
        t, p, tv, pv = entries
        self._merge_tbl(self.terminal, self.terminal_version, t, tv)
        self._merge_tbl(self.partial, self.partial_version, p, pv)

    def evict_learned(self) -> int:
        """Drop every learned-tagged entry (master refit superseded them;
        they reprice on next lookup).  Bumps the mutation epoch: exports
        can no longer be expressed as table-length islices."""
        n = len(self.terminal_version) + len(self.partial_version)
        if n:
            for s in self.terminal_version:
                del self.terminal[s]
            self.terminal_version.clear()
            for s in self.partial_version:
                del self.partial[s]
            self.partial_version.clear()
            self.epoch += 1
        return n


class CachedMDP:
    """``ScheduleMDP`` wrapper memoizing ``terminal_cost``/``partial_cost``.

    Everything else delegates to the wrapped MDP, so this nests around any
    object implementing the MDP protocol (including test doubles).

    ``cost_backend`` (optional, a ``HybridCostBackend``) reroutes MISS
    pricing through the learned-cost serving layer; hit/miss bookkeeping,
    deduplication, and the batch contract below are unchanged."""

    def __init__(self, mdp, cache: TranspositionCache = None,
                 cost_backend=None):
        self.mdp = mdp
        self.cache = cache if cache is not None else TranspositionCache()
        self.cost_backend = cost_backend
        if cost_backend is not None:
            cost_backend.bind(self.cache)

    # -- pure structure: straight delegation ---------------------------
    @property
    def initial_state(self) -> State:
        return self.mdp.initial_state

    @property
    def space(self):
        return self.mdp.space

    @property
    def cost_model(self):
        return self.mdp.cost_model

    def n_actions(self, state: State) -> int:
        return self.mdp.n_actions(state)

    def step(self, state: State, action: int) -> State:
        return self.mdp.step(state, action)

    def is_terminal(self, state: State) -> bool:
        return self.mdp.is_terminal(state)

    def plan(self, state: State):
        return self.mdp.plan(state)

    # -- memoized cost signals -----------------------------------------
    def terminal_cost(self, state: State) -> float:
        tbl = self.cache.terminal
        c = tbl.get(state)
        if c is not None:
            self.cache.hits += 1
            return c
        self.cache.misses += 1
        if self.cost_backend is not None:
            costs, ver = self.cost_backend.price_terminal(self.mdp, [state])
            c = costs[0]
            if ver:
                self.cache.terminal_version[state] = ver
        else:
            c = self.mdp.terminal_cost(state)
        tbl[state] = c
        return c

    def partial_cost(self, state: State) -> float:
        if self.mdp.is_terminal(state):
            return self.terminal_cost(state)
        tbl = self.cache.partial
        c = tbl.get(state)
        if c is not None:
            self.cache.hits += 1
            return c
        self.cache.misses += 1
        if self.cost_backend is not None:
            costs, ver = self.cost_backend.price_partial(self.mdp, [state])
            c = costs[0]
            if ver:
                self.cache.partial_version[state] = ver
        else:
            c = self.mdp.partial_cost(state)
        tbl[state] = c
        return c

    # -- batched cost signals ------------------------------------------
    # Contract (shared by both methods): values equal the scalar methods
    # element-for-element; hits + misses advance by exactly len(states);
    # only MISSES reach the pricing layer, deduplicated, in first-occurrence
    # order — a state appearing twice in one batch is one miss plus one
    # hit, exactly as if the batch had been priced sequentially.  A warm
    # cache therefore never changes returned values, only the hit count.
    # The deduplicated miss batch is priced COLUMNAR-SIDE: it reaches the
    # wrapped MDP's batch methods (one PlanColumns encode + one vectorized
    # roofline-kernel pass per miss batch) or, with a cost backend
    # mounted, the backend (which builds the same one-per-batch encoding
    # and feeds it to the learned MLP or the analytic kernel); newly
    # priced entries then carry the serving model's version tag.

    def _batch(self, states, tbl, vtbl, price) -> List[float]:
        out: List[Optional[float]] = [None] * len(states)
        pending: Dict[State, None] = {}  # dedup, insertion-ordered
        hits = 0
        for i, s in enumerate(states):
            c = tbl.get(s)
            if c is not None:
                out[i] = c
                hits += 1
            elif s in pending:
                hits += 1  # duplicate miss: sequential order would hit
                self.cache.dedup += 1
            else:
                pending[s] = None
        self.cache.hits += hits
        self.cache.misses += len(pending)
        if pending:
            miss_states = list(pending)
            costs, ver = price(miss_states)
            for s, c in zip(miss_states, costs):
                tbl[s] = c
                if ver:
                    vtbl[s] = ver
            for i, s in enumerate(states):
                if out[i] is None:
                    out[i] = tbl[s]
        return out

    def _terminal_price(self):
        if self.cost_backend is not None:
            return lambda miss: self.cost_backend.price_terminal(self.mdp, miss)
        inner = getattr(self.mdp, "terminal_cost_batch", None)
        if inner is None:
            return lambda miss: ([self.mdp.terminal_cost(s) for s in miss], 0)
        return lambda miss: (inner(miss), 0)

    def _partial_price(self):
        if self.cost_backend is not None:
            return lambda miss: self.cost_backend.price_partial(self.mdp, miss)
        inner = getattr(self.mdp, "partial_cost_batch", None)
        if inner is None:
            return lambda miss: ([self.mdp.partial_cost(s) for s in miss], 0)
        return lambda miss: (inner(miss), 0)

    def terminal_cost_batch(self, states: Sequence[State]) -> List[float]:
        return self._batch(
            states, self.cache.terminal, self.cache.terminal_version,
            self._terminal_price(),
        )

    def partial_cost_batch(self, states: Sequence[State]) -> List[float]:
        """Mixed batches allowed: terminal states route to the terminal
        table (as the scalar ``partial_cost`` does)."""
        is_terminal = self.mdp.is_terminal
        term_idx = [i for i, s in enumerate(states) if is_terminal(s)]
        if not term_idx:
            return self._batch(
                states, self.cache.partial, self.cache.partial_version,
                self._partial_price(),
            )
        term_set = set(term_idx)
        part_idx = [i for i in range(len(states)) if i not in term_set]
        out: List[Optional[float]] = [None] * len(states)
        for i, c in zip(term_idx,
                        self.terminal_cost_batch([states[i] for i in term_idx])):
            out[i] = c
        for i, c in zip(part_idx,
                        self.partial_cost_batch([states[i] for i in part_idx])):
            out[i] = c
        return out

    # -- serving hooks --------------------------------------------------
    def on_round_end(self) -> None:
        """Round-boundary hook (lockstep batched rounds, parallel merges):
        gives the online trainer a deterministic refit point even when no
        miss batch crosses the refit threshold mid-round."""
        if self.cost_backend is not None:
            self.cost_backend.maybe_refit()

    def __getattr__(self, name):
        # fall through for any extension attribute on the wrapped MDP;
        # dunders (and ``mdp`` itself, pre-__init__ during unpickling) must
        # raise, not recurse
        if name.startswith("_") or name == "mdp":
            raise AttributeError(name)
        return getattr(self.mdp, name)
