"""Persistent pinned process-pool workers for the parallel ensemble.

The pre-pinning pool (`ProcessPoolExecutor.submit(fn, tree)`) made the
worker→master *return* trip a true per-round delta (PR 2/4), but every
submit still pickled each whole ``ArrayMCTS`` — flat node arrays that grow
every round — plus the shared ``CachedMDP`` (the full transposition cache
and the serve-only cost backend).  The submit payload therefore grew with
the tree, not the round, and the pool kept losing to sequential below ~4
cores.

This module makes the submit side a per-round delta too.  Each worker
process is PINNED: it holds its subset of the ensemble's trees (keyed by
tree index) and one serve-only ``CachedMDP`` for the whole run, installed
once by an ``init`` snapshot.  Every subsequent round the master submits
only a FORWARD DELTA:

* ``advance`` — the previous round's root-synchronization action (the
  worker applies it to each pinned tree with ``advance_root``, exactly as
  the master did to its canonical copies);
* ``cache`` — the sibling trees' new transposition-cache entries since
  this worker's last submit, exported incrementally from the master's
  merged cache (``TranspositionCache.export_since`` against a per-worker
  watermark) so the shared-cache hit rate is preserved without ever
  re-shipping the table;
* ``params`` — learned-model parameters, ONLY when the master's fit
  generation changed (``HybridCostBackend.params_delta``); workers keep
  serving the old generation until a new one arrives.

The worker applies the forward delta, runs each pinned tree's decision
round, and returns the existing reverse delta
(``ArrayMCTS.begin_delta``/``collect_delta``) plus its round's new cache
entries and counter diffs — so the numeric payload in BOTH directions
scales with the round, not the tree.  Payload sizes are measured at the
pickle boundary (``submit_bytes``/``return_bytes``/``snapshot_bytes``,
surfaced on ``TuneResult``), so the O(round) claim is a number CI can
gate, not an assertion.

Determinism and fault tolerance: the master keeps the CANONICAL trees —
every reverse delta is applied to its copy (``apply_delta`` reproduces
the worker's post-round tree exactly), so when a pinned worker dies the
master respawns it and reseeds it from a snapshot of those trees plus the
current merged cache; the replacement re-runs the round from the identical
pre-round state (same pickled RNG), so results — plans, costs, decision
sequences — are unchanged by any number of worker deaths.  Merges happen
in worker/tree-index order regardless of completion order, preserving the
sequential-bit-identity guarantee of the analytic path.
"""
from __future__ import annotations

import multiprocessing
import os
import pickle
import traceback
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.engine.cache import CachedMDP

_PROTO = pickle.HIGHEST_PROTOCOL


def pick_mp_context():
    """forkserver where available (workers start from a clean process —
    forking a jax-threaded parent can deadlock), fork otherwise; schedule
    pricing is deliberately jax-free so workers stay cheap to spawn.

    The forkserver preloads the engine module chain (numpy, the MDP and
    cost-model modules — everything a pickled ``CachedMDP``/``ArrayMCTS``
    needs, none of it jax): children then FORK with the imports already
    done, so after the first pool of a process, worker spawn cost drops
    from an import chain to a fork."""
    methods = multiprocessing.get_all_start_methods()
    method = next((m for m in ("forkserver", "fork") if m in methods), None)
    ctx = multiprocessing.get_context(method)
    if method == "forkserver":
        # a no-op once the server is running; effective when called (as
        # here) before the first worker process ever starts
        ctx.set_forkserver_preload(["repro.core.ensemble"])
    return ctx


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------
def _apply_forward(mdp, trees: Dict[int, object], fwd: dict) -> None:
    """Apply a round's forward delta: params first (a new fit generation
    evicts the local copies of predictions the master already evicted),
    then the sibling cache entries, then the root advance (which prices
    nothing — ``advance_root`` only steps the MDP structure)."""
    cached = isinstance(mdp, CachedMDP)
    params = fwd.get("params")
    if params is not None and cached and mdp.cost_backend is not None:
        mdp.cost_backend.apply_params(params)
    cache = fwd.get("cache")
    if cache is not None and cached:
        entries, full = cache
        mdp.cache.apply_export(entries, full)
    advance = fwd.get("advance")
    if advance is not None:
        for tid in sorted(trees):
            trees[tid].advance_root(advance)


def _run_round(mdp, trees: Dict[int, object], fwd: dict):
    _apply_forward(mdp, trees, fwd)
    cached = isinstance(mdp, CachedMDP)
    backend = mdp.cost_backend if cached else None
    if cached:
        cache = mdp.cache
        h0, m0 = cache.hits, cache.misses
        wm = cache.watermark()
    serve0 = backend.counters() if backend is not None else None
    evals0 = getattr(mdp.cost_model, "n_evals", None)
    results = {}
    for tid in sorted(trees):  # deterministic within-worker order
        tree = trees[tid]
        tree.begin_delta()
        res = tree.run_decision()
        results[tid] = (tree.collect_delta(), res)
    stats = cache_new = serving = evals = None
    if cached:
        stats = (cache.hits - h0, cache.misses - m0)
        # this round's new entries: everything past the round-start
        # watermark (the worker never refits/evicts, so its tables are
        # append-only within a round and the islice export is exact)
        cache_new, _full = cache.export_since(wm)
    if serve0 is not None:
        s1 = backend.counters()
        serving = tuple(a - b for a, b in zip(s1, serve0))
    if evals0 is not None:
        evals = getattr(mdp.cost_model, "n_evals") - evals0
    return ("round", results, stats, cache_new, evals, serving)


def _worker_main(conn) -> None:
    """Pinned-worker loop: hold the init snapshot's trees + serve-only
    MDP for the whole run, answer one ``round`` message at a time."""
    mdp = None
    trees: Dict[int, object] = {}
    try:
        while True:
            try:
                msg = pickle.loads(conn.recv_bytes())
            except EOFError:
                return
            kind = msg[0]
            if kind == "init":
                # (mdp, trees) unpickle from ONE message, so the trees'
                # shared mdp reference dedups to a single object
                mdp, trees = msg[1], msg[2]
                conn.send_bytes(pickle.dumps(("ok",), _PROTO))
            elif kind == "round":
                try:
                    out = _run_round(mdp, trees, msg[1])
                except Exception:  # deterministic errors surface master-side
                    out = ("err", traceback.format_exc())
                conn.send_bytes(pickle.dumps(out, _PROTO))
            elif kind == "stop":
                return
    except (BrokenPipeError, ConnectionResetError, KeyboardInterrupt, OSError):
        return


# ---------------------------------------------------------------------------
# Master side
# ---------------------------------------------------------------------------
@dataclass
class _Worker:
    proc: object
    conn: object
    tids: List[int]
    watermark: Optional[tuple] = None
    known_version: int = 0
    just_synced: bool = True  # init snapshot already holds the advance/cache
    submitted: bool = False   # a round message is in flight
    # keys this worker itself returned last round (pure-analytic runs
    # only): its own entries land in the master cache past its submit-time
    # watermark, so without this they would be echoed straight back next
    # round — ~1/n_workers of every incremental export, pure waste
    echo: Optional[tuple] = None


class PinnedWorkerPool:
    """Master-side handle over the pinned workers.

    ``trees`` is the ensemble's canonical (master) tree list — this pool
    mutates it: reverse deltas are applied to these objects every round,
    which is both what the winner selection reads and what worker-death
    resync snapshots.  ``mdp`` is the shared (usually ``CachedMDP``) the
    trees search over.
    """

    def __init__(self, trees: List[object], mdp, *,
                 n_workers: Optional[int] = None, mp_context=None):
        self.trees = trees
        self.mdp = mdp
        self.cached = isinstance(mdp, CachedMDP)
        self.backend = mdp.cost_backend if self.cached else None
        ctx = mp_context if mp_context is not None else pick_mp_context()
        self._ctx = ctx
        n = n_workers or os.cpu_count() or 2
        if trees:  # never more workers than trees — but an EMPTY pool
            n = min(n, len(trees))  # (service pre-spawn before any run)
        n = max(n, 1)  # keeps the requested width for a later rebind()
        # payload accounting (pickled bytes crossing the pool boundary)
        self.submit_bytes = 0
        self.return_bytes = 0
        self.snapshot_bytes = 0  # init + death-resync whole-state shipments
        self.submit_bytes_rounds: List[int] = []
        self.return_bytes_rounds: List[int] = []
        self.n_worker_restarts = 0
        self.extra_evals = 0  # worker-side cost-model evals (per-round diffs)
        # round-robin pinning: tree i lives on worker i % n for the run.
        # Spawn + init overlap across workers: all processes launch and
        # receive their snapshots before the first (blocking) ack read.
        self._workers = [
            self._launch([t for t in range(len(trees)) if t % n == w])
            for w in range(n)
        ]
        for w in self._workers:
            self._await_init(w)

    # -- lifecycle -----------------------------------------------------
    def _launch(self, tids: List[int]) -> _Worker:
        """Start a worker process and ship its init snapshot: this
        worker's canonical trees plus the shared MDP (cache counters and
        serving counters pickle zeroed; the backend pickles serve-only).
        Paid once at startup and once per worker death — never per
        round."""
        parent, child = self._ctx.Pipe(duplex=True)
        proc = self._ctx.Process(
            target=_worker_main, args=(child,), daemon=True)
        proc.start()
        child.close()
        w = _Worker(proc, parent, tids)
        payload = pickle.dumps(
            ("init", self.mdp, {tid: self.trees[tid] for tid in w.tids}),
            _PROTO,
        )
        w.conn.send_bytes(payload)
        self.snapshot_bytes += len(payload)
        if self.cached:
            w.watermark = self.mdp.cache.watermark()
        if self.backend is not None:
            w.known_version = self.backend.trainer.version
        return w

    def _await_init(self, w: _Worker) -> None:
        ack = pickle.loads(w.conn.recv_bytes())
        if ack != ("ok",):
            raise RuntimeError(f"pinned worker failed to initialize: {ack!r}")

    def _spawn(self, tids: List[int]) -> _Worker:
        w = self._launch(tids)
        self._await_init(w)
        return w

    def _resync(self, w: _Worker) -> _Worker:
        """Worker-death recovery: respawn and reseed from the master's
        canonical trees + merged cache.  The snapshot is exactly the
        worker's lost pre-round state (same pickled RNG), so re-running
        the round reproduces the lost results bit-for-bit."""
        self.n_worker_restarts += 1
        try:
            w.conn.close()
        except OSError:
            pass
        if w.proc.is_alive():
            w.proc.terminate()
        w.proc.join(timeout=5)
        fresh = self._spawn(w.tids)
        self._workers[self._workers.index(w)] = fresh
        return fresh

    def rebind(self, trees: List[object], mdp) -> None:
        """Re-point the LIVE worker processes at a new run's canonical
        trees + MDP (the daemon reuses one pool across tuning runs, so
        worker spawn cost is paid once per process, not once per request).

        Ships a fresh ``init`` snapshot to every worker — the worker loop
        already accepts repeated inits — and resets all per-worker cursors
        (cache watermark, model generation, echo set) to the new run's
        state.  A worker that died between runs is respawned here."""
        self.trees = trees
        self.mdp = mdp
        self.cached = isinstance(mdp, CachedMDP)
        self.backend = mdp.cost_backend if self.cached else None
        n = len(self._workers)
        pending = []
        for wi, w in enumerate(list(self._workers)):
            w.tids = [t for t in range(len(trees)) if t % n == wi]
            payload = pickle.dumps(
                ("init", mdp, {tid: trees[tid] for tid in w.tids}), _PROTO)
            try:
                w.conn.send_bytes(payload)
            except (BrokenPipeError, ConnectionResetError, OSError):
                self._resync(w)  # respawn ships the same snapshot
                continue
            self.snapshot_bytes += len(payload)
            if self.cached:
                w.watermark = mdp.cache.watermark()
            if self.backend is not None:
                w.known_version = self.backend.trainer.version
            w.just_synced = True
            w.submitted = False
            w.echo = None
            pending.append(wi)
        for wi in pending:
            w = self._workers[wi]
            try:
                self._await_init(w)
            except (EOFError, ConnectionResetError, OSError):
                self._resync(w)

    def shutdown(self) -> None:
        for w in self._workers:
            try:
                w.conn.send_bytes(pickle.dumps(("stop",), _PROTO))
            except (BrokenPipeError, ConnectionResetError, OSError):
                pass
        for w in self._workers:
            w.proc.join(timeout=5)
            if w.proc.is_alive():
                w.proc.terminate()
            try:
                w.conn.close()
            except OSError:
                pass

    # -- the per-round protocol ----------------------------------------
    def _forward(self, w: _Worker, advance: Optional[int]) -> dict:
        """Build this worker's forward delta and move its cursors.  A
        just-(re)synced worker's snapshot already contains the advance,
        the full cache, and the current model — everything ships empty."""
        fwd: dict = {"advance": None if w.just_synced else advance}
        w.just_synced = False
        if self.cached:
            if w.watermark != (wm := self.mdp.cache.watermark()):
                entries, full = self.mdp.cache.export_since(w.watermark)
                if not full and w.echo is not None:
                    # drop the worker's own last-round entries: a pure
                    # memo maps a key to one exact value, so the worker's
                    # copy is already the merged value (learned runs never
                    # set ``echo`` — a sibling's exact audit can overwrite
                    # a prediction, and the worker must see that)
                    t, p, tv, pv = entries
                    et, ep = w.echo
                    entries = (
                        {k: v for k, v in t.items() if k not in et},
                        {k: v for k, v in p.items() if k not in ep},
                        tv, pv,
                    )
                fwd["cache"] = (entries, full)
                w.watermark = wm
            else:
                fwd["cache"] = None
            w.echo = None
        if self.backend is not None:
            fwd["params"] = self.backend.params_delta(w.known_version)
            w.known_version = self.backend.trainer.version
        return fwd

    def _submit(self, w: _Worker, advance: Optional[int]) -> None:
        buf = pickle.dumps(("round", self._forward(w, advance)), _PROTO)
        w.conn.send_bytes(buf)
        self.submit_bytes += len(buf)
        self._round_submit += len(buf)
        w.submitted = True

    def _collect(self, w: _Worker, advance: Optional[int]):
        """One worker's round result; on a dead pipe, resync and re-run
        the round once before giving up."""
        for attempt in (0, 1):
            try:
                if not w.submitted:
                    self._submit(w, advance)
                buf = w.conn.recv_bytes()
            except (BrokenPipeError, ConnectionResetError, EOFError, OSError):
                if attempt:
                    raise RuntimeError(
                        f"pinned worker for trees {w.tids} died twice in "
                        f"one round") from None
                w = self._resync(w)
                continue
            w.submitted = False
            self.return_bytes += len(buf)
            self._round_return += len(buf)
            msg = pickle.loads(buf)
            if msg[0] == "err":
                raise RuntimeError(f"pinned worker raised:\n{msg[1]}")
            return msg[1:]
        raise AssertionError("unreachable")

    def round(self, advance: Optional[int] = None) -> List[object]:
        """One decision round across all pinned workers.

        Submits every worker's forward delta, then collects and merges in
        worker order (each worker's trees in index order) — deterministic
        regardless of completion order.  Returns the per-tree
        ``DecisionResult``s in tree-index order."""
        self._round_submit = 0
        self._round_return = 0
        for w in list(self._workers):
            try:
                self._submit(w, advance)
            except (BrokenPipeError, ConnectionResetError, OSError):
                self._resync(w)  # snapshot embeds the advance; collect submits
        results: Dict[int, object] = {}
        for i in range(len(self._workers)):
            # re-read: _collect may have replaced the worker via resync
            got = self._collect(self._workers[i], advance)
            tree_out, stats, cache_new, evals, serving = got
            for tid in sorted(tree_out):
                delta, res = tree_out[tid]
                self.trees[tid].apply_delta(delta)
                results[tid] = res
            if self.cached and cache_new is not None:
                self.mdp.cache.apply_export(cache_new)
                if stats is not None:
                    self.mdp.cache.hits += stats[0]
                    self.mdp.cache.misses += stats[1]
                if self.backend is None:
                    # pure-analytic: remember what this worker just sent
                    # so next round's forward export skips echoing it back
                    self._workers[i].echo = (
                        set(cache_new[0]), set(cache_new[1]))
            if serving is not None and self.backend is not None:
                self.backend.merge_counters(serving)
            if evals is not None:
                self.extra_evals += evals
        self.submit_bytes_rounds.append(self._round_submit)
        self.return_bytes_rounds.append(self._round_return)
        return [results[tid] for tid in range(len(self.trees))]
