"""Persistent pinned process-pool workers for the parallel ensemble.

The pre-pinning pool (`ProcessPoolExecutor.submit(fn, tree)`) made the
worker→master *return* trip a true per-round delta (PR 2/4), but every
submit still pickled each whole ``ArrayMCTS`` — flat node arrays that grow
every round — plus the shared ``CachedMDP`` (the full transposition cache
and the serve-only cost backend).  The submit payload therefore grew with
the tree, not the round, and the pool kept losing to sequential below ~4
cores.

This module makes the submit side a per-round delta too.  Each worker
process is PINNED: it holds its subset of the ensemble's trees (keyed by
tree index) and one serve-only ``CachedMDP`` for the whole run, installed
once by an ``init`` snapshot.  Every subsequent round the master submits
only a FORWARD DELTA:

* ``advance`` — the previous round's root-synchronization action (the
  worker applies it to each pinned tree with ``advance_root``, exactly as
  the master did to its canonical copies);
* ``shm`` — on pure-analytic runs with POSIX shared memory available
  (the default), the sibling cache entries do not ride the pipe at all:
  the master appends every round's new entries to a shared-memory log
  (``engine/shm_cache.ShmCacheLog``) and the forward delta carries only
  the segment name and write cursor; the worker maps the segment
  read-only and folds the unseen rows into its local cache
  (``ShmCacheReader.fold``) — cross-process cache hits with O(1) submit
  payload.  The segment's lifecycle is owned by this pool: created at
  init-snapshot time, resized by publish-new-then-swap, swapped (and the
  old generation unlinked) on worker-death ``_resync``, unlinked on
  ``shutdown()``;
* ``cache`` — the export fallback: the sibling trees' new entries since
  this worker's last submit, exported incrementally from the master's
  merged cache (``TranspositionCache.export_since`` against a per-worker
  watermark).  Engages when shm is unavailable or disabled, and whenever
  the cache stops being append-only (a learned-tag eviction or
  exact-wins rewrite bumps the mutation ``epoch``) — the pool then
  unlinks the log and degrades every worker to one full-export resync,
  exactly as the epoch machinery already degrades stale watermarks;
* ``params`` — learned-model parameters, ONLY when the master's fit
  generation changed (``HybridCostBackend.params_delta``); workers keep
  serving the old generation until a new one arrives.

The worker applies the forward delta, runs each pinned tree's decision
round — scalar ``run_decision`` per tree, or ONE lockstep
``run_decision_batch`` over its whole pinned subset when the pool was
built with ``worker_batch=True`` (batched leaf pricing and the pool then
compose: each worker prices one deduplicated miss batch per step through
the columnar kernel instead of K scalar walks) — and returns the
existing reverse delta (``ArrayMCTS.begin_delta``/``collect_delta``)
plus its round's new cache entries and counter diffs — so the numeric
payload in BOTH directions scales with the round, not the tree.  Payload
sizes are measured at the pickle boundary
(``submit_bytes``/``return_bytes``/``snapshot_bytes``, surfaced on
``TuneResult``), so the O(round) claim is a number CI can gate, not an
assertion; per-worker hit/miss/dedup counters and the shm-vs-export
serving split are surfaced the same way (``PinnedWorkerPool.stats()``),
as is the round's cross-worker duplicate-eval count (distinct states
priced by two or more workers in the same round — the quantity the
shared cache exists to crush).

Determinism and fault tolerance: the master keeps the CANONICAL trees —
every reverse delta is applied to its copy (``apply_delta`` reproduces
the worker's post-round tree exactly), so when a pinned worker dies the
master respawns it and reseeds it from a snapshot of those trees plus the
current merged cache; the replacement re-runs the round from the identical
pre-round state (same pickled RNG), so results — plans, costs, decision
sequences — are unchanged by any number of worker deaths.  Merges happen
in worker/tree-index order regardless of completion order, preserving the
sequential-bit-identity guarantee of the analytic path.
"""
from __future__ import annotations

import multiprocessing
import os
import pickle
import traceback
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.engine.cache import CachedMDP
from repro.core.engine.shm_cache import HAVE_SHM, ShmCacheLog, ShmCacheReader

_PROTO = pickle.HIGHEST_PROTOCOL


def pick_mp_context():
    """forkserver where available (workers start from a clean process —
    forking a jax-threaded parent can deadlock), fork otherwise; schedule
    pricing is deliberately jax-free so workers stay cheap to spawn.

    The forkserver preloads the engine module chain (numpy, the MDP and
    cost-model modules — everything a pickled ``CachedMDP``/``ArrayMCTS``
    needs, none of it jax): children then FORK with the imports already
    done, so after the first pool of a process, worker spawn cost drops
    from an import chain to a fork."""
    methods = multiprocessing.get_all_start_methods()
    method = next((m for m in ("forkserver", "fork") if m in methods), None)
    ctx = multiprocessing.get_context(method)
    if method == "forkserver":
        # a no-op once the server is running; effective when called (as
        # here) before the first worker process ever starts
        ctx.set_forkserver_preload(["repro.core.ensemble"])
    return ctx


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------
def _apply_forward(mdp, trees: Dict[int, object], fwd: dict,
                   reader: Optional[ShmCacheReader] = None) -> None:
    """Apply a round's forward delta: params first (a new fit generation
    evicts the local copies of predictions the master already evicted),
    then the sibling cache entries (folded from the shared-memory log
    when the round message carries a cursor, applied from the pickled
    export otherwise), then the root advance (which prices nothing —
    ``advance_root`` only steps the MDP structure)."""
    cached = isinstance(mdp, CachedMDP)
    params = fwd.get("params")
    if params is not None and cached and mdp.cost_backend is not None:
        mdp.cost_backend.apply_params(params)
    shm = fwd.get("shm")
    if shm is not None and reader is not None and cached:
        if isinstance(shm, tuple):  # generation changed: new segment name
            reader.fold(mdp.cache, shm[0], shm[1])
        else:  # steady round: bare cursor over the current segment
            reader.fold(mdp.cache, reader.name, shm)
    cache = fwd.get("cache")
    if cache is not None and cached:
        entries, full = cache
        mdp.cache.apply_export(entries, full)
    advance = fwd.get("advance")
    if advance is not None:
        for tid in sorted(trees):
            trees[tid].advance_root(advance)


def _run_round(mdp, trees: Dict[int, object], fwd: dict,
               reader: Optional[ShmCacheReader] = None,
               batch: bool = False):
    _apply_forward(mdp, trees, fwd, reader)
    cached = isinstance(mdp, CachedMDP)
    backend = mdp.cost_backend if cached else None
    if cached:
        cache = mdp.cache
        h0, m0, d0 = cache.hits, cache.misses, cache.dedup
        wm = cache.watermark()
    serve0 = backend.counters() if backend is not None else None
    evals0 = getattr(mdp.cost_model, "n_evals", None)
    results = {}
    tids = sorted(trees)  # deterministic within-worker order
    if batch and tids:
        # in-worker lockstep: ONE batched decision round over the whole
        # pinned subset — delta recording is cursor-aware (engine/batch),
        # so the reverse transport is unchanged
        from repro.core.engine.batch import run_decision_batch

        for tid in tids:
            trees[tid].begin_delta()
        ress = run_decision_batch([trees[tid] for tid in tids], mdp)
        for tid, res in zip(tids, ress):
            results[tid] = (trees[tid].collect_delta(), res)
    else:
        for tid in tids:
            tree = trees[tid]
            tree.begin_delta()
            res = tree.run_decision()
            results[tid] = (tree.collect_delta(), res)
    stats = cache_new = serving = evals = None
    if cached:
        stats = {
            "hits": cache.hits - h0,
            "misses": cache.misses - m0,
            "dedup": cache.dedup - d0,
        }
        # this round's new entries: everything past the round-start
        # watermark (the worker never refits/evicts, so its tables are
        # append-only within a round and the islice export is exact)
        cache_new, _full = cache.export_since(wm)
    if serve0 is not None:
        s1 = backend.counters()
        serving = tuple(a - b for a, b in zip(s1, serve0))
    if evals0 is not None:
        evals = getattr(mdp.cost_model, "n_evals") - evals0
    return ("round", results, stats, cache_new, evals, serving)


def _worker_main(conn) -> None:
    """Pinned-worker loop: hold the init snapshot's trees + serve-only
    MDP for the whole run, answer one ``round`` message at a time."""
    mdp = None
    trees: Dict[int, object] = {}
    reader: Optional[ShmCacheReader] = None
    batch = False
    try:
        while True:
            try:
                msg = pickle.loads(conn.recv_bytes())
            except EOFError:
                return
            kind = msg[0]
            if kind == "init":
                # (mdp, trees) unpickle from ONE message, so the trees'
                # shared mdp reference dedups to a single object
                mdp, trees = msg[1], msg[2]
                opts = msg[3] if len(msg) > 3 else {}
                batch = bool(opts.get("batch"))
                if reader is not None:
                    reader.close()
                    reader = None
                shm_info = opts.get("shm")
                if shm_info is not None and HAVE_SHM:
                    # attach at the snapshot-time cursor: the pickled
                    # cache already holds every row up to it
                    reader = ShmCacheReader()
                    reader.attach(*shm_info)
                conn.send_bytes(pickle.dumps(("ok",), _PROTO))
            elif kind == "round":
                try:
                    out = _run_round(mdp, trees, msg[1], reader, batch)
                except Exception:  # deterministic errors surface master-side
                    out = ("err", traceback.format_exc())
                conn.send_bytes(pickle.dumps(out, _PROTO))
            elif kind == "stop":
                if reader is not None:
                    reader.close()
                return
    except (BrokenPipeError, ConnectionResetError, KeyboardInterrupt, OSError):
        return


# ---------------------------------------------------------------------------
# Master side
# ---------------------------------------------------------------------------
@dataclass
class _Worker:
    proc: object
    conn: object
    tids: List[int]
    watermark: Optional[tuple] = None
    known_version: int = 0
    just_synced: bool = True  # init snapshot already holds the advance/cache
    submitted: bool = False   # a round message is in flight
    # keys this worker itself returned last round (pure-analytic runs
    # only): its own entries land in the master cache past its submit-time
    # watermark, so without this they would be echoed straight back next
    # round — ~1/n_workers of every incremental export, pure waste
    echo: Optional[tuple] = None
    # shm-log cursor and segment name as of the last message this worker
    # was sent (steady rounds ship the bare cursor int; the name rides
    # along only when the generation changed)
    shm_count: int = 0
    shm_name: Optional[str] = None
    # cumulative counters (hits/misses/dedup from round returns,
    # shm_entries/export_entries accounted master-side at submit) —
    # carried across death-resyncs, surfaced by ``PinnedWorkerPool.stats``
    stats: Dict[str, int] = field(default_factory=dict)


class PinnedWorkerPool:
    """Master-side handle over the pinned workers.

    ``trees`` is the ensemble's canonical (master) tree list — this pool
    mutates it: reverse deltas are applied to these objects every round,
    which is both what the winner selection reads and what worker-death
    resync snapshots.  ``mdp`` is the shared (usually ``CachedMDP``) the
    trees search over.
    """

    def __init__(self, trees: List[object], mdp, *,
                 n_workers: Optional[int] = None, mp_context=None,
                 shm: Optional[bool] = None, worker_batch: bool = False):
        self.trees = trees
        self.mdp = mdp
        self.cached = isinstance(mdp, CachedMDP)
        self.backend = mdp.cost_backend if self.cached else None
        self.shm_opt = shm  # None = auto (on for pure-analytic runs)
        self.worker_batch = worker_batch
        ctx = mp_context if mp_context is not None else pick_mp_context()
        self._ctx = ctx
        n = n_workers or os.cpu_count() or 2
        if trees:  # never more workers than trees — but an EMPTY pool
            n = min(n, len(trees))  # (service pre-spawn before any run)
        n = max(n, 1)  # keeps the requested width for a later rebind()
        # payload accounting (pickled bytes crossing the pool boundary)
        self.submit_bytes = 0
        self.return_bytes = 0
        self.snapshot_bytes = 0  # init + death-resync whole-state shipments
        self.submit_bytes_rounds: List[int] = []
        self.return_bytes_rounds: List[int] = []
        self.n_worker_restarts = 0
        # restarts attributable to the CURRENT binding (reset by rebind():
        # the daemon's health watchdog reads this to tell "one bad run"
        # from "the pool is repeatedly dying")
        self.restarts_since_rebind = 0
        self.extra_evals = 0  # worker-side cost-model evals (per-round diffs)
        # cross-worker duplicate evals: per round, the number of (state,
        # table) keys that TWO OR MORE workers priced independently —
        # deterministic (derived from the returned exports, which depend
        # only on search trajectories), so CI can gate on it
        self.dup_evals = 0
        self.dup_evals_rounds: List[int] = []
        self._shm: Optional[ShmCacheLog] = None
        self._shm_wm = None
        self.shm_used = False  # log existed for this run (survives shutdown)
        if self._shm_eligible():
            self._shm = ShmCacheLog()
            self._shm_wm = mdp.cache.watermark()
            self.shm_used = True
        # round-robin pinning: tree i lives on worker i % n for the run.
        # Spawn + init overlap across workers: all processes launch and
        # receive their snapshots before the first (blocking) ack read.
        self._workers = [
            self._launch([t for t in range(len(trees)) if t % n == w])
            for w in range(n)
        ]
        for w in self._workers:
            self._await_init(w)

    # -- lifecycle -----------------------------------------------------
    def _shm_eligible(self) -> bool:
        """shm serves the append-only pure-analytic path only: a mounted
        cost backend can evict/rewrite entries, which the log cannot
        express (the export/epoch protocol handles those runs)."""
        return (HAVE_SHM and self.shm_opt is not False and self.cached
                and self.backend is None)

    @property
    def shm_enabled(self) -> bool:
        return self._shm is not None

    def _launch(self, tids: List[int]) -> _Worker:
        """Start a worker process and ship its init snapshot: this
        worker's canonical trees plus the shared MDP (cache counters and
        serving counters pickle zeroed; the backend pickles serve-only).
        Paid once at startup and once per worker death — never per
        round."""
        parent, child = self._ctx.Pipe(duplex=True)
        proc = self._ctx.Process(
            target=_worker_main, args=(child,), daemon=True)
        proc.start()
        child.close()
        w = _Worker(proc, parent, tids)
        opts = {"batch": self.worker_batch}
        if self._shm is not None:
            # attach-at-cursor: the snapshot cache below already holds
            # every row up to the current count
            opts["shm"] = (self._shm.name, self._shm.count)
            w.shm_count = self._shm.count
            w.shm_name = self._shm.name
        payload = pickle.dumps(
            ("init", self.mdp, {tid: self.trees[tid] for tid in w.tids},
             opts),
            _PROTO,
        )
        w.conn.send_bytes(payload)
        self.snapshot_bytes += len(payload)
        if self.cached:
            w.watermark = self.mdp.cache.watermark()
        if self.backend is not None:
            w.known_version = self.backend.trainer.version
        return w

    def _await_init(self, w: _Worker) -> None:
        ack = pickle.loads(w.conn.recv_bytes())
        if ack != ("ok",):
            raise RuntimeError(f"pinned worker failed to initialize: {ack!r}")

    def _spawn(self, tids: List[int]) -> _Worker:
        w = self._launch(tids)
        self._await_init(w)
        return w

    def _resync(self, w: _Worker) -> _Worker:
        """Worker-death recovery: respawn and reseed from the master's
        canonical trees + merged cache.  The snapshot is exactly the
        worker's lost pre-round state (same pickled RNG), so re-running
        the round reproduces the lost results bit-for-bit."""
        self.n_worker_restarts += 1
        self.restarts_since_rebind += 1
        try:
            w.conn.close()
        except OSError:
            pass
        if w.proc.is_alive():
            w.proc.terminate()
        w.proc.join(timeout=5)
        if self._shm is not None:
            # generation bump: the dead worker can never have the retiring
            # segment mapped again; live workers and the respawn get the
            # new name, and the old file is unlinked at the round boundary
            self._shm.swap()
        fresh = self._spawn(w.tids)
        fresh.stats = w.stats  # counters survive the death
        self._workers[self._workers.index(w)] = fresh
        return fresh

    def rebind(self, trees: List[object], mdp, *,
               shm: Optional[bool] = None,
               worker_batch: Optional[bool] = None) -> None:
        """Re-point the LIVE worker processes at a new run's canonical
        trees + MDP (the daemon reuses one pool across tuning runs, so
        worker spawn cost is paid once per process, not once per request).

        Ships a fresh ``init`` snapshot to every worker — the worker loop
        already accepts repeated inits — and resets all per-worker cursors
        (cache watermark, model generation, echo set, shm cursor) to the
        new run's state; the previous run's shm segment is unlinked and a
        fresh log created if the new run is shm-eligible.  A worker that
        died between runs is respawned here."""
        self.trees = trees
        self.mdp = mdp
        self.cached = isinstance(mdp, CachedMDP)
        self.backend = mdp.cost_backend if self.cached else None
        if worker_batch is not None:
            self.worker_batch = worker_batch
        self.shm_opt = shm  # new run's preference (None = auto)
        if self._shm is not None:
            self._shm.close()
            self._shm.unlink()
            self._shm = None
            self._shm_wm = None
        self.shm_used = False
        if self._shm_eligible():
            self._shm = ShmCacheLog()
            self._shm_wm = mdp.cache.watermark()
            self.shm_used = True
        # per-run counters restart with the new run's trees
        # (n_worker_restarts stays cumulative over the pool's lifetime)
        self.restarts_since_rebind = 0
        self.dup_evals = 0
        self.dup_evals_rounds = []
        self.submit_bytes_rounds = []
        self.return_bytes_rounds = []
        n = len(self._workers)
        pending = []
        for wi, w in enumerate(list(self._workers)):
            w.tids = [t for t in range(len(trees)) if t % n == wi]
            opts = {"batch": self.worker_batch}
            if self._shm is not None:
                opts["shm"] = (self._shm.name, self._shm.count)
                w.shm_count = self._shm.count
                w.shm_name = self._shm.name
            else:
                w.shm_count = 0
                w.shm_name = None
            payload = pickle.dumps(
                ("init", mdp, {tid: trees[tid] for tid in w.tids}, opts),
                _PROTO)
            try:
                w.conn.send_bytes(payload)
            except (BrokenPipeError, ConnectionResetError, OSError):
                w.stats = {}  # new run: counters restart even on respawn
                self._resync(w)  # respawn ships the same snapshot
                continue
            self.snapshot_bytes += len(payload)
            if self.cached:
                w.watermark = mdp.cache.watermark()
            if self.backend is not None:
                w.known_version = self.backend.trainer.version
            w.just_synced = True
            w.submitted = False
            w.echo = None
            w.stats = {}
            pending.append(wi)
        for wi in pending:
            w = self._workers[wi]
            try:
                self._await_init(w)
            except (EOFError, ConnectionResetError, OSError):
                self._resync(w)

    def shutdown(self) -> None:
        for w in self._workers:
            try:
                w.conn.send_bytes(pickle.dumps(("stop",), _PROTO))
            except (BrokenPipeError, ConnectionResetError, OSError):
                pass
        for w in self._workers:
            w.proc.join(timeout=5)
            if w.proc.is_alive():
                w.proc.terminate()
            try:
                w.conn.close()
            except OSError:
                pass
        if self._shm is not None:
            self._shm.close()
            self._shm.unlink()
            self._shm = None

    # -- the per-round protocol ----------------------------------------
    def _forward(self, w: _Worker, advance: Optional[int]) -> dict:
        """Build this worker's forward delta and move its cursors.  A
        just-(re)synced worker's snapshot already contains the advance,
        the full cache, and the current model — everything ships empty.
        With the shm log live, sibling cache entries ship as an O(1)
        (segment name, cursor) pair instead of a pickled export."""
        fwd: dict = {"advance": None if w.just_synced else advance}
        w.just_synced = False
        if self._shm is not None:
            if w.shm_name == self._shm.name:
                fwd["shm"] = self._shm.count  # steady: bare cursor int
            else:
                fwd["shm"] = (self._shm.name, self._shm.count)
                w.shm_name = self._shm.name
            s = w.stats
            s["shm_entries"] = (
                s.get("shm_entries", 0) + self._shm.count - w.shm_count)
            w.shm_count = self._shm.count
            # the per-worker export watermark idles while shm serves; it
            # is re-armed (set to None → one full export) on shm disable
        elif self.cached:
            if w.watermark != (wm := self.mdp.cache.watermark()):
                entries, full = self.mdp.cache.export_since(w.watermark)
                if not full and w.echo is not None:
                    # drop the worker's own last-round entries: a pure
                    # memo maps a key to one exact value, so the worker's
                    # copy is already the merged value (learned runs never
                    # set ``echo`` — a sibling's exact audit can overwrite
                    # a prediction, and the worker must see that)
                    t, p, tv, pv = entries
                    et, ep = w.echo
                    entries = (
                        {k: v for k, v in t.items() if k not in et},
                        {k: v for k, v in p.items() if k not in ep},
                        tv, pv,
                    )
                fwd["cache"] = (entries, full)
                w.watermark = wm
                s = w.stats
                s["export_entries"] = (
                    s.get("export_entries", 0)
                    + len(entries[0]) + len(entries[1]))
            else:
                fwd["cache"] = None
            w.echo = None
        if self.backend is not None:
            fwd["params"] = self.backend.params_delta(w.known_version)
            w.known_version = self.backend.trainer.version
        return fwd

    def _submit(self, w: _Worker, advance: Optional[int]) -> None:
        buf = pickle.dumps(("round", self._forward(w, advance)), _PROTO)
        w.conn.send_bytes(buf)
        self.submit_bytes += len(buf)
        self._round_submit += len(buf)
        w.submitted = True

    def _collect(self, w: _Worker, advance: Optional[int]):
        """One worker's round result; on a dead pipe, resync and re-run
        the round once before giving up."""
        for attempt in (0, 1):
            try:
                if not w.submitted:
                    self._submit(w, advance)
                buf = w.conn.recv_bytes()
            except (BrokenPipeError, ConnectionResetError, EOFError, OSError):
                if attempt:
                    raise RuntimeError(
                        f"pinned worker for trees {w.tids} died twice in "
                        f"one round") from None
                w = self._resync(w)
                continue
            w.submitted = False
            self.return_bytes += len(buf)
            self._round_return += len(buf)
            msg = pickle.loads(buf)
            if msg[0] == "err":
                raise RuntimeError(f"pinned worker raised:\n{msg[1]}")
            return msg[1:]
        raise AssertionError("unreachable")

    def round(self, advance: Optional[int] = None) -> List[object]:
        """One decision round across all pinned workers.

        Submits every worker's forward delta, then collects and merges in
        worker order (each worker's trees in index order) — deterministic
        regardless of completion order.  Returns the per-tree
        ``DecisionResult``s in tree-index order."""
        self._round_submit = 0
        self._round_return = 0
        for w in list(self._workers):
            try:
                self._submit(w, advance)
            except (BrokenPipeError, ConnectionResetError, OSError):
                self._resync(w)  # snapshot embeds the advance; collect submits
        results: Dict[int, object] = {}
        exports: List[tuple] = []  # per-worker returned key sets (dup count)
        for i in range(len(self._workers)):
            # re-read: _collect may have replaced the worker via resync
            got = self._collect(self._workers[i], advance)
            tree_out, stats, cache_new, evals, serving = got
            for tid in sorted(tree_out):
                delta, res = tree_out[tid]
                self.trees[tid].apply_delta(delta)
                results[tid] = res
            if self.cached and cache_new is not None:
                self.mdp.cache.apply_export(cache_new)
                if stats is not None:
                    self.mdp.cache.hits += stats["hits"]
                    self.mdp.cache.misses += stats["misses"]
                    self.mdp.cache.dedup += stats["dedup"]
                    ws = self._workers[i].stats
                    for k, v in stats.items():
                        ws[k] = ws.get(k, 0) + v
                keys = (set(cache_new[0]), set(cache_new[1]))
                exports.append(keys)
                if self.backend is None and self._shm is None:
                    # pure-analytic export mode: remember what this worker
                    # just sent so next round's export skips echoing it
                    # back (the shm log has no echo problem — re-folding
                    # your own exact entry is a no-op dict insert)
                    self._workers[i].echo = keys
            if serving is not None and self.backend is not None:
                self.backend.merge_counters(serving)
            if evals is not None:
                self.extra_evals += evals
        # cross-worker duplicate evals: a key in >=2 workers' returns was
        # priced that many times this round — the re-pricing the shared
        # cache exists to eliminate (deterministic: a pure function of
        # the search trajectories, not of timing)
        dup = 0
        if len(exports) > 1:
            for k in (0, 1):
                counts: Dict[object, int] = {}
                for keys in exports:
                    for s in keys[k]:
                        counts[s] = counts.get(s, 0) + 1
                dup += sum(c - 1 for c in counts.values() if c > 1)
        self.dup_evals += dup
        self.dup_evals_rounds.append(dup)
        if self._shm is not None:
            self._shm_append()
        self.submit_bytes_rounds.append(self._round_submit)
        self.return_bytes_rounds.append(self._round_return)
        return [results[tid] for tid in range(len(self.trees))]

    def _shm_append(self) -> None:
        """Publish the round's new master-cache entries to the shm log.
        Any sign the tables stopped being append-only (an epoch bump, a
        learned tag) disables shm for the rest of the run: the log is
        unlinked and every worker degrades to one full-export resync —
        the same path a stale watermark already takes."""
        cache = self.mdp.cache
        entries, full = cache.export_since(self._shm_wm)
        if full or entries[2] or entries[3]:
            self._shm_disable()
            return
        self._shm.append(entries)
        self._shm_wm = cache.watermark()
        self._shm.drain_retired()  # no round message names old gens now

    def _shm_disable(self) -> None:
        if self._shm is None:
            return
        self._shm.close()
        self._shm.unlink()
        self._shm = None
        self._shm_wm = None
        for w in self._workers:
            w.watermark = None  # next forward: full export resync
            w.echo = None

    # -- introspection --------------------------------------------------
    def stats(self) -> dict:
        """Per-worker counters and pool-level dedup/dup-eval totals, in
        worker-slot order (surfaced on ``TuneResult.stats``)."""
        return {
            "shm": self.shm_used,
            "worker_batch": self.worker_batch,
            "n_worker_restarts": self.n_worker_restarts,
            "restarts_since_rebind": self.restarts_since_rebind,
            "dup_evals": self.dup_evals,
            "dup_evals_rounds": list(self.dup_evals_rounds),
            "workers": [dict(w.stats) for w in self._workers],
        }
