"""Array-flattened MCTS: the reference search in flat numpy storage.

Same algorithm as ``repro.core.mcts.MCTS`` — selection, expansion,
simulation, backprop, all three UCB variants, both simulation policies —
but the tree lives in flat arrays indexed by node id
(``visit_counts``, ``sum_cost``, ``sum_reward``, ``best_cost``,
``node_action``, and a ``children`` id table), so the per-level UCB score
is computed over all children at once instead of a Python
``max(..., key=...)`` over ``Node`` objects (after Ragan et al.,
*Array-Based Monte Carlo Tree Search*): one vectorized numpy expression
for wide nodes, an unrolled scalar loop over the same arrays for narrow
nodes where numpy call overhead would dominate.  For ``ScheduleMDP``s the
engine additionally precomputes the static depth->n_actions table so
selection and rollout skip per-step MDP dispatch.

Behavioral equivalence is exact, not approximate: the RNG call sequence
matches the reference line for line, and every float in the UCB score is
computed with the same IEEE-754 operations in the same order (the scalar
``math.log`` of the parent count feeds correctly-rounded numpy
``sqrt``/``divide``/``multiply``), so for a fixed seed both engines select
identical paths, sample identical terminals, and report identical
``best_cost`` — the parity tests in ``tests/test_engine.py`` assert this
for every UCB × simulation combination.
"""
from __future__ import annotations

import math
import random
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core.mcts import DecisionResult, MCTSConfig

INF = float("inf")


class ArrayMCTS:
    """Drop-in engine with the reference ``MCTS`` interface
    (``run_decision`` / ``advance_root`` / ``done``)."""

    def __init__(self, mdp, config: MCTSConfig, capacity: int = 1024):
        self.mdp = mdp
        self.cfg = config
        if config.ucb not in ("paper", "cp10", "sqrt2"):
            raise ValueError(config.ucb)
        self._paper = config.ucb in ("paper", "cp10")
        self._cp = config.cp
        self.rng = random.Random(config.seed)
        self.baseline: Optional[float] = None
        self.global_best = INF
        self.global_best_state: Optional[Tuple[int, ...]] = None
        self.sim_time = 0.0
        self.eval_time = 0.0

        # flat node storage -------------------------------------------------
        cap = max(capacity, 16)
        self.size = 0
        self.visit_counts = np.zeros(cap, dtype=np.int64)
        self.sum_cost = np.zeros(cap, dtype=np.float64)
        self.sum_reward = np.zeros(cap, dtype=np.float64)
        self.best_cost = np.full(cap, INF, dtype=np.float64)
        self.node_action = np.full(cap, -1, dtype=np.int32)
        self.n_children = np.zeros(cap, dtype=np.int32)
        # children[nid, slot] = child id, slots filled in insertion order
        # (same tie-break order as the reference dict iteration)
        self.children = np.full((cap, 4), -1, dtype=np.int32)
        self.untried: List[List[int]] = []
        self.best_state: List[Optional[Tuple[int, ...]]] = []
        # python mirrors of the tree STRUCTURE (child ids per node) for the
        # scalar hot paths; the numpy ``children`` table stays canonical and
        # feeds the batched-UCB path for wide nodes
        self._childlist: List[List[int]] = []

        self.root_state: Tuple[int, ...] = mdp.initial_state
        # fast path: a ScheduleMDP's transition structure is static — states
        # are action prefixes, the action count depends only on depth, and
        # ``step`` is tuple append.  Precomputing the depth->n_actions table
        # lets selection and rollout skip per-step method dispatch entirely
        # (values and RNG consumption are unchanged).  Other MDPs (test
        # doubles) take the generic path.
        self._depth_actions: Optional[List[int]] = None
        inner = getattr(mdp, "mdp", mdp)  # unwrap CachedMDP
        from repro.core.mdp import ScheduleMDP

        if isinstance(inner, ScheduleMDP):
            space = inner.space
            self._depth_actions = [
                space.n_actions(d) for d in range(space.n_stages)
            ]
        # per-round delta recording (process-pool workers; see
        # begin_delta/collect_delta/apply_delta)
        self._delta_base: Optional[int] = None
        self._delta_parents: List[int] = []
        self._delta_best: List[int] = []
        self._delta_touched: List[int] = []
        self.root = self._new_node(-1, self.root_state)

    # -- storage management ------------------------------------------------
    @staticmethod
    def _extend(arr: np.ndarray, cap: int, fill) -> np.ndarray:
        out = np.full((cap,) + arr.shape[1:], fill, dtype=arr.dtype)
        out[: arr.shape[0]] = arr
        return out

    def _grow_nodes(self):
        cap = self.visit_counts.shape[0] * 2
        self.visit_counts = self._extend(self.visit_counts, cap, 0)
        self.sum_cost = self._extend(self.sum_cost, cap, 0.0)
        self.sum_reward = self._extend(self.sum_reward, cap, 0.0)
        self.best_cost = self._extend(self.best_cost, cap, INF)
        self.node_action = self._extend(self.node_action, cap, -1)
        self.n_children = self._extend(self.n_children, cap, 0)
        self.children = self._extend(self.children, cap, -1)

    def _grow_width(self, need: int):
        w = self.children.shape[1]
        while w < need:
            w *= 2
        wider = np.full((self.children.shape[0], w), -1, dtype=np.int32)
        wider[:, : self.children.shape[1]] = self.children
        self.children = wider

    def _new_node(self, action: int, state) -> int:
        if self.size >= self.visit_counts.shape[0]:
            self._grow_nodes()
        nid = self.size
        self.size += 1
        self.visit_counts[nid] = 0
        self.sum_cost[nid] = 0.0
        self.sum_reward[nid] = 0.0
        self.best_cost[nid] = INF
        self.node_action[nid] = action
        self.n_children[nid] = 0
        da = self._depth_actions
        if da is not None:
            d = len(state)
            n_act = 0 if d >= len(da) else da[d]
        else:
            n_act = 0 if self.mdp.is_terminal(state) else self.mdp.n_actions(state)
        self.untried.append(list(range(n_act)))
        self.best_state.append(None)
        self._childlist.append([])
        return nid

    # -- tree policy (vectorized) -------------------------------------------
    def _best_child(self, nid: int) -> int:
        """argmax of the UCB score over the children.

        Wide nodes take the batched numpy path (one vectorized expression
        over all children at once); narrow nodes (the common case — most
        stages have 2-4 options) use an unrolled scalar loop, because numpy
        call overhead dominates below ~8 elements.  Both paths and the
        reference compute the same IEEE-754 operations in the same order
        (``np.sqrt``/``math.sqrt`` are correctly rounded), so scores — and
        therefore argmax with first-of-ties — are bit-identical."""
        kids = self._childlist[nid]
        nc = len(kids)
        if nc == 1:  # single-option stage: argmax is the only child
            return kids[0]
        logn = math.log(max(int(self.visit_counts[nid]), 1))
        paper = self._paper
        if nc < 8:
            vc, sc, sr = self.visit_counts, self.sum_cost, self.sum_reward
            cp, sqrt = self._cp, math.sqrt
            best_id = -1
            best_score = None
            for cid in kids:
                n = float(vc[cid])
                if paper:
                    # exploit = 1/(sum/n); score = exploit*(1+cp*sqrt(logn/n))
                    score = (1.0 / (float(sc[cid]) / n)) * (
                        1.0 + cp * sqrt(logn / n)
                    )
                else:
                    score = float(sr[cid]) / n + sqrt(2.0) * sqrt(2.0 * logn / n)
                if best_score is None or score > best_score:  # first of ties
                    best_id, best_score = cid, score
            return best_id
        ids = self.children[nid, :nc]
        n = self.visit_counts[ids].astype(np.float64)
        if paper:
            exploit = 1.0 / (self.sum_cost[ids] / n)
            scores = exploit * (1.0 + self._cp * np.sqrt(logn / n))
        else:
            mean_r = self.sum_reward[ids] / n
            scores = mean_r + math.sqrt(2.0) * np.sqrt(2.0 * logn / n)
        # np.argmax keeps the first of tied maxima — same rule as max() over
        # the reference dict's insertion-ordered children
        return int(ids[int(np.argmax(scores))])

    def _select(self):
        nid, state = self.root, self.root_state
        fast = self._depth_actions is not None
        untried, childlist = self.untried, self._childlist
        actions, best_child = self.node_action, self._best_child
        path = [nid]
        while not untried[nid] and childlist[nid]:
            nid = best_child(nid)
            a = int(actions[nid])
            state = state + (a,) if fast else self.mdp.step(state, a)
            path.append(nid)
        return nid, state, path

    def _is_terminal(self, state) -> bool:
        if self._depth_actions is not None:
            return len(state) >= len(self._depth_actions)
        return self.mdp.is_terminal(state)

    def _expand(self, nid: int, state):
        if self._is_terminal(state) or not self.untried[nid]:
            return nid, state, None
        pool = self.untried[nid]
        a = pool.pop(self.rng.randrange(len(pool)))
        child_state = (
            state + (a,) if self._depth_actions is not None
            else self.mdp.step(state, a)
        )
        child = self._new_node(a, child_state)
        slot = len(self._childlist[nid])
        if slot >= self.children.shape[1]:
            self._grow_width(slot + 1)
        self.children[nid, slot] = child
        self.n_children[nid] = slot + 1
        self._childlist[nid].append(child)
        if self._delta_base is not None:
            self._delta_parents.append(nid)
        return child, child_state, child

    # -- default policy ------------------------------------------------------
    def _simulate(self, state):
        t0 = time.perf_counter()
        da = self._depth_actions
        greedy = self.cfg.simulation == "greedy"
        if da is not None:
            # fast rollout: no per-step MDP dispatch; RNG consumption is
            # identical to the generic path (one randrange per depth, or the
            # greedy partial_cost sweep with the same tie-break draws)
            n_stages = len(da)
            if not greedy:
                rr = self.rng.randrange
                d = len(state)
                state = state + tuple(rr(da[i]) for i in range(d, n_stages))
            else:
                partial = self.mdp.partial_cost
                rand = self.rng.random
                while len(state) < n_stages:
                    best_a, best_c = 0, INF
                    for a in range(da[len(state)]):
                        c = partial(state + (a,))
                        if c < best_c or (c == best_c and rand() < 0.5):
                            best_a, best_c = a, c
                    state = state + (best_a,)
        else:
            while not self.mdp.is_terminal(state):
                n = self.mdp.n_actions(state)
                if greedy:
                    best_a, best_c = 0, INF
                    for a in range(n):
                        c = self.mdp.partial_cost(self.mdp.step(state, a))
                        if c < best_c or (c == best_c and self.rng.random() < 0.5):
                            best_a, best_c = a, c
                    state = self.mdp.step(state, best_a)
                else:
                    state = self.mdp.step(state, self.rng.randrange(n))
        self.sim_time += time.perf_counter() - t0
        t1 = time.perf_counter()
        cost = self.mdp.terminal_cost(state)
        self.eval_time += time.perf_counter() - t1
        return state, cost

    def _backprop(self, path: List[int], terminal, cost: float):
        if self.baseline is None:
            self.baseline = cost
        beat_best = cost < self.global_best
        if beat_best:
            self.global_best = cost
            self.global_best_state = terminal
        if self.cfg.reward_mode == "binary":
            r = 1.0 if beat_best else 0.0
        else:
            r = (self.baseline / cost) if cost > 0 else 0.0
        rec = self._delta_best if self._delta_base is not None else None
        if rec is not None:
            # pre-round nodes whose visit/sum stats this backprop touches:
            # exactly what collect_delta must ship besides the new slices
            base = self._delta_base
            self._delta_touched.extend(n for n in path if n < base)
        if len(path) < 16:
            vc, sc, sr, bc = (
                self.visit_counts, self.sum_cost, self.sum_reward, self.best_cost,
            )
            for nid in path:
                vc[nid] += 1
                sc[nid] += cost
                sr[nid] += r
                if cost < bc[nid]:
                    bc[nid] = cost
                    self.best_state[nid] = terminal
                    if rec is not None:
                        rec.append(nid)
        else:
            ids = np.asarray(path, dtype=np.int64)
            self.visit_counts[ids] += 1
            self.sum_cost[ids] += cost
            self.sum_reward[ids] += r
            improved = ids[self.best_cost[ids] > cost]
            self.best_cost[improved] = cost
            for nid in improved:
                self.best_state[int(nid)] = terminal
                if rec is not None:
                    rec.append(int(nid))

    def iterate_once(self):
        nid, state, path = self._select()
        child, child_state, created = self._expand(nid, state)
        if created is not None:
            path.append(created)
        terminal, cost = self._simulate(child_state)
        self._backprop(path, terminal, cost)

    # -- decision loop --------------------------------------------------------
    def run_decision(self) -> DecisionResult:
        c = self.cfg
        iters = 0
        t0 = time.perf_counter()
        while True:
            if c.seconds_per_decision is not None:
                if time.perf_counter() - t0 >= c.seconds_per_decision and iters > 0:
                    break
                if iters >= 100000:
                    break
            elif iters >= (c.iters_per_decision or 1):
                break
            self.iterate_once()
            iters += 1
        if not self._childlist[self.root]:
            self.iterate_once()
            iters += 1
        return self._root_decision(iters)

    def _root_decision(self, iters: int) -> DecisionResult:
        """Winner among the root's children: best BEST-cost child, ties to
        the lowest action — same (best_cost, action) key as the reference."""
        ids = self._childlist[self.root]
        keys = [
            (float(self.best_cost[i]), int(self.node_action[i])) for i in ids
        ]
        best = ids[min(range(len(keys)), key=keys.__getitem__)]
        return DecisionResult(
            action=int(self.node_action[best]),
            best_cost=float(self.best_cost[best]),
            best_state=self.best_state[best],
            iterations=iters,
        )

    # -- per-round tree deltas (process-pool transport) ----------------------
    # A worker runs one decision round and ships back ONLY what the round
    # changed, instead of pickling the whole tree: the round's NEW node
    # slices (``[base:size]`` stat/structure buffers), the stat rows of the
    # round's TOUCHED pre-round nodes (the backprop paths — recorded during
    # the round, so the numeric payload scales with the round, not with the
    # total tree), and the point mutations to pre-round nodes (untried
    # pools / child table rows of expanded parents, improved best-states).
    # The master applies the delta to the tree object it kept, which
    # reproduces the worker's post-round tree exactly — asserted by
    # tests/test_engine.py::test_parallel_delta_merge_equals_whole_tree.
    # This is the REVERSE direction of the pinned-worker protocol
    # (engine/workers.py); the forward direction needs no tree payload at
    # all — the master's root-synchronization action is replayed through
    # ``advance_root``, which both sides apply to identical trees.

    def begin_delta(self):
        """Start recording a round's mutations (worker side)."""
        self._delta_base = self.size
        self._delta_parents = []
        self._delta_best = []
        self._delta_touched = []

    def collect_delta(self) -> dict:
        """Package the recorded round as a picklable delta and stop
        recording.  Payload is a TRUE delta: ``[base:size]`` slices for
        the round's new nodes plus the touched pre-round stat rows —
        nothing proportional to the pre-round tree ships."""
        base = self._delta_base
        size = self.size
        parents = sorted({n for n in self._delta_parents if n < base})
        improved = {n for n in self._delta_best if n < base}
        # every pre-round node whose numeric stats changed this round:
        # backprop paths (visit/sum/best writes); expanded parents' stat
        # changes are also backprop writes, so ``touched`` covers them
        touched = np.fromiter(
            sorted(set(self._delta_touched)), dtype=np.int64,
        )
        delta = {
            "base": base,
            "size": size,
            "width": self.children.shape[1],
            "visit_counts": self.visit_counts[base:size].copy(),
            "sum_cost": self.sum_cost[base:size].copy(),
            "sum_reward": self.sum_reward[base:size].copy(),
            "best_cost": self.best_cost[base:size].copy(),
            "node_action": self.node_action[base:size].copy(),
            "n_children": self.n_children[base:size].copy(),
            "children": self.children[base:size].copy(),
            "touched": touched,
            "touched_visit": self.visit_counts[touched],
            "touched_sum_cost": self.sum_cost[touched],
            "touched_sum_reward": self.sum_reward[touched],
            "touched_best_cost": self.best_cost[touched],
            # expanded pre-round parents: their children-table rows gained
            # slots this round (n_children rides along per parent)
            "children_mut": {n: self.children[n].copy() for n in parents},
            "n_children_mut": {n: int(self.n_children[n]) for n in parents},
            "untried_new": self.untried[base:],
            "childlist_new": self._childlist[base:],
            "best_state_new": self.best_state[base:],
            "untried_mut": {n: self.untried[n] for n in parents},
            "childlist_mut": {n: self._childlist[n] for n in parents},
            "best_state_mut": {n: self.best_state[n] for n in improved},
            "rng": self.rng.getstate(),
            "baseline": self.baseline,
            "global_best": self.global_best,
            "global_best_state": self.global_best_state,
            "sim_time": self.sim_time,
            "eval_time": self.eval_time,
        }
        self._delta_base = None
        self._delta_parents = []
        self._delta_best = []
        self._delta_touched = []
        return delta

    def apply_delta(self, delta: dict):
        """Apply a worker's round delta to this (pre-round) tree, making it
        equal to the worker's post-round tree."""
        base, size = delta["base"], delta["size"]
        if base != len(self.untried):
            raise ValueError(
                f"delta base {base} does not match tree size {len(self.untried)}"
            )
        while self.visit_counts.shape[0] < size:
            self._grow_nodes()
        width = delta["width"]
        if self.children.shape[1] < width:
            self._grow_width(width)
        self.size = size
        self.visit_counts[base:size] = delta["visit_counts"]
        self.sum_cost[base:size] = delta["sum_cost"]
        self.sum_reward[base:size] = delta["sum_reward"]
        self.best_cost[base:size] = delta["best_cost"]
        self.node_action[base:size] = delta["node_action"]
        self.n_children[base:size] = delta["n_children"]
        self.children[base:size, :width] = delta["children"]
        t = delta["touched"]
        self.visit_counts[t] = delta["touched_visit"]
        self.sum_cost[t] = delta["touched_sum_cost"]
        self.sum_reward[t] = delta["touched_sum_reward"]
        self.best_cost[t] = delta["touched_best_cost"]
        for n, row in delta["children_mut"].items():
            self.children[n, : row.shape[0]] = row
        for n, v in delta["n_children_mut"].items():
            self.n_children[n] = v
        self.untried.extend(delta["untried_new"])
        self._childlist.extend(delta["childlist_new"])
        self.best_state.extend(delta["best_state_new"])
        for n, pool in delta["untried_mut"].items():
            self.untried[n] = pool
        for n, kids in delta["childlist_mut"].items():
            self._childlist[n] = kids
        for n, st in delta["best_state_mut"].items():
            self.best_state[n] = st
        self.rng.setstate(delta["rng"])
        self.baseline = delta["baseline"]
        self.global_best = delta["global_best"]
        self.global_best_state = delta["global_best_state"]
        self.sim_time = delta["sim_time"]
        self.eval_time = delta["eval_time"]

    def advance_root(self, action: int):
        self.root_state = self.mdp.step(self.root_state, action)
        nxt = -1
        for i in self._childlist[self.root]:
            if int(self.node_action[i]) == action:
                nxt = i
                break
        if nxt < 0:
            nxt = self._new_node(action, self.root_state)
        self.root = nxt

    @property
    def done(self) -> bool:
        return self.mdp.is_terminal(self.root_state)


def delta_nbytes(delta: dict) -> int:
    """Numeric payload of a collected round delta, in bytes — the array
    buffers that dominate the wire size (new-node slices, touched stat
    rows, expanded parents' child-table rows).  Payload accounting for the
    O(new nodes + touched rows) transport claim: this number scales with
    the ROUND, while ``pickle.dumps(tree)`` scales with the whole tree."""
    n = 0
    for v in delta.values():
        if isinstance(v, np.ndarray):
            n += v.nbytes
    for row in delta["children_mut"].values():
        n += row.nbytes
    return n
