"""Shared-memory transposition-cache log: cross-process cache hits with
zero export traffic.

The ``TranspositionCache`` hot tables are insertion-ordered, append-only
dicts in analytic mode — every entry is an exact pure-memo value keyed by
an action-index tuple.  The pinned-worker pool (``engine/workers.py``)
used to ship each worker "everything since your last watermark" as a
pickled dict every round (``export_since``/``apply_export``).  This module
replaces that transport for the pure-analytic path with a
``multiprocessing.shared_memory`` segment holding the same entries as
FLAT ARRAYS — fixed-width int32 key rows (action tuples, length column
alongside), a table-kind column (terminal vs partial), and a float64
value column — behind an append-only write cursor:

* the MASTER owns the segment (``ShmCacheLog``): it appends the round's
  new entries after merging worker returns and publishes the new row
  count; resizes happen by publish-new-then-swap (create the bigger
  segment, copy the row prefix, unlink the old one — readers keep their
  row cursors, because row indices are preserved);
* each WORKER maps the segment read-only (``ShmCacheReader``) and, at
  every round start, folds the rows between its local cursor and the
  cursor the master put in the round message into its local cache dicts
  — an O(new rows) numpy slice walk, no pickled payload on the wire.

Values round-trip exactly (float64 in, float64 out), so the worker's
cache serves the same bits the master's does and the parallel
bit-identity guarantee is untouched.  The write cursor is only ever
advanced while all workers are idle (the master appends between
collecting one round and submitting the next), so readers never observe
a torn row.

The watermark/``export_since`` delta protocol stays as the fallback: for
platforms without POSIX shared memory, for learned-cost runs (tag
evictions and exact-wins rewrites mutate tables in place — the mutation
``epoch`` machinery degrades those to a resync, which the append-only log
cannot express), and for any run that disables shm explicitly.
"""
from __future__ import annotations

import itertools
from typing import Dict, Optional, Tuple

import numpy as np

try:  # POSIX shared memory; absent/broken on some exotic platforms
    from multiprocessing import shared_memory as _shm_mod

    import inspect as _inspect
    import os as _os

    # readers need a tracker-free attach: either 3.13+'s ``track=False``
    # or a raw mmap of the shm file (Linux /dev/shm) — see ``_Mapping``
    HAVE_SHM = (
        "track" in _inspect.signature(_shm_mod.SharedMemory).parameters
        or _os.path.isdir("/dev/shm")
    )
except ImportError:  # pragma: no cover - platform without shm
    _shm_mod = None
    HAVE_SHM = False

State = Tuple[int, ...]

# segment names are namespaced per pool instance so two pools in one
# process (or two daemons on one box) can never collide: the pid plus a
# module-level sequence number
_POOL_SEQ = itertools.count()

_HEADER_SLOTS = 8  # int64: [count, capacity, width]; rest reserved
_HEADER_BYTES = _HEADER_SLOTS * 8


def pool_uid() -> str:
    """A per-pool namespace component, unique within this process."""
    import os

    return f"{os.getpid()}-{next(_POOL_SEQ)}"


class _Mapping:
    """Reader-side attachment to an existing segment WITHOUT touching the
    resource tracker: the master owns unlinking, and under forkserver the
    workers SHARE the master's tracker process — a tracked attach (or a
    compensating ``unregister``) in a worker would corrupt the master's
    registration and misfire unlinks/warnings at exit.  Python 3.13+ has
    ``track=False`` for exactly this; earlier versions get a raw read-only
    mmap of the POSIX shm file (Linux: ``/dev/shm/<name>``), which never
    enters the tracker at all."""

    __slots__ = ("buf", "_shm", "_mm")

    def __init__(self, name: str):
        self._shm = self._mm = None
        try:  # Python >= 3.13
            self._shm = _shm_mod.SharedMemory(name=name, track=False)
            self.buf = self._shm.buf
            return
        except TypeError:
            pass
        import mmap
        import os

        fd = os.open("/dev/shm/" + name.lstrip("/"), os.O_RDONLY)
        try:
            size = os.fstat(fd).st_size
            self._mm = mmap.mmap(fd, size, access=mmap.ACCESS_READ)
        finally:
            os.close(fd)
        self.buf = memoryview(self._mm)

    def close(self) -> None:
        if self._shm is not None:
            self._shm.close()
        else:
            try:
                self.buf.release()
                self._mm.close()
            except BufferError:  # numpy views still alive; GC finishes it
                pass


def _nbytes(capacity: int, width: int) -> int:
    # header + keys(int32) + lens(int16) + kinds(uint8) + values(float64)
    return _HEADER_BYTES + capacity * (width * 4 + 2 + 1 + 8)


class _Views:
    """Numpy views over one mapped segment (shared by writer and reader;
    layout is fully determined by the header's capacity/width)."""

    __slots__ = ("header", "keys", "lens", "kinds", "vals")

    def __init__(self, buf, capacity: int, width: int):
        self.header = np.ndarray(
            (_HEADER_SLOTS,), dtype=np.int64, buffer=buf)
        off = _HEADER_BYTES
        self.keys = np.ndarray(
            (capacity, width), dtype=np.int32, buffer=buf, offset=off)
        off += capacity * width * 4
        self.lens = np.ndarray(
            (capacity,), dtype=np.int16, buffer=buf, offset=off)
        off += capacity * 2
        self.kinds = np.ndarray(
            (capacity,), dtype=np.uint8, buffer=buf, offset=off)
        off += capacity
        self.vals = np.ndarray(
            (capacity,), dtype=np.float64, buffer=buf, offset=off)


class ShmCacheLog:
    """Master-side append-only writer over one shared segment.

    Lifecycle is owned by the pinned pool: created at init-snapshot time,
    swapped (new segment, rows copied, old one unlinked) on resize and on
    worker-death resync, unlinked on ``shutdown()``."""

    def __init__(self, uid: Optional[str] = None, *, capacity: int = 4096,
                 width: int = 16):
        if not HAVE_SHM:  # pragma: no cover - guarded by callers
            raise RuntimeError("multiprocessing.shared_memory unavailable")
        self.uid = uid if uid is not None else pool_uid()
        self.gen = 0
        self.count = 0
        self.capacity = capacity
        self.width = width
        # superseded generations, unlinked by ``drain_retired()`` once no
        # in-flight round message can still name them (end of the round
        # that swapped, or shutdown) — a reader attaches by NAME, so the
        # old file must outlive any message that carries it
        self.retired = []
        self._seg = self._create(capacity, width)
        self._views = _Views(self._seg.buf, capacity, width)
        self._publish()

    # -- segment management --------------------------------------------
    @property
    def name(self) -> str:
        return self._seg.name

    def _create(self, capacity: int, width: int):
        name = f"repro-cache-{self.uid}-g{self.gen}"
        return _shm_mod.SharedMemory(
            name=name, create=True, size=_nbytes(capacity, width))

    def _publish(self) -> None:
        h = self._views.header
        h[1] = self.capacity
        h[2] = self.width
        h[0] = self.count  # count last: a reader never sees rows > count

    def _migrate(self, capacity: int, width: int) -> None:
        """Publish-new-then-swap: bigger (or fresh same-size) segment,
        row prefix copied so reader cursors stay valid, old segment
        unlinked — attached readers keep their mapping until they switch
        to the new name (the round message carries it)."""
        self.gen += 1
        seg = self._create(capacity, width)
        views = _Views(seg.buf, capacity, width)
        n = self.count
        if n:
            views.keys[:n, : self.width] = self._views.keys[:n]
            views.lens[:n] = self._views.lens[:n]
            views.kinds[:n] = self._views.kinds[:n]
            views.vals[:n] = self._views.vals[:n]
        old = self._seg
        self._seg, self._views = seg, views
        self.capacity, self.width = capacity, width
        self._publish()
        self.retired.append(old)

    def swap(self) -> None:
        """Same-content generation bump (worker-death resync): the old
        segment is retired (unlinked at the next ``drain_retired``) and
        live readers move over on the next round message."""
        self._migrate(self.capacity, self.width)

    def drain_retired(self) -> None:
        """Unlink every superseded generation (round boundary/shutdown)."""
        for seg in self.retired:
            seg.close()
            try:
                seg.unlink()
            except FileNotFoundError:  # pragma: no cover - already gone
                pass
        self.retired = []

    # -- appends --------------------------------------------------------
    def append(self, entries) -> int:
        """Append an ``export_since`` payload ``(terminal, partial,
        terminal_version, partial_version)``; learned-tagged entries are
        rejected (the log is exact-only — callers fall back to the export
        protocol before any tag exists).  Returns rows appended."""
        t, p, tv, pv = entries
        if tv or pv:
            raise ValueError("shm cache log holds exact entries only")
        items = [(s, v, 0) for s, v in t.items()]
        items += [(s, v, 1) for s, v in p.items()]
        if not items:
            return 0
        need_w = max((len(s) for s, _, _ in items), default=0)
        cap, width = self.capacity, self.width
        while self.count + len(items) > cap:
            cap *= 2
        while need_w > width:
            width *= 2
        if (cap, width) != (self.capacity, self.width):
            self._migrate(cap, width)
        v = self._views
        i = self.count
        for s, val, kind in items:
            n = len(s)
            v.keys[i, :n] = s
            v.lens[i] = n
            v.kinds[i] = kind
            v.vals[i] = val
            i += 1
        self.count = i
        v.header[0] = i
        return len(items)

    # -- lifecycle ------------------------------------------------------
    def close(self) -> None:
        self._seg.close()

    def unlink(self) -> None:
        self.drain_retired()
        try:
            self._seg.unlink()
        except FileNotFoundError:  # pragma: no cover - already gone
            pass


class ShmCacheReader:
    """Worker-side read-only cursor over the master's log.

    ``fold(cache, name, cursor)`` attaches ``name`` if it is new (segment
    swaps ride the round message), reads the rows between the local
    cursor and ``cursor``, and inserts them into the worker cache's
    tables — plain dict stores of exact values, so re-folding the
    worker's own entries is a no-op and the cache's mutation ``epoch``
    never moves."""

    def __init__(self):
        self.name: Optional[str] = None
        self._seg = None
        self._views: Optional[_Views] = None
        self.cursor = 0
        self.folded = 0  # rows folded lifetime (the shm serving counter)

    def attach(self, name: str, cursor: int) -> None:
        """Point at a segment at ``cursor`` WITHOUT folding — used at
        init time, when the snapshot already contains every entry up to
        the cursor."""
        self._switch(name)
        self.cursor = cursor

    def _switch(self, name: str) -> None:
        if name == self.name:
            return
        if self._seg is not None:
            self._views = None  # drop numpy views before unmapping
            self._seg.close()
        self._seg = _Mapping(name)
        h = np.ndarray((_HEADER_SLOTS,), dtype=np.int64, buffer=self._seg.buf)
        self._views = _Views(self._seg.buf, int(h[1]), int(h[2]))
        self.name = name

    def fold(self, cache, name: str, cursor: int) -> int:
        """Fold rows ``[self.cursor, cursor)`` of segment ``name`` into
        ``cache``; returns the number of rows folded."""
        self._switch(name)
        lo, hi = self.cursor, cursor
        if hi <= lo:
            return 0
        v = self._views
        keys = v.keys[lo:hi]
        lens = v.lens[lo:hi]
        kinds = v.kinds[lo:hi]
        vals = v.vals[lo:hi]
        term, part = cache.terminal, cache.partial
        for i in range(hi - lo):
            s = tuple(int(a) for a in keys[i, : lens[i]])
            if kinds[i]:
                part[s] = vals[i]
            else:
                term[s] = vals[i]
        n = hi - lo
        self.cursor = hi
        self.folded += n
        return n

    def close(self) -> None:
        if self._seg is not None:
            self._views = None  # drop numpy views before unmapping
            self._seg.close()
            self._seg = None
            self.name = None
