"""Lockstep batched decision rounds for ``ArrayMCTS``: the pending-leaf
queue that makes leaf evaluation — ProTuner's hot path — batched end-to-end.

Within one decision round the K ensemble trees are independent given the
transposition cache: tree i's trajectory depends only on its own RNG stream
and its own node statistics, and the cache is a pure memo (it changes which
states get *priced*, never the values returned).  Running the K trees'
iterations in lockstep is therefore exactly sequential-equivalent — same
plans, costs, and decision sequences; with the shared cache on (the array
engine's default) even the aggregate cache hit/miss and ``n_evals`` totals
match, because "first lookup of a state is a miss, every later one a hit"
does not depend on lookup order.  (Uncached, ``cost_batch``'s in-call
dedup can price a leaf shared by two trees once where the scalar loop
prices it twice — values are unaffected, only ``n_evals`` drops.)  What changes
is the shape of the work: each lockstep step exposes K complete schedules
to ONE ``terminal_cost_batch`` call (select-many → expand-many →
evaluate-batch → backprop-many) instead of K interleaved scalar
``terminal_cost`` calls, so duplicate leaves collapse and the round's
deduplicated miss batch prices through one ``PlanColumns`` encode and one
vectorized columnar-kernel pass (``AnalyticCostModel.cost_batch`` →
``_terms_columnar``; bit-identical to the scalar walk by certification).  Greedy rollout tails batch the same
way: each depth's candidate sweep prices through ``partial_cost_batch`` in
one call, with the reference's tie-break RNG draws replayed afterwards in
action order (evaluation consumes no RNG, so the stream is unchanged).

The driver also restructures the per-iteration bookkeeping: each tree's
hot per-node stats live in plain-Python list mirrors for the duration of
the round (scalar list reads/writes are ~3x cheaper than numpy scalar
indexing, and selection/backprop are exactly such scalar walks), flushed
back into the canonical flat arrays in one vectorized assignment per field
at round end.  UCB arithmetic replays the reference's IEEE-754 operation
sequence, so parity stays exact — certified across the full
(UCB × policy × reward × seed) grid by ``tests/test_differential.py``.
"""
from __future__ import annotations

import math
import time
from typing import List, Optional, Tuple

from repro.core.engine.array_mcts import INF, ArrayMCTS
from repro.core.mcts import DecisionResult

SQRT2 = math.sqrt(2.0)

State = Tuple[int, ...]


def _terminal_cost_batch(mdp, states: List[State]) -> List[float]:
    fn = getattr(mdp, "terminal_cost_batch", None)
    if fn is not None:
        return fn(states)
    return [mdp.terminal_cost(s) for s in states]


class _TreeCursor:
    """One tree's view of a lockstep round.

    Carries Python-list mirrors of the flat per-node stat arrays plus local
    bindings of everything the select/expand/rollout walk touches; shares
    the tree's RNG and python-side structure (``untried``/``_childlist``/
    ``best_state``) by reference, so expansion mutates the tree directly and
    ``flush`` only needs to write the stat mirrors back."""

    __slots__ = (
        "t", "mdp", "rng", "untried", "childlist", "best_state",
        "vc", "sc", "sr", "bc", "act",
        "da", "n_stages", "paper", "cp", "greedy", "binary",
        "delta_base", "dparents", "dbest", "dtouched",
    )

    def __init__(self, t: ArrayMCTS):
        self.t = t
        self.mdp = t.mdp
        self.rng = t.rng
        self.untried = t.untried
        self.childlist = t._childlist
        self.best_state = t.best_state
        # per-round delta recording (pinned-worker reverse transport): the
        # cursor's inline expand/backprop mirror ArrayMCTS's hooks, feeding
        # the same record lists ``collect_delta`` packages; recording into
        # them unfiltered is fine — collect_delta filters by ``base``
        self.delta_base = t._delta_base
        self.dparents = t._delta_parents
        self.dbest = t._delta_best
        self.dtouched = t._delta_touched
        size = t.size
        self.vc: List[int] = t.visit_counts[:size].tolist()
        self.sc: List[float] = t.sum_cost[:size].tolist()
        self.sr: List[float] = t.sum_reward[:size].tolist()
        self.bc: List[float] = t.best_cost[:size].tolist()
        self.act: List[int] = t.node_action[:size].tolist()
        self.da = t._depth_actions
        self.n_stages = len(self.da) if self.da is not None else 0
        self.paper = t._paper
        self.cp = t._cp
        self.greedy = t.cfg.simulation == "greedy"
        self.binary = t.cfg.reward_mode == "binary"

    # -- tree policy ------------------------------------------------------
    def _best_child(self, nid: int) -> int:
        """Reference UCB argmax over list mirrors — the same IEEE-754
        operations in the same order as ``MCTS._ucb_score`` (ints convert
        to float64 exactly), first-of-ties."""
        kids = self.childlist[nid]
        if len(kids) == 1:
            return kids[0]
        vc = self.vc
        logn = math.log(max(vc[nid], 1))
        sqrt = math.sqrt
        best_id = -1
        best_score = None
        if self.paper:
            sc, cp = self.sc, self.cp
            for cid in kids:
                n = vc[cid]
                score = (1.0 / (sc[cid] / n)) * (1.0 + cp * sqrt(logn / n))
                if best_score is None or score > best_score:
                    best_id, best_score = cid, score
        else:
            sr = self.sr
            for cid in kids:
                n = vc[cid]
                score = sr[cid] / n + SQRT2 * sqrt(2.0 * logn / n)
                if best_score is None or score > best_score:
                    best_id, best_score = cid, score
        return best_id

    # -- one iteration up to (not including) terminal pricing -------------
    def advance_to_leaf(self):
        """Select + expand + roll out; returns the pending leaf
        ``(path, terminal_state)`` whose cost the caller prices in batch."""
        t = self.t
        untried, childlist, act = self.untried, self.childlist, self.act
        rng, mdp = self.rng, self.mdp
        fast = self.da is not None
        # select
        nid, state = t.root, t.root_state
        path = [nid]
        while not untried[nid] and childlist[nid]:
            nid = self._best_child(nid)
            a = act[nid]
            state = state + (a,) if fast else mdp.step(state, a)
            path.append(nid)
        # expand
        terminal_here = (
            len(state) >= self.n_stages if fast else mdp.is_terminal(state)
        )
        if not terminal_here and untried[nid]:
            pool = untried[nid]
            a = pool.pop(rng.randrange(len(pool)))
            state = state + (a,) if fast else mdp.step(state, a)
            child = t._new_node(a, state)
            slot = len(childlist[nid])
            if slot >= t.children.shape[1]:
                t._grow_width(slot + 1)
            t.children[nid, slot] = child
            t.n_children[nid] = slot + 1
            childlist[nid].append(child)
            if self.delta_base is not None:
                self.dparents.append(nid)
            path.append(child)
            self.vc.append(0)
            self.sc.append(0.0)
            self.sr.append(0.0)
            self.bc.append(INF)
            self.act.append(a)
        # rollout (terminal cost deferred to the batch)
        t0 = time.perf_counter()
        if fast:
            if not self.greedy:
                rr = rng.randrange
                da = self.da
                state = state + tuple(
                    rr(da[i]) for i in range(len(state), self.n_stages)
                )
            else:
                state = self._greedy_rollout(state)
        else:
            state = self._generic_rollout(state)
        t.sim_time += time.perf_counter() - t0
        return path, state

    def _greedy_rollout(self, state: State) -> State:
        """Greedy default policy with each depth's candidate sweep priced in
        one ``partial_cost_batch`` call; tie-break RNG draws replay in
        action order afterwards, so the stream matches the scalar engine."""
        da, mdp = self.da, self.mdp
        pc_batch = getattr(mdp, "partial_cost_batch", None)
        rand = self.rng.random
        while len(state) < self.n_stages:
            n = da[len(state)]
            cands = [state + (a,) for a in range(n)]
            if pc_batch is not None and n > 1:
                costs = pc_batch(cands)
            else:
                pc = mdp.partial_cost
                costs = [pc(c) for c in cands]
            best_a, best_c = 0, INF
            for a in range(n):
                c = costs[a]
                if c < best_c or (c == best_c and rand() < 0.5):
                    best_a, best_c = a, c
            state = cands[best_a]
        return state

    def _generic_rollout(self, state: State) -> State:
        """Non-``ScheduleMDP`` path (test doubles): per-step MDP dispatch,
        batched greedy sweeps when the MDP offers them."""
        mdp, rng = self.mdp, self.rng
        pc_batch = getattr(mdp, "partial_cost_batch", None)
        greedy, rand = self.greedy, self.rng.random
        while not mdp.is_terminal(state):
            n = mdp.n_actions(state)
            if greedy:
                steps = [mdp.step(state, a) for a in range(n)]
                if pc_batch is not None and n > 1:
                    costs = pc_batch(steps)
                else:
                    pc = mdp.partial_cost
                    costs = [pc(s) for s in steps]
                best_a, best_c = 0, INF
                for a in range(n):
                    c = costs[a]
                    if c < best_c or (c == best_c and rand() < 0.5):
                        best_a, best_c = a, c
                state = steps[best_a]
            else:
                state = mdp.step(state, rng.randrange(n))
        return state

    # -- backprop ----------------------------------------------------------
    def backprop(self, path: List[int], terminal: State, cost: float):
        t = self.t
        if t.baseline is None:
            t.baseline = cost
        beat = cost < t.global_best
        if beat:
            t.global_best = cost
            t.global_best_state = terminal
        if self.binary:
            r = 1.0 if beat else 0.0
        else:
            r = (t.baseline / cost) if cost > 0 else 0.0
        vc, sc, sr, bc = self.vc, self.sc, self.sr, self.bc
        best_state = self.best_state
        base = self.delta_base
        if base is not None:
            self.dtouched.extend(n for n in path if n < base)
        for nid in path:
            vc[nid] += 1
            sc[nid] += cost
            sr[nid] += r
            if cost < bc[nid]:
                bc[nid] = cost
                best_state[nid] = terminal
                if base is not None:
                    self.dbest.append(nid)

    def flush(self):
        """Write the stat mirrors back into the canonical flat arrays (one
        vectorized assignment per field; capacity already grown by
        ``_new_node``)."""
        t = self.t
        size = t.size
        assert size == len(self.vc)
        t.visit_counts[:size] = self.vc
        t.sum_cost[:size] = self.sc
        t.sum_reward[:size] = self.sr
        t.best_cost[:size] = self.bc


def run_decision_batch(
    trees: List[ArrayMCTS], mdp=None, controller=None
) -> List[DecisionResult]:
    """One lockstep decision round over ``trees`` — the batched equivalent
    of ``[t.run_decision() for t in trees]``, with identical results.

    Requires an iteration budget (wall-clock budgets are inherently
    per-tree and fall back to scalar ``run_decision``).  All trees must
    share the per-decision budget, as ProTuner ensembles do.

    ``controller`` (core/run_control.py) is the mid-round cancellation
    seam: once ``controller.cancel()`` fires, the remaining iterations of
    THIS round are skipped (after at least one, so every root has a
    child) and the round's decisions are computed from the simulations
    done so far.  Deadlines never truncate — ``abort_round`` only answers
    to an explicit cancel — so an uninterrupted (or merely
    deadline-bounded) round runs all its iterations and stays
    bit-identical to a controller-free one."""
    if not trees:
        return []
    if mdp is None:
        mdp = trees[0].mdp
    cfg = trees[0].cfg
    if cfg.seconds_per_decision is not None:
        return [t.run_decision() for t in trees]
    iters = cfg.iters_per_decision or 1
    cursors = [_TreeCursor(t) for t in trees]
    for it in range(iters):
        if controller is not None and it and controller.abort_round():
            break
        pending = [c.advance_to_leaf() for c in cursors]
        t0 = time.perf_counter()
        costs = _terminal_cost_batch(mdp, [leaf for _, leaf in pending])
        dt = (time.perf_counter() - t0) / len(cursors)
        for c, (path, leaf), cost in zip(cursors, pending, costs):
            c.backprop(path, leaf, cost)
            c.t.eval_time += dt
    out: List[DecisionResult] = []
    for c in cursors:
        extra = 0
        if not c.childlist[c.t.root]:
            # degenerate budget: guarantee a root child, as run_decision does
            path, leaf = c.advance_to_leaf()
            c.backprop(path, leaf, _terminal_cost_batch(mdp, [leaf])[0])
            extra = 1
        c.flush()
        out.append(c.t._root_decision(iters + extra))
    # learned-cost serving (engine/serving.py): a decision-round boundary
    # is the online trainer's deterministic refit point — the next round's
    # miss batches are then priced by the refreshed model
    round_end = getattr(mdp, "on_round_end", None)
    if round_end is not None:
        round_end()
    return out
