"""Learned-cost serving behind the transposition-cache seam.

The paper's §3 observation — a model trained on complete schedules ranks
complete schedules well — plus the engine layer's two facts make this
subsystem almost free:

* every ``TranspositionCache`` terminal entry is a ``(actions, cost)``
  training example that the search already paid for, and
* the batch seam (PR 2: ``CachedMDP.terminal_cost_batch`` →
  ``cost_batch``) already funnels every cache-miss batch through ONE
  pricing call — the natural mount point for a model that prices a whole
  batch in one JAX forward pass.

Three pieces:

``OnlineCostTrainer``
    Harvests the cache's analytic-priced terminal entries (entries a
    learned model priced are tagged in ``cache.terminal_version`` and
    excluded, so the model never trains on its own predictions), refits
    the ``LearnedCostModel`` MLP on snapshots — warm-started from the
    previous fit, normalization recomputed per fit — and scores each fit
    on a held-out slice (Spearman) to decide whether the model is
    *confident* enough to serve.

``HybridCostBackend``
    Mounted inside ``CachedMDP`` (``cost_backend=``).  Prices each
    deduplicated miss batch: ``mode="learned"`` serves the model whenever
    one exists, ``mode="hybrid"`` additionally requires the holdout
    confidence gate; both fall back to the analytic path (which preserves
    PR-2's one-``cost_batch``-call-per-miss-batch batching) while
    untrained.  Entries the model priced are tagged with the model's
    version id so merged caches stay interpretable — version 0 / no tag
    always means exact analytic.

``make_cost_backend``
    Maps the user-facing ``cost="analytic"|"learned"|"hybrid"`` selector
    (``autotune`` / ``ProTuner`` / ``resolve_backend``) to a backend —
    ``None`` for ``"analytic"``, so the exact-analytic path is literally
    the unchanged PR-2 code and stays bit-identical for the differential
    grid (``tests/test_differential.py``).

Process-pool protocol: pickled backends disable refitting
(``__getstate__`` clears ``refit_enabled``), so workers only SERVE the
model version they were shipped and tag new entries with it; the master
refits on the merged cache at round boundaries and ships the new model
with the next round's submissions.  Merged caches therefore never contain
a version id that some trainer didn't mint.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_model import PlanColumns

COST_MODES = ("analytic", "learned", "hybrid")


@dataclass
class FitReport:
    """One refit: dataset size, holdout quality, and the serving verdict.

    ``n_train + n_holdout <= n_examples``: holdout-marked states that are
    too few to score (< 8) sit out entirely rather than leak into
    training."""

    version: int
    n_examples: int
    n_train: int
    n_holdout: int
    holdout_spearman: float
    confident: bool


class OnlineCostTrainer:
    """Periodic refits of the learned cost model on transposition-cache
    snapshots.

    ``should_fit`` triggers on the count of ANALYTIC terminal entries: the
    first fit at ``min_examples``, refits every ``refit_every`` new
    analytic entries after that.  Each fit recomputes the log-cost
    normalization from the snapshot (the cache's cost distribution drifts
    as the search descends) and warm-starts from the previous parameters.
    """

    def __init__(
        self,
        space,
        *,
        min_examples: int = 64,
        refit_every: int = 256,
        steps: int = 200,
        lr: float = 3e-3,
        seed: int = 0,
        holdout_frac: float = 0.25,
        confidence_threshold: float = 0.8,
    ):
        self.space = space
        self.min_examples = min_examples
        self.refit_every = refit_every
        self.steps = steps
        self.lr = lr
        self.seed = seed
        self.holdout_frac = holdout_frac
        self.confidence_threshold = confidence_threshold
        self.model = None  # LearnedCostModel after the first fit
        self.confident = False
        self.version = 0  # fit generation; 0 = untrained
        self._fitted_at = 0  # analytic-entry count at the last fit
        # adaptive refit interval: doubles after an unconfident fit (more
        # data of the same on-policy distribution rarely flips the verdict
        # immediately, and fits are the expensive part), resets once a fit
        # clears the gate
        self._interval = refit_every
        self.reports: List[FitReport] = []

    # -- harvest --------------------------------------------------------
    @staticmethod
    def n_analytic(cache) -> int:
        """Analytic-priced terminal entries (tags mark learned ones)."""
        return len(cache.terminal) - len(cache.terminal_version)

    def harvest(self, cache) -> Tuple[list, List[float]]:
        """Snapshot the cache's analytic terminal entries as training
        pairs: a terminal state IS its action tuple, so each entry is a
        free ``(actions, cost)`` example."""
        tagged = cache.terminal_version
        states = [s for s in cache.terminal if s not in tagged]
        return states, [cache.terminal[s] for s in states]

    def should_fit(self, cache) -> bool:
        n = self.n_analytic(cache)
        if self.model is None:
            return n >= self.min_examples
        return n - self._fitted_at >= self._interval

    # -- fit ------------------------------------------------------------
    def is_holdout(self, state) -> bool:
        """Persistent train/holdout split by content hash: a state's
        assignment never changes — across fits, processes, and runs — so
        warm-started parameters have NEVER trained on any holdout example
        and the confidence score cannot be inflated by memorization (a
        per-fit reshuffle would hand fit N+1 a holdout that fit N trained
        on).  Salted so the split is independent of the audit-batch hash."""
        denom = max(int(round(1.0 / self.holdout_frac)), 2)
        return zlib.crc32(repr(tuple(state)).encode() + b"/holdout") % denom == 0

    def fit(self, cache) -> Optional[FitReport]:
        from repro.core.learned_cost import _spearman, fit_learned_cost

        states, costs = self.harvest(cache)
        n = len(states)
        if n < max(self.min_examples, 8):
            return None
        plans = [self.space.plan_from_actions(list(s)) for s in states]
        # holdout-marked states NEVER train — even when there are too few
        # of them to score (then they sit out entirely and the fit stays
        # uncertified) — otherwise a small first fit would leak them into
        # the warm-started params and inflate every later confidence score
        hold, train = [], []
        for i, s in enumerate(states):
            (hold if self.is_holdout(s) else train).append(i)
        if len(hold) < 8:
            hold = []  # too little data to certify: hybrid keeps falling back
        if len(train) < 8:
            return None
        model = fit_learned_cost(
            self.space,
            [plans[i] for i in train],
            [costs[i] for i in train],
            params=self.model.params if self.model is not None else None,
            steps=self.steps,
            lr=self.lr,
            seed=self.seed,
        )
        self.version += 1
        model.version = self.version
        if hold:
            preds = model.cost_batch([plans[i] for i in hold])
            rho = _spearman(
                np.asarray(preds), np.asarray([costs[i] for i in hold])
            )
        else:
            rho = 0.0
        self.confident = bool(hold) and rho >= self.confidence_threshold
        self.model = model
        self._fitted_at = self.n_analytic(cache)
        self._interval = (
            self.refit_every if self.confident
            else min(self._interval * 2, 16 * self.refit_every)
        )
        report = FitReport(
            self.version, n, len(train), len(hold), rho, self.confident
        )
        self.reports.append(report)
        return report


class HybridCostBackend:
    """Prices ``CachedMDP`` miss batches: learned model when trained (and,
    in hybrid mode, confident), exact analytic otherwise.

    Returned by every ``price_*`` call: ``(costs, version)`` where
    ``version`` is 0 for analytic pricing or the serving model's fit
    generation — ``CachedMDP`` tags the new cache entries with it."""

    def __init__(
        self,
        space,
        mode: str = "hybrid",
        trainer: Optional[OnlineCostTrainer] = None,
        audit_every: int = 8,
        **trainer_kwargs,
    ):
        if mode not in ("learned", "hybrid"):
            raise ValueError(
                f"cost backend mode {mode!r}; analytic mode mounts no "
                f"backend (make_cost_backend returns None)"
            )
        self.mode = mode
        self.trainer = trainer if trainer is not None else OnlineCostTrainer(
            space, **trainer_kwargs
        )
        # Audit stream: while the model serves, ~1/``audit_every`` of
        # terminal miss batches are still priced analytically (and left
        # untagged).  Without it, serving STARVES training — every new
        # entry would be model-tagged, the analytic-entry count would
        # freeze, and no refit (hence no confidence re-check) could ever
        # fire again; the gate could open once and never close.  The audit
        # batches keep fresh on-policy labels flowing from whatever region
        # the search currently explores, so later refits can detect drift.
        # Selection is a STATELESS content hash of the batch's first state
        # (``audit_batch``), so the stream survives worker pickling and
        # needs no counter synchronization across processes.  0/None
        # disables (serve-everything; refits stop once serving starts —
        # only sensible for fixed offline models).
        self.audit_every = audit_every
        self.cache = None  # bound by CachedMDP at mount time
        self.refit_enabled = True  # cleared in pickled (worker) copies
        self.n_learned_batches = 0
        self.n_learned_plans = 0
        self.n_analytic_plans = 0

    # -- lifecycle ------------------------------------------------------
    def bind(self, cache) -> None:
        self.cache = cache

    def __getstate__(self):
        # Workers serve the shipped model but never refit: version ids
        # stay minted by exactly one trainer (the master's), so tags in
        # merged caches are globally interpretable.  Pricing counters ship
        # zeroed (like TranspositionCache's hit/miss counters): a worker's
        # counts are then exactly its round's activity, and the master
        # merges them by summing (``merge_counters``) without double
        # counting.
        d = self.__dict__.copy()
        d["refit_enabled"] = False
        d["n_learned_batches"] = 0
        d["n_learned_plans"] = 0
        d["n_analytic_plans"] = 0
        return d

    def counters(self) -> Tuple[int, int, int]:
        return (
            self.n_learned_batches, self.n_learned_plans, self.n_analytic_plans
        )

    def merge_counters(self, counters: Tuple[int, int, int]) -> None:
        """Fold a worker's round pricing counters back into this backend
        (they pickle zeroed, so each worker reports exactly its round)."""
        self.n_learned_batches += counters[0]
        self.n_learned_plans += counters[1]
        self.n_analytic_plans += counters[2]

    @property
    def model(self):
        return self.trainer.model

    @property
    def model_version(self) -> int:
        return self.trainer.version

    def maybe_refit(self) -> None:
        """Refit check — called at every pricing boundary and at lockstep
        round ends; a cheap integer compare when nothing is due.

        A successful refit EVICTS every learned-priced cache entry: cached
        predictions would otherwise be served as hits forever, so early
        model generations would keep steering the search long after being
        superseded (or after the confidence gate closed).  Evicted states
        are simply repriced — by the new model or analytically — on their
        next lookup; analytic entries are exact and never evicted."""
        if (
            self.refit_enabled
            and self.cache is not None
            and self.trainer.should_fit(self.cache)
        ):
            if self.trainer.fit(self.cache) is not None:
                self._evict_learned(self.cache)

    @staticmethod
    def _evict_learned(cache) -> None:
        cache.evict_learned()

    # -- fit-generation-keyed param shipping (pinned workers) ----------
    # Pinned process-pool workers hold this backend for the whole run, so
    # the master ships model parameters ONLY when the fit generation
    # changes — nothing rides on the wire between refits (the pre-pinning
    # pool re-pickled the entire backend, trainer and all, every round).

    def params_delta(self, known_version: int):
        """What a worker holding fit generation ``known_version`` needs:
        ``None`` while the generation is unchanged, else ``(version,
        confident, model)`` — the serving verdict and the warm model
        (params + normalization) of the current generation."""
        t = self.trainer
        if t.version == known_version:
            return None
        return (t.version, t.confident, t.model)

    def apply_params(self, delta) -> None:
        """Worker side: install a shipped fit generation.  Mirrors the
        master's refit eviction first — the local cache may hold
        predictions tagged by the superseded generation, and the master
        already evicted its copies, so they must not keep serving as
        hits.  Until this call arrives, the worker keeps serving the old
        model (bit-identity with the sequential learned path is not a
        contract; the ANALYTIC parallel path never mounts a backend)."""
        version, confident, model = delta
        if self.cache is not None:
            self.cache.evict_learned()
        t = self.trainer
        t.version = version
        t.confident = confident
        t.model = model

    def _serving_model(self):
        m = self.trainer.model
        if m is None:
            return None
        if self.mode == "hybrid" and not self.trainer.confident:
            return None
        return m

    def audit_batch(self, states: Sequence) -> bool:
        """True if a serving-era terminal miss batch should be priced
        analytically anyway (the audit stream).  A pure content hash of
        the first miss state: deterministic across processes and runs,
        ~1/``audit_every`` of batches."""
        if not self.audit_every:
            return False
        h = zlib.crc32(repr(states[0]).encode())
        return h % self.audit_every == 0

    # -- pricing --------------------------------------------------------
    # When the LEARNED model serves, the miss batch's plans are
    # materialized once and encoded once as a PlanColumns
    # structure-of-arrays — the same encoding the analytic columnar
    # kernel prices, featurized directly by the MLP
    # (learned_cost.featurize_columns), so the batch never re-walks the
    # plan objects.  When the model does NOT serve (untrained, gate
    # closed, audit batch), pricing goes straight to the MDP's analytic
    # batch methods — they dedup default-completions and apply the cost
    # model's own small-batch dispatch, so no encode is paid that the
    # kernel would not use.  MDPs without the relevant seams (test
    # doubles) take the scalar fallbacks unchanged.

    def _serve_columns(self, m, cols) -> List[float]:
        if hasattr(m, "cost_columns"):
            return m.cost_columns(cols)
        return m.cost_batch(cols.plans)

    def price_terminal(self, mdp, states: Sequence) -> Tuple[List[float], int]:
        """Price a deduplicated terminal miss batch; ONE model forward
        pass (over one ``PlanColumns`` encode) when serving learned, one
        analytic ``terminal_cost_batch`` → columnar kernel otherwise.
        ~1/``audit_every`` of serving-era batches go analytic (see
        ``__init__``: the audit stream that keeps training alive)."""
        self.maybe_refit()
        m = self._serving_model()
        if m is not None and self.audit_batch(states):
            m = None  # audit batch: exact labels, untagged, harvestable
        plan = getattr(mdp, "plan", None)
        if m is not None and plan is not None:
            cols = PlanColumns.from_plans([plan(s) for s in states])
            costs = self._serve_columns(m, cols)
            self.n_learned_batches += 1
            self.n_learned_plans += len(states)
            return costs, m.version
        self.n_analytic_plans += len(states)
        price = getattr(mdp, "terminal_cost_batch", None)
        if price is not None:
            return price(states), 0
        return [mdp.terminal_cost(s) for s in states], 0

    def price_partial(self, mdp, states: Sequence) -> Tuple[List[float], int]:
        """Partial prefixes price through their default completion — the
        SAME features the analytic partial signal scores
        (``ScheduleMDP.completed_plans``; one shared implementation so the
        two paths cannot drift), and the features the model was trained on
        for complete schedules (the paper's Fig. 1/2 caveat applies: this
        signal is weaker).  MDPs without ``completed_plans`` (test
        doubles) price analytically."""
        self.maybe_refit()
        m = self._serving_model()
        completed = getattr(mdp, "completed_plans", None)
        if m is not None and completed is not None:
            cols = PlanColumns.from_plans(completed(states))
            costs = self._serve_columns(m, cols)
            self.n_learned_batches += 1
            self.n_learned_plans += len(states)
            return costs, m.version
        self.n_analytic_plans += len(states)
        price = getattr(mdp, "partial_cost_batch", None)
        if price is not None:
            return price(states), 0
        return [mdp.partial_cost(s) for s in states], 0

    # -- observability --------------------------------------------------
    def stats(self) -> dict:
        t = self.trainer
        return {
            "cost_mode": self.mode,
            "model_version": t.version,
            "n_fits": len(t.reports),
            "confident": t.confident,
            "holdout_spearman": (
                t.reports[-1].holdout_spearman if t.reports else None
            ),
            "learned_batches": self.n_learned_batches,
            "learned_plans": self.n_learned_plans,
            "analytic_plans": self.n_analytic_plans,
        }


def make_cost_backend(cost, space, **trainer_kwargs):
    """Resolve the ``cost=`` selector to a backend (or ``None``).

    ``"analytic"`` → ``None``: no backend is mounted, so the pricing path
    is the unchanged PR-2 code — bit-identical by construction, certified
    by the differential grid.  A ready-made ``HybridCostBackend`` passes
    through (tests and benchmarks configure trainers directly)."""
    if cost is None or cost == "analytic":
        return None
    if isinstance(cost, HybridCostBackend):
        return cost
    if cost in ("learned", "hybrid"):
        return HybridCostBackend(space, mode=cost, **trainer_kwargs)
    raise ValueError(f"unknown cost mode {cost!r}; expected one of {COST_MODES}")
