"""The ``SearchBackend`` protocol: one calling convention for every search
algorithm (MCTS ensemble, beam, greedy, random), so ``autotune`` — and any
future driver (distributed tuner, learned-cost trainer) — dispatches on an
algorithm name without knowing algorithm internals.

A backend is anything with a ``name`` and

    run(mdp, *, seed=0, time_budget_s=None, measure_fn=None, **opts)
        -> TuneResult

``resolve_backend(algo, engine=..., cost=...)`` maps the paper's Table-1
algorithm names to configured backend instances; ``engine`` selects the
MCTS tree representation — ``"array"`` flat numpy with batched leaf
evaluation (the default, differential-tested against the reference) or
``"reference"`` Node objects — and ``cost`` selects the serving layer of
the cost stack (``"analytic"`` exact, ``"learned"``/``"hybrid"`` online
learned-cost serving behind the transposition cache; see
``repro.core.engine.serving``).  Whichever backend runs, batch pricing
below the seam is the columnar roofline kernel
(``cost_model.PlanColumns`` + ``_terms_columnar``; docs/architecture.md
§4) — bit-identical to the retained scalar oracle, so backend selection
never changes search values.

Execution options flow through ``**opts`` untouched: ``parallel=True``
runs MCTS ensembles on the persistent pinned worker pool
(``repro.core.engine.workers`` — per-round deltas in both directions,
payload bytes surfaced on ``TuneResult``), ``n_workers`` caps that pool,
and non-MCTS backends simply ignore both.
"""
from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

from repro.core.mcts import MCTSConfig


@runtime_checkable
class SearchBackend(Protocol):
    name: str

    def run(
        self,
        mdp,
        *,
        seed: int = 0,
        time_budget_s: Optional[float] = None,
        measure_fn=None,
        **opts,
    ):  # -> TuneResult
        ...


# Table 1 configurations (time budgets scaled: the paper's 30s/10s/1s per
# decision assume a C++ cost model; ours exposes both iteration- and
# second-based budgets).
TABLE1 = {
    "mcts_30s": MCTSConfig(ucb="paper", iters_per_decision=384),
    "mcts_10s": MCTSConfig(ucb="paper", iters_per_decision=128),
    "mcts_1s": MCTSConfig(ucb="paper", iters_per_decision=16),
    "mcts_Cp10_30s": MCTSConfig(ucb="cp10", iters_per_decision=384),
    "mcts_sqrt2_30s": MCTSConfig(ucb="sqrt2", iters_per_decision=384),
    "mcts_cost+real_30s": MCTSConfig(ucb="paper", iters_per_decision=384),
    "mcts_cost+real_1s": MCTSConfig(ucb="paper", iters_per_decision=16),
    "mcts_binary_30s": MCTSConfig(
        ucb="paper", reward_mode="binary", iters_per_decision=384
    ),  # §4.1 0/1-reward ablation (paper: 9% worse)
}


def resolve_backend(
    algo: str, engine: str = "array", cost: str = "analytic"
) -> SearchBackend:
    """Map an algorithm name (paper §5 protocol) to a configured backend.

    ``cost`` configures MCTS backends' learned-cost serving mode; the
    non-model-based baselines (beam/greedy/random) ignore it — they price
    straight through the analytic model, as in the paper."""
    # imported here: beam/random/evolve/ensemble all define backends and
    # import TuneResult from ensemble, which imports this package
    from repro.core.beam import BeamBackend, GreedyBackend
    from repro.core.ensemble import MCTSEnsembleBackend
    from repro.core.evolve import EvolutionarySearchBackend, PortfolioBackend
    from repro.core.random_search import RandomBackend

    if algo == "beam":
        return BeamBackend(beam_size=32, passes=5)
    if algo == "greedy":
        return GreedyBackend()
    if algo == "random":
        return RandomBackend()
    if algo == "evolve":
        return EvolutionarySearchBackend()
    if algo == "portfolio":
        # member mcts/beam runs inherit the engine/cost selection through
        # the portfolio's run() opts
        return PortfolioBackend()
    if algo in TABLE1 or algo == "mcts":
        return MCTSEnsembleBackend(
            algo=algo,
            config=TABLE1.get(algo, TABLE1["mcts_30s"]),
            engine=engine,
            cost=cost,
            name="mcts",
        )
    raise ValueError(f"unknown algo {algo!r}")
