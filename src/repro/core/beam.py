"""Beam search baseline (Adams et al. 2019 protocol: beam 32, 5 passes) and
greedy search (beam size 1).

Exactly the behaviour the paper criticizes: every depth is ranked by the
cost model's estimate of an INCOMPLETE schedule (default-completed here),
so cost-model error compounds at every level of the tree.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.engine import CachedMDP
from repro.core.ensemble import TuneResult
from repro.core.mdp import ScheduleMDP, State


def beam_search(
    mdp: ScheduleMDP,
    *,
    beam_size: int = 32,
    passes: int = 5,
    seed: int = 0,
    time_budget_s: Optional[float] = None,
) -> TuneResult:
    t0 = time.perf_counter()
    rng = random.Random(seed)
    best_cost = float("inf")
    best_state: Optional[State] = None
    for p in range(passes):
        if time_budget_s and time.perf_counter() - t0 > time_budget_s:
            break
        frontier: List[State] = [mdp.initial_state]
        depth = 0
        while frontier and not mdp.is_terminal(frontier[0]):
            candidates: List[Tuple[float, float, State]] = []
            for s in frontier:
                for a in range(mdp.n_actions(s)):
                    child = mdp.step(s, a)
                    c = mdp.partial_cost(child)
                    # later passes diversify via rank jitter (the Halide
                    # autoscheduler restarts with perturbed orderings)
                    jitter = rng.random() * 1e-12 if p == 0 else rng.random() * c * 0.05 * p
                    candidates.append((c + jitter, rng.random(), child))
            candidates.sort()
            frontier = [s for _, _, s in candidates[:beam_size]]
            depth += 1
        for s in frontier:
            c = mdp.terminal_cost(s)
            if c < best_cost:
                best_cost, best_state = c, s
    return TuneResult(
        plan=mdp.plan(best_state),
        cost=best_cost,
        measured=None,
        n_evals=getattr(mdp.cost_model, "n_evals", 0),
        n_measurements=0,
        wall_time_s=time.perf_counter() - t0,
        algo=f"beam{beam_size}",
    )


def greedy_search(mdp: ScheduleMDP, seed: int = 0, **kw) -> TuneResult:
    res = beam_search(mdp, beam_size=1, passes=1, seed=seed, **kw)
    res.algo = "greedy"
    return res


# ---------------------------------------------------------------------------
# SearchBackend adapters (repro.core.engine.backend protocol)
# ---------------------------------------------------------------------------
@dataclass
class BeamBackend:
    """Beam search as a ``SearchBackend``.  ``cache=True`` wraps the MDP in
    the shared transposition cache — beam re-prices identical default-
    completed prefixes across passes, so later passes become nearly free."""

    beam_size: int = 32
    passes: int = 5
    name: str = "beam"

    def run(self, mdp, *, seed=0, time_budget_s=None, measure_fn=None,
            cache: bool = False, **_) -> TuneResult:
        if cache and not isinstance(mdp, CachedMDP):
            mdp = CachedMDP(mdp)
        res = beam_search(
            mdp,
            beam_size=self.beam_size,
            passes=self.passes,
            seed=seed,
            time_budget_s=time_budget_s,
        )
        if isinstance(mdp, CachedMDP):
            res.cache_hits = mdp.cache.hits
            res.cache_misses = mdp.cache.misses
        return res


@dataclass
class GreedyBackend:
    name: str = "greedy"

    def run(self, mdp, *, seed=0, time_budget_s=None, measure_fn=None,
            **_) -> TuneResult:
        return greedy_search(mdp, seed=seed, time_budget_s=time_budget_s)
