"""The scheduling MDP (paper §3-4): deterministic transitions over decision
prefixes; only terminal (complete) schedules have a meaningful cost."""
from __future__ import annotations

from typing import Callable, Sequence, Tuple

from repro.core.cost_model import AnalyticCostModel
from repro.core.space import SchedulePlan, ScheduleSpace

State = Tuple[int, ...]


class ScheduleMDP:
    def __init__(self, space: ScheduleSpace, cost_model):
        self.space = space
        self.cost_model = cost_model

    @property
    def initial_state(self) -> State:
        return ()

    def n_actions(self, state: State) -> int:
        return self.space.n_actions(len(state))

    def step(self, state: State, action: int) -> State:
        assert 0 <= action < self.n_actions(state)
        return state + (action,)

    def is_terminal(self, state: State) -> bool:
        return len(state) == self.space.n_stages

    def plan(self, state: State) -> SchedulePlan:
        assert self.is_terminal(state)
        return self.space.plan_from_actions(state)

    def terminal_cost(self, state: State) -> float:
        """Cost of a COMPLETE schedule — the only reliable signal."""
        return self.cost_model.cost(self.plan(state))

    def partial_cost(self, state: State) -> float:
        """Cost of an incomplete schedule via default-completion — the
        unreliable intermediate signal beam/greedy search depends on."""
        if self.is_terminal(state):
            return self.terminal_cost(state)
        return self.cost_model.partial_cost(state, self.space)
