"""The scheduling MDP (paper §3-4): deterministic transitions over decision
prefixes; only terminal (complete) schedules have a meaningful cost."""
from __future__ import annotations

from typing import Callable, Sequence, Tuple

from repro.core.cost_model import AnalyticCostModel
from repro.core.space import SchedulePlan, ScheduleSpace

State = Tuple[int, ...]


class ScheduleMDP:
    def __init__(self, space: ScheduleSpace, cost_model):
        self.space = space
        self.cost_model = cost_model

    @property
    def initial_state(self) -> State:
        return ()

    def n_actions(self, state: State) -> int:
        return self.space.n_actions(len(state))

    def step(self, state: State, action: int) -> State:
        assert 0 <= action < self.n_actions(state)
        return state + (action,)

    def is_terminal(self, state: State) -> bool:
        return len(state) == self.space.n_stages

    def plan(self, state: State) -> SchedulePlan:
        assert self.is_terminal(state)
        return self.space.plan_from_actions(state)

    def terminal_cost(self, state: State) -> float:
        """Cost of a COMPLETE schedule — the only reliable signal."""
        return self.cost_model.cost(self.plan(state))

    def partial_cost(self, state: State) -> float:
        """Cost of an incomplete schedule via default-completion — the
        unreliable intermediate signal beam/greedy search depends on."""
        if self.is_terminal(state):
            return self.terminal_cost(state)
        return self.cost_model.partial_cost(state, self.space)

    def completed_plans(self, states: Sequence[State]) -> list:
        """Default-complete each prefix into a full ``SchedulePlan`` — the
        features every partial-schedule consumer scores (the analytic
        batch path here and the learned-cost server in
        ``engine/serving.py``); defaults resolved once per batch."""
        defaults = self.space.default_actions()
        return [
            self.space.plan_from_actions(list(s) + defaults[len(s):])
            for s in states
        ]

    # -- batched pricing (values identical to the scalar methods) ----------
    def terminal_cost_batch(self, states: Sequence[State]) -> list:
        """``[terminal_cost(s) for s in states]`` in one cost-model call.
        Routes through ``cost_model.cost_batch`` when available — the
        batch materializes its plans once and (columnar models) encodes
        them once as ``PlanColumns`` for the vectorized roofline kernel;
        duplicate states are priced once.  Falls back to the scalar
        loop for cost models without a batch seam."""
        batch = getattr(self.cost_model, "cost_batch", None)
        if batch is None:
            return [self.terminal_cost(s) for s in states]
        return batch([self.plan(s) for s in states])

    def partial_cost_batch(self, states: Sequence[State]) -> list:
        """``[partial_cost(s) for s in states]`` in one cost-model call
        (terminal states price as terminal, like the scalar method); the
        default completions resolve against the space's memoized default
        actions and the completed batch takes the same one-encode columnar
        path as ``terminal_cost_batch``."""
        batch = getattr(self.cost_model, "cost_batch", None)
        if batch is None:
            return [self.partial_cost(s) for s in states]
        return batch(self.completed_plans(states))
