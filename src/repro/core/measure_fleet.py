"""Fault-tolerant measurement fleet: N persistent worker processes
fanning out ``measure_fn`` requests, sharing the content-hash on-disk
cache as the dedup store.

Real measurement (a subprocess XLA compile per plan, seconds each) is the
one layer of the cost stack that cannot hide inside the search loop's
~100 µs budget.  This module moves it off the critical path: the master
batches every plan it wants priced into one ``measure_many`` call, the
fleet fans the cache misses out over persistent workers, and the search
only ever blocks at root synchronizations — exactly where the paper's
``mcts_cost+real_*`` configurations re-rank candidates.

Request lifecycle (docs/architecture.md §8):

1. **cache** — each request is keyed by ``measure.request_key`` (content
   hash of version, arch, shape, mesh, devices, plan); a valid on-disk
   record resolves the request without touching a worker.
2. **single-flight** — concurrent misses for the same key are grouped
   into one in-flight job; the plan compiles once and every requester
   shares the record.
3. **dispatch** — jobs go to idle workers over the same pipe protocol as
   ``PinnedWorkerPool`` (spawn via ``pick_mp_context``'s forkserver).
4. **watchdog** — every in-flight job has a master-side deadline
   (request timeout + ``grace_s``); a worker that blows it is SIGKILLed
   and respawned, and the job re-queues.
5. **retry** — failures (worker death, watchdog timeout, or an error the
   target raised) re-queue with exponential backoff
   (``backoff_s * backoff_factor**(retries-1)``) up to ``max_retries``;
   every re-dispatch, whatever its cause, consumes the same budget.
6. **publish** — a successful record is written atomically
   (``measure.write_record``) so a fleet cache file is byte-identical to
   the serial ``measure_cell`` path's.

A request that exhausts its retries resolves to a failed
``MeasureOutcome`` (``record=None``, ``error`` set) — the fleet never
raises from ``measure_many``; callers choose strictness.  ``FleetMeasure``
(from ``bind``) is the ``measure_fn``-shaped adapter the ensemble
threads through ``measure_backend=``.
"""
from __future__ import annotations

import heapq
import os
import pickle
import time
import traceback
from dataclasses import dataclass, field
from multiprocessing.connection import wait as _conn_wait
from typing import Dict, List, Optional

from repro.core.engine.workers import _PROTO, pick_mp_context
from repro.core.measure import (
    CACHE_DIR,
    load_record,
    make_request,
    measure_request,
    request_key,
    write_record,
)


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------
def _fleet_worker_main(conn, target) -> None:
    """One-request-at-a-time measurement loop.  ``target`` is the
    measurement function (module-level, pickled by reference): the real
    subprocess ``measure_request`` in production, the analytic stub in
    tests and the CI gate."""
    try:
        while True:
            try:
                msg = pickle.loads(conn.recv_bytes())
            except EOFError:
                return
            if msg[0] == "stop":
                return
            _, rid, req = msg
            try:
                out = ("ok", rid, target(req))
            except Exception:  # surfaced master-side; retry policy decides
                out = ("err", rid, traceback.format_exc())
            conn.send_bytes(pickle.dumps(out, _PROTO))
    except (BrokenPipeError, ConnectionResetError, KeyboardInterrupt, OSError):
        return


# ---------------------------------------------------------------------------
# Master side
# ---------------------------------------------------------------------------
@dataclass
class MeasureOutcome:
    """Per-request provenance — stamped onto sweep artifact rows."""

    key: str
    record: Optional[dict] = None
    from_cache: bool = False
    attempts: int = 0
    retries: int = 0
    timeouts: int = 0
    worker_deaths: int = 0
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.record is not None

    def provenance(self) -> dict:
        return {
            "key": self.key,
            "from_cache": self.from_cache,
            "attempts": self.attempts,
            "retries": self.retries,
            "timeouts": self.timeouts,
            "worker_deaths": self.worker_deaths,
            "failed": not self.ok,
        }


@dataclass
class _Job:
    """One in-flight cache key (single-flight: N requests, one compile)."""

    key: str
    req: dict
    slots: List[int] = field(default_factory=list)  # output positions
    outcome: MeasureOutcome = None  # type: ignore[assignment]
    ready_at: float = 0.0


@dataclass
class _FleetWorker:
    proc: object
    conn: object
    job: Optional[_Job] = None
    deadline: float = 0.0


class MeasurementFleet:
    """Master-side handle over the measurement workers.

    Workers spawn lazily on the first cache miss and persist across
    ``measure_many`` calls; ``shutdown()`` (or the context manager) stops
    them.  All counters are cumulative over the fleet's lifetime.
    """

    def __init__(
        self,
        n_workers: int = 4,
        *,
        cache_dir: Optional[str] = None,
        target=None,
        timeout: float = 1800.0,
        grace_s: float = 60.0,
        max_retries: int = 2,
        backoff_s: float = 0.5,
        backoff_factor: float = 2.0,
        mp_context=None,
    ):
        self.n_workers = max(int(n_workers), 1)
        self.cache_dir = cache_dir or CACHE_DIR
        self.target = target or measure_request
        self.timeout = timeout
        self.grace_s = grace_s
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_factor = backoff_factor
        self._ctx = mp_context
        self._workers: List[_FleetWorker] = []
        self._rid = 0
        self._seq = 0
        # lifetime counters
        self.n_requests = 0
        self.n_cache_hits = 0
        self.n_deduped = 0
        self.n_measured = 0
        self.n_retries = 0
        self.n_timeouts = 0
        self.n_failures = 0
        self.n_worker_restarts = 0

    # -- lifecycle -----------------------------------------------------
    def _ensure_workers(self) -> None:
        if self._ctx is None:
            self._ctx = pick_mp_context()
        while len(self._workers) < self.n_workers:
            self._workers.append(self._spawn())

    def _spawn(self) -> _FleetWorker:
        parent, child = self._ctx.Pipe(duplex=True)
        proc = self._ctx.Process(
            target=_fleet_worker_main, args=(child, self.target), daemon=True
        )
        proc.start()
        child.close()
        return _FleetWorker(proc, parent)

    def _respawn(self, w: _FleetWorker) -> None:
        """SIGKILL-survivable replacement (same recovery shape as
        ``PinnedWorkerPool._resync``): the dead worker's job re-queues
        through the normal retry budget."""
        self.n_worker_restarts += 1
        try:
            w.conn.close()
        except OSError:
            pass
        if w.proc.is_alive():
            w.proc.kill()
        w.proc.join(timeout=5)
        self._workers[self._workers.index(w)] = self._spawn()

    def shutdown(self) -> None:
        for w in self._workers:
            try:
                w.conn.send_bytes(pickle.dumps(("stop",), _PROTO))
            except (BrokenPipeError, ConnectionResetError, OSError):
                pass
        for w in self._workers:
            w.proc.join(timeout=5)
            if w.proc.is_alive():
                w.proc.terminate()
            try:
                w.conn.close()
            except OSError:
                pass
        self._workers = []

    def __enter__(self) -> "MeasurementFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- scheduling ----------------------------------------------------
    def _requeue(self, job: _Job, pending: List, retries: List) -> None:
        """Failed attempt: back off and retry, or fail permanently."""
        o = job.outcome
        if o.retries >= self.max_retries:
            self.n_failures += 1
            if o.error is None:
                o.error = "retries exhausted"
            job.ready_at = -1.0  # terminal marker
            return
        o.retries += 1
        self.n_retries += 1
        delay = self.backoff_s * self.backoff_factor ** (o.retries - 1)
        job.ready_at = time.monotonic() + delay
        self._seq += 1
        heapq.heappush(retries, (job.ready_at, self._seq, job))

    def _dispatch(self, w: _FleetWorker, job: _Job) -> bool:
        self._rid += 1
        job.outcome.attempts += 1
        payload = pickle.dumps(("req", self._rid, job.req), _PROTO)
        try:
            w.conn.send_bytes(payload)
        except (BrokenPipeError, ConnectionResetError, OSError):
            return False  # caller respawns; attempt not charged to retries
        w.job = job
        timeout = job.req.get("timeout") or self.timeout
        w.deadline = time.monotonic() + timeout + self.grace_s
        return True

    # -- the fan-out ---------------------------------------------------
    def measure_many(self, requests: List[dict]) -> List[MeasureOutcome]:
        """Resolve every request: cache hit, deduped join on an in-flight
        key, or a fleet measurement.  Never raises — inspect
        ``MeasureOutcome.ok`` / ``.error`` per request."""
        os.makedirs(self.cache_dir, exist_ok=True)
        self.n_requests += len(requests)
        outcomes: List[Optional[MeasureOutcome]] = [None] * len(requests)
        jobs: Dict[str, _Job] = {}
        for i, req in enumerate(requests):
            key = request_key(req)
            if key in jobs:  # single-flight: join the in-flight job
                jobs[key].slots.append(i)
                self.n_deduped += 1
                continue
            rec = load_record(os.path.join(self.cache_dir, key + ".json"))
            if rec is not None:
                self.n_cache_hits += 1
                outcomes[i] = MeasureOutcome(key, rec, from_cache=True)
                continue
            job = _Job(key, req, [i])
            job.outcome = MeasureOutcome(key)
            jobs[key] = job
        if jobs:
            self._run(list(jobs.values()))
        for job in jobs.values():
            for i in job.slots:
                outcomes[i] = job.outcome
        return outcomes  # type: ignore[return-value]

    def _run(self, todo: List[_Job]) -> None:
        self._ensure_workers()
        pending: List[_Job] = list(todo)
        retries: List = []  # (ready_at, seq, job) heap
        done = 0
        total = len(todo)
        while done < total:
            now = time.monotonic()
            # promote due retries
            while retries and retries[0][0] <= now:
                pending.append(heapq.heappop(retries)[2])
            # dispatch to idle workers (an idle worker found dead at send
            # time is replaced in place; the attempt is not charged)
            for wi in range(len(self._workers)):
                if not pending:
                    break
                if self._workers[wi].job is not None:
                    continue
                job = pending.pop(0)
                while not self._dispatch(self._workers[wi], job):
                    job.outcome.attempts -= 1
                    self._respawn(self._workers[wi])
            busy = [w for w in self._workers if w.job is not None]
            if not busy:
                if retries:
                    time.sleep(max(0.0, retries[0][0] - time.monotonic()))
                    continue
                if pending:
                    continue
                break  # every remaining job failed terminally
            # wait for the first result or the nearest deadline
            horizon = min(w.deadline for w in busy)
            if retries:
                horizon = min(horizon, retries[0][0])
            wait_s = max(0.0, min(horizon - time.monotonic(), 1.0))
            ready = _conn_wait([w.conn for w in busy], timeout=wait_s)
            for conn in ready:
                w = next(x for x in busy if x.conn is conn)
                job = w.job
                try:
                    msg = pickle.loads(conn.recv_bytes())
                except (BrokenPipeError, ConnectionResetError, EOFError, OSError):
                    # worker died mid-request (e.g. SIGKILL)
                    w.job = None
                    self._respawn(w)
                    job.outcome.worker_deaths += 1
                    self._requeue(job, pending, retries)
                    if job.ready_at < 0:
                        done += 1
                    continue
                w.job = None
                if msg[0] == "ok":
                    path = os.path.join(self.cache_dir, job.key + ".json")
                    write_record(path, msg[2])
                    # serve the JSON round-trip, exactly like a cache hit
                    job.outcome.record = load_record(path)
                    self.n_measured += 1
                    done += 1
                else:
                    job.outcome.error = msg[2]
                    self._requeue(job, pending, retries)
                    if job.ready_at < 0:
                        done += 1
            # watchdog: kill workers past their deadline
            now = time.monotonic()
            for w in [x for x in self._workers if x.job is not None]:
                if now < w.deadline:
                    continue
                job = w.job
                w.job = None
                self._respawn(w)
                timeout = job.req.get("timeout") or self.timeout
                self.n_timeouts += 1
                job.outcome.timeouts += 1
                job.outcome.error = (
                    f"watchdog: no result within {timeout:.1f}s"
                    f"+{self.grace_s:.1f}s grace"
                )
                self._requeue(job, pending, retries)
                if job.ready_at < 0:
                    done += 1

    # -- conveniences ---------------------------------------------------
    def measure_cell(
        self,
        arch: str,
        shape: str,
        mesh: str = "single",
        plan=None,
        devices: Optional[int] = None,
        extras: Optional[dict] = None,
    ) -> dict:
        """Strict single-request measurement (raises on failure) —
        fleet-backed drop-in for ``measure.measure_cell``."""
        req = make_request(
            arch, shape, mesh, plan, devices, self.timeout, extras=extras
        )
        out = self.measure_many([req])[0]
        if not out.ok:
            raise RuntimeError(
                f"fleet measurement failed for {arch}×{shape}×{mesh} "
                f"after {out.attempts} attempt(s): {out.error}"
            )
        return out.record

    def bind(
        self,
        arch: str,
        shape: str,
        mesh: str = "single",
        devices: Optional[int] = None,
    ) -> "FleetMeasure":
        return FleetMeasure(self, arch, shape, mesh, devices)

    def stats(self) -> dict:
        return {
            "n_workers": self.n_workers,
            "n_requests": self.n_requests,
            "n_cache_hits": self.n_cache_hits,
            "n_deduped": self.n_deduped,
            "n_measured": self.n_measured,
            "n_retries": self.n_retries,
            "n_timeouts": self.n_timeouts,
            "n_failures": self.n_failures,
            "n_worker_restarts": self.n_worker_restarts,
        }


class FleetMeasure:
    """``measure_fn``-shaped adapter over a fleet, bound to one cell.

    ``__call__`` is the strict scalar interface existing callers expect
    (plan → step seconds, raises on failure); ``measure_plans`` is the
    batch interface the ensemble's re-rank prefetch uses — one
    ``measure_many`` fan-out, ``None`` per failed plan so the caller can
    degrade that candidate to its analytic estimate.
    """

    def __init__(self, fleet: MeasurementFleet, arch, shape, mesh, devices):
        self.fleet = fleet
        self.arch, self.shape = arch, shape
        self.mesh, self.devices = mesh, devices

    def _request(self, plan) -> dict:
        return make_request(
            self.arch, self.shape, self.mesh, plan, self.devices,
            self.fleet.timeout,
        )

    def __call__(self, plan) -> float:
        out = self.fleet.measure_many([self._request(plan)])[0]
        if not out.ok:
            raise RuntimeError(
                f"fleet measurement failed for {self.arch}×{self.shape}"
                f"×{self.mesh}: {out.error}"
            )
        return float(out.record["step_s"])

    def measure_plans(self, plans) -> List[Optional[float]]:
        outs = self.fleet.measure_many([self._request(p) for p in plans])
        return [
            float(o.record["step_s"]) if o.ok else None for o in outs
        ]

    def stats(self) -> dict:
        return self.fleet.stats()
