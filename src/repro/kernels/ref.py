"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernels are validated against
(``tests/test_kernels_*.py`` sweep shapes/dtypes and assert_allclose), and the
fallback path ``ops.py`` dispatches to when kernels are disabled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Flash attention (GQA, causal)
# ---------------------------------------------------------------------------
def attention(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    Skv = k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    groups = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    kk = jnp.repeat(k, groups, axis=1)
    vv = jnp.repeat(v, groups, axis=1)
    logits = jnp.einsum(
        "bhsd,bhtd->bhst", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    if causal:
        # queries are the LAST S positions of the Skv-long key sequence
        qpos = jnp.arange(S)[:, None] + (Skv - S)
        kpos = jnp.arange(Skv)[None, :]
        logits = jnp.where(qpos >= kpos, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba-1 selective scan
# ---------------------------------------------------------------------------
def selective_scan(
    u: jax.Array,  # (B, L, Di)
    dt: jax.Array,  # (B, L, Di)   (already softplus'd)
    A: jax.Array,  # (Di, N)      (negative reals)
    Bm: jax.Array,  # (B, L, N)
    Cm: jax.Array,  # (B, L, N)
    D: jax.Array,  # (Di,)
) -> jax.Array:
    """y_t = C_t . x_t + D*u_t with x_t = exp(dt_t A) x_{t-1} + dt_t u_t B_t."""
    Bsz, L, Di = u.shape
    N = A.shape[1]
    uf = u.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(x, inp):
        u_t, dt_t, b_t, c_t = inp  # (B,Di),(B,Di),(B,N),(B,N)
        dA = jnp.exp(dt_t[..., None] * Af[None])  # (B, Di, N)
        dBu = (dt_t * u_t)[..., None] * b_t[:, None, :]  # (B, Di, N)
        x = dA * x + dBu
        y = jnp.einsum("bdn,bn->bd", x, c_t)
        return x, y

    x0 = jnp.zeros((Bsz, Di, N), jnp.float32)
    xs = (
        uf.transpose(1, 0, 2),
        dtf.transpose(1, 0, 2),
        Bf.transpose(1, 0, 2),
        Cf.transpose(1, 0, 2),
    )
    _, ys = jax.lax.scan(step, x0, xs)
    y = ys.transpose(1, 0, 2) + uf * D.astype(jnp.float32)[None, None]
    return y.astype(u.dtype)


def selective_scan_step(
    x: jax.Array,  # (B, Di, N) carried state
    u: jax.Array,  # (B, Di)
    dt: jax.Array,  # (B, Di)
    A: jax.Array,  # (Di, N)
    b: jax.Array,  # (B, N)
    c: jax.Array,  # (B, N)
    D: jax.Array,  # (Di,)
):
    """Single decode step; returns (new_state, y)."""
    xf = x.astype(jnp.float32)
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A.astype(jnp.float32)[None])
    dBu = (dt * u).astype(jnp.float32)[..., None] * b.astype(jnp.float32)[:, None, :]
    xf = dA * xf + dBu
    y = jnp.einsum("bdn,bn->bd", xf, c.astype(jnp.float32))
    y = y + u.astype(jnp.float32) * D.astype(jnp.float32)[None]
    return xf.astype(x.dtype), y.astype(u.dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE grouped (capacity-batched) GEMM
# ---------------------------------------------------------------------------
def moe_gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (E, C, d), w: (E, d, f) -> (E, C, f); f32 accumulation."""
    out = jnp.einsum(
        "ecd,edf->ecf",
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Int8 rowwise quantization (gradient compression)
# ---------------------------------------------------------------------------
def quantize_int8(x: jax.Array):
    """Rowwise symmetric int8. x: (R, C) -> (q int8 (R,C), scale f32 (R,1))."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)
