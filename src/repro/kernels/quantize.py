"""Int8 rowwise symmetric quant/dequant Pallas-TPU kernels.

Used by the error-feedback compressed gradient all-reduce: quantize before
putting bytes on the ICI wire, dequantize after.  Both kernels are pure
memory-bound VPU work — fusing max-reduce + scale + round into one pass
halves the HBM traffic of the compression step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, C)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_int8(x: jax.Array, *, block_rows: int = 256, interpret: bool = False):
    R, C = x.shape
    block_rows = min(block_rows, R)
    assert R % block_rows == 0
    grid = (R // block_rows,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, C), lambda r: (r, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, C), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, 1), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.int8),
            jax.ShapeDtypeStruct((R, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("dtype", "block_rows", "interpret"))
def dequantize_int8(
    q: jax.Array,
    scale: jax.Array,
    *,
    dtype=jnp.float32,
    block_rows: int = 256,
    interpret: bool = False,
):
    R, C = q.shape
    block_rows = min(block_rows, R)
    assert R % block_rows == 0
    grid = (R // block_rows,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, C), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, 1), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, C), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), dtype),
        interpret=interpret,
    )(q, scale)
