"""Mamba-1 selective-scan Pallas-TPU kernel (chunked along time).

Tiling: grid = (batch, d_inner blocks, time chunks); time chunks are the
innermost (sequential) grid axis so the SSM state (d_block × N) lives in VMEM
scratch and is carried across chunks.  Within a chunk the recurrence is a
``fori_loop`` over time steps whose body is pure VPU work over the
(d_block × N) state — on TPU the (8,128)-lane VREG layout wants
d_block a multiple of 8 and N (=16 for Mamba-1) padded into lanes.

``chunk`` is a schedule-space knob: larger chunks amortize grid overhead and
HBM→VMEM block transfers; smaller chunks shrink the VMEM working set
(u/dt/y blocks are (chunk × d_block)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return pl.MemorySpace.ANY(shape, dtype)  # type: ignore[attr-defined]


def _scan_kernel(
    u_ref,  # (1, chunk, d_block)
    dt_ref,  # (1, chunk, d_block)
    a_ref,  # (d_block, N)
    b_ref,  # (1, chunk, N)
    c_ref,  # (1, chunk, N)
    d_ref,  # (1, d_block)
    y_ref,  # (1, chunk, d_block)
    x_ref,  # scratch (d_block, N) f32
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        x_ref[...] = jnp.zeros_like(x_ref)

    a = a_ref[...].astype(jnp.float32)  # (d_block, N)
    dvec = d_ref[0, :].astype(jnp.float32)  # (d_block,)

    def body(t, _):
        u_t = u_ref[0, t, :].astype(jnp.float32)  # (d_block,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)  # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)
        dA = jnp.exp(dt_t[:, None] * a)  # (d_block, N)
        dBu = (dt_t * u_t)[:, None] * b_t[None, :]
        x = dA * x_ref[...] + dBu
        x_ref[...] = x
        y = jnp.sum(x * c_t[None, :], axis=1) + dvec * u_t
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


@functools.partial(jax.jit, static_argnames=("chunk", "d_block", "interpret"))
def selective_scan(
    u: jax.Array,  # (B, L, Di)
    dt: jax.Array,  # (B, L, Di)
    A: jax.Array,  # (Di, N)
    Bm: jax.Array,  # (B, L, N)
    Cm: jax.Array,  # (B, L, N)
    D: jax.Array,  # (Di,)
    *,
    chunk: int = 128,
    d_block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, L, Di = u.shape
    N = A.shape[1]
    chunk = min(chunk, L)
    d_block = min(d_block, Di)
    assert L % chunk == 0 and Di % d_block == 0, (L, chunk, Di, d_block)
    grid = (B, Di // d_block, L // chunk)

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, di, c: (b, c, di)),
            pl.BlockSpec((1, chunk, d_block), lambda b, di, c: (b, c, di)),
            pl.BlockSpec((d_block, N), lambda b, di, c: (di, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, di, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, di, c: (b, c, 0)),
            pl.BlockSpec((1, d_block), lambda b, di, c: (0, di)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block), lambda b, di, c: (b, c, di)),
        out_shape=jax.ShapeDtypeStruct((B, L, Di), u.dtype),
        scratch_shapes=[_vmem((d_block, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, A, Bm, Cm, D.reshape(1, Di))


# re-exported from the jax-free geometry module
from repro.kernels.geometry import scan_vmem_bytes as vmem_bytes  # noqa: E402
