"""Fused RMSNorm Pallas-TPU kernel.

Row-tiled: grid over blocks of rows; each block loads (block_rows × d) into
VMEM once, reduces in f32 on the VPU, scales, and writes back — one HBM
round-trip instead of the three (square, mean, scale) an unfused lowering
would do for large d.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm(
    x: jax.Array,  # (..., d)
    w: jax.Array,  # (d,)
    *,
    block_rows: int = 256,
    eps: float = 1e-6,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    # pad rows to a multiple of block_rows
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((1, d), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w.reshape(1, d))
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
