"""Flash attention forward Pallas-TPU kernel (causal, GQA).

Tiling: grid = (batch, q_heads, q_blocks, kv_blocks); the kv axis is the
innermost (sequential on TPU), so the online-softmax running max / sum /
accumulator live in VMEM scratch that persists across kv steps.  The MXU
sees (block_q × head_dim) @ (head_dim × block_kv) and
(block_q × block_kv) @ (block_kv × head_dim) matmuls — block sizes are
schedule-space knobs (multiples of 128 keep the MXU fully fed).

Fully-masked kv blocks above the causal diagonal are skipped via
``pl.when`` — with block_q == block_kv this halves the compute, and is the
structural analogue of the paper's "don't evaluate children you will not
use" observation (§5.3).

GQA is handled in the k/v index_maps (q-head h reads kv-head h // group).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(
    q_ref,  # (1, 1, block_q, D)
    k_ref,  # (1, 1, block_kv, D)
    v_ref,  # (1, 1, block_kv, D)
    o_ref,  # (1, 1, block_q, D)
    m_ref,  # scratch (block_q, 1) f32
    l_ref,  # scratch (block_q, 1) f32
    acc_ref,  # scratch (block_q, D) f32
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_kv: int,
    seq_q: int,
    seq_kv: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nkv = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block skip: first kv index of this block vs last q position of
    # this q block (queries occupy the LAST seq_q positions of seq_kv).
    q_off = seq_kv - seq_q
    run = True
    if causal:
        run = kj * block_kv <= q_off + (qi + 1) * block_q - 1

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bkv)
        if causal:
            qpos = q_off + qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0
            )
            kpos = kj * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1
            )
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]  # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (bq, bkv)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kj == nkv - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0, (Sq, block_q, Skv, block_kv)
    scale = D ** -0.5
    grid = (B, Hq, Sq // block_q, Skv // block_kv)

    kernel = functools.partial(
        _fwd_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_kv=block_kv,
        seq_q=Sq,
        seq_kv=Skv,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, kj: (b, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, D),
                lambda b, h, qi, kj, g=group: (b, h // g, kj, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_kv, D),
                lambda b, h, qi, kj, g=group: (b, h // g, kj, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, D), lambda b, h, qi, kj: (b, h, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            _vmem((block_q, 1), jnp.float32),
            _vmem((block_q, 1), jnp.float32),
            _vmem((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover - CPU interpret fallback
        return pl.MemorySpace.ANY(shape, dtype)  # type: ignore[attr-defined]


# re-exported from the jax-free geometry module (the cost model and the
# search workers import it from there without touching jax)
from repro.kernels.geometry import flash_vmem_bytes as vmem_bytes  # noqa: E402
