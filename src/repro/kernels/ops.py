"""Public jit'd kernel entry points with backend dispatch.

Three modes (``set_kernel_mode`` / ``kernel_mode`` context manager):

* ``auto``      — Pallas kernels on TPU, jnp oracles elsewhere (default).
                  This is what the models call: on the CPU-only container the
                  oracle path lowers to the same dot-products so dry-run
                  ``cost_analysis`` FLOPs/bytes are representative, while on a
                  real TPU pod the Pallas kernels run.
* ``interpret`` — Pallas kernels in interpret mode (CPU correctness tests).
* ``ref``       — force the jnp oracles.

Kernel block shapes are threaded from the schedule plan (``KernelTiles``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gemm as _mg
from repro.kernels import quantize as _qt
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rn
from repro.kernels import selective_scan as _ss

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class KernelTiles:
    """Schedule-tunable kernel block shapes."""

    attn_block_q: int = 256
    attn_block_kv: int = 256
    scan_chunk: int = 128
    scan_d_block: int = 256
    moe_block_c: int = 128
    moe_block_f: int = 256
    moe_block_d: int = 256


DEFAULT_TILES = KernelTiles()


def set_kernel_mode(mode: str) -> None:
    assert mode in ("auto", "interpret", "ref"), mode
    _state.mode = mode


def get_kernel_mode() -> str:
    return getattr(_state, "mode", "auto")


@contextlib.contextmanager
def kernel_mode(mode: str):
    prev = get_kernel_mode()
    set_kernel_mode(mode)
    try:
        yield
    finally:
        set_kernel_mode(prev)


def _use_pallas() -> bool:
    mode = get_kernel_mode()
    if mode == "ref":
        return False
    if mode == "interpret":
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return get_kernel_mode() == "interpret" or jax.default_backend() != "tpu"


# -- attention ---------------------------------------------------------------
def attention(q, k, v, *, causal=True, tiles: KernelTiles = DEFAULT_TILES):
    if _use_pallas():
        return _fa.flash_attention(
            q,
            k,
            v,
            causal=causal,
            block_q=tiles.attn_block_q,
            block_kv=tiles.attn_block_kv,
            interpret=_interpret(),
        )
    # kernel_streamed: on the TPU target this region is the flash-attention
    # Pallas kernel — its interior (S² scores chain) never touches HBM, so
    # the HLO byte analysis (core/hlo_analysis.py) excludes ops under this
    # scope from the memory-roofline term.
    with jax.named_scope("kernel_streamed_attention"):
        return _ref.attention(q, k, v, causal=causal)


# -- mamba scan ----------------------------------------------------------------
def selective_scan(u, dt, A, Bm, Cm, D, *, tiles: KernelTiles = DEFAULT_TILES):
    if _use_pallas():
        return _ss.selective_scan(
            u,
            dt,
            A,
            Bm,
            Cm,
            D,
            chunk=tiles.scan_chunk,
            d_block=tiles.scan_d_block,
            interpret=_interpret(),
        )
    # kernel_streamed: the Pallas scan kernel carries the SSM state in VMEM
    with jax.named_scope("kernel_streamed_scan"):
        return _ref.selective_scan(u, dt, A, Bm, Cm, D)


selective_scan_step = _ref.selective_scan_step  # decode step: pure jnp


# -- rmsnorm -------------------------------------------------------------------
def rmsnorm(x, w, *, eps: float = 1e-6):
    if _use_pallas():
        return _rn.rmsnorm(x, w, eps=eps, interpret=_interpret())
    return _ref.rmsnorm(x, w, eps=eps)


# -- moe grouped gemm -----------------------------------------------------------
def moe_gemm(x, w, *, tiles: KernelTiles = DEFAULT_TILES):
    if _use_pallas():
        return _mg.moe_gemm(
            x,
            w,
            block_c=tiles.moe_block_c,
            block_f=tiles.moe_block_f,
            block_d=tiles.moe_block_d,
            interpret=_interpret(),
        )
    return _ref.moe_gemm(x, w)


# -- int8 quant ------------------------------------------------------------------
def quantize_int8(x):
    if _use_pallas():
        return _qt.quantize_int8(x, interpret=_interpret())
    return _ref.quantize_int8(x)


def dequantize_int8(q, scale, dtype=jnp.float32):
    if _use_pallas():
        return _qt.dequantize_int8(q, scale, dtype=dtype, interpret=_interpret())
    return _ref.dequantize_int8(q, scale, dtype=dtype)
