"""Grouped (per-expert) matmul Pallas-TPU kernel for capacity-batched MoE.

x: (E, C, d) tokens grouped per expert (padded to capacity C),
w: (E, d, f) expert weights  ->  (E, C, f).

Tiling: grid = (E, C/block_c, f/block_f, d/block_d) with the contraction
axis innermost so a (block_c × block_f) f32 accumulator persists in VMEM
scratch across d-steps.  Every matmul tile is MXU-shaped; block sizes are
schedule knobs (multiples of 128).  Expert-parallel execution shards the E
axis, so the kernel never sees more than E/ep experts per device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover
        return pl.MemorySpace.ANY(shape, dtype)  # type: ignore[attr-defined]


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    di = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(di == nd - 1)
    def _fin():
        o_ref[0, :, :] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret")
)
def moe_gemm(
    x: jax.Array,  # (E, C, d)
    w: jax.Array,  # (E, d, f)
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 256,
    interpret: bool = False,
) -> jax.Array:
    E, C, d = x.shape
    _, _, f = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    block_d = min(block_d, d)
    assert C % block_c == 0 and f % block_f == 0 and d % block_d == 0
    grid = (E, C // block_c, f // block_f, d // block_d)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, c, fo, di: (e, c, di)),
            pl.BlockSpec((1, block_d, block_f), lambda e, c, fo, di: (e, di, fo)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_c, block_f), lambda e, c, fo, di: (e, c, fo)
        ),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=[_vmem((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
