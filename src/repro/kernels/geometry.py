"""Kernel tile-geometry helpers: pure integer math, NO jax imports.

These working-set formulas are shared by the Pallas kernels (to validate
block choices) and by the analytic cost model (to penalize VMEM-spilling
schedules).  They live in a jax-free module so the search layer — including
``ProTuner``'s process-pool workers, which only ever price schedules —
never drags the XLA runtime into the process.  ``flash_attention`` and
``selective_scan`` re-export them for backward compatibility.
"""
from __future__ import annotations


def flash_vmem_bytes(
    block_q: int, block_kv: int, head_dim: int, dtype_bytes: int = 2
) -> int:
    """Working-set estimate for one flash-attention grid step."""
    io = (block_q + 2 * block_kv + block_q) * head_dim * dtype_bytes
    scratch = (block_q * (2 + head_dim)) * 4
    return io + scratch


def scan_vmem_bytes(
    chunk: int, d_block: int, n_state: int, dtype_bytes: int = 2
) -> int:
    """Working-set estimate for one selective-scan time chunk."""
    io = (
        3 * chunk * d_block + 2 * chunk * n_state + d_block * n_state + d_block
    ) * dtype_bytes
    scratch = d_block * n_state * 4
    return io + scratch
