"""Deterministic synthetic token pipeline with multi-host shard semantics.

Index math is stateless: batch ``step`` for host ``h`` of ``H`` is a pure
function of (seed, step, h, H).  That is what makes elastic restart and
straggler re-balance exact — any host can recompute any other host's shard
after a re-mesh, so no sample is dropped or duplicated (see
runtime/fault_tolerance.py).  A real deployment swaps `_synth_tokens` for a
tokenized corpus reader with the same indexing contract.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import InputShape, ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    host_index: int = 0
    host_count: int = 1
    prefetch: int = 2


def _rng_for(seed: int, step: int, sample: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step, sample]))


def _synth_tokens(seed: int, step: int, sample: int, seq: int, vocab: int) -> np.ndarray:
    """A learnable synthetic language: Markov-ish integer sequences."""
    rng = _rng_for(seed, step, sample)
    start = rng.integers(0, vocab)
    stride = rng.integers(1, 7)
    toks = (start + stride * np.arange(seq + 1)) % vocab
    noise = rng.random(seq + 1) < 0.05
    toks = np.where(noise, rng.integers(0, vocab, seq + 1), toks)
    return toks.astype(np.int32)


class Pipeline:
    """Host-sharded, prefetching batch iterator."""

    def __init__(self, cfg: ModelConfig, shape: InputShape, dc: DataConfig = DataConfig()):
        self.cfg, self.shape, self.dc = cfg, shape, dc
        assert shape.global_batch % dc.host_count == 0, (
            shape.global_batch,
            dc.host_count,
        )
        self.local_batch = shape.global_batch // dc.host_count
        self._q: "queue.Queue" = queue.Queue(maxsize=dc.prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- stateless batch construction -------------------------------------------
    def batch_at(self, step: int, host_index: Optional[int] = None) -> Dict[str, np.ndarray]:
        h = self.dc.host_index if host_index is None else host_index
        seq, vocab = self.shape.seq_len, self.cfg.vocab_size
        base = step * self.shape.global_batch + h * self.local_batch
        toks = np.stack(
            [
                _synth_tokens(self.dc.seed, step, base + i, seq, vocab)
                for i in range(self.local_batch)
            ]
        )
        # labels[t] is the id of position t; the loss shifts internally
        # (logits[:, :-1] vs labels[:, 1:]), so labels == input ids.
        inputs = labels = toks[:, :-1]
        if self.cfg.input_kind == "embeddings":
            # stub modality frontend: deterministic embedding of token ids
            rng = _rng_for(self.dc.seed, 0, 0)
            proj = rng.standard_normal((1, self.cfg.d_model)).astype(np.float32)
            inputs = (inputs[..., None] % 256).astype(np.float32) / 256.0 * proj
        if self.cfg.pos_kind == "mrope":
            pos = np.broadcast_to(
                np.arange(seq, dtype=np.int32)[None, None, :],
                (self.local_batch, 3, seq),
            ).copy()
        else:
            pos = np.broadcast_to(
                np.arange(seq, dtype=np.int32)[None, :], (self.local_batch, seq)
            ).copy()
        return {"inputs": inputs, "labels": labels, "positions": pos}

    # -- prefetching iterator -------------------------------------------------------
    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self.iterate(start_step=0)

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        self._stop.clear()

        def worker():
            step = start_step
            while not self._stop.is_set():
                try:
                    self._q.put(self.batch_at(step), timeout=0.2)
                    step += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        try:
            while True:
                yield self._q.get()
        finally:
            self._stop.set()

    def close(self):
        self._stop.set()
