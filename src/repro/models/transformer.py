"""Model composition: scan-over-periods stacks for all 10 architectures.

A model is a stack of ``n_periods`` copies of a heterogeneous *period* (the
``cfg.layer_plan()``): dense archs have a 1-layer period, Jamba an 8-layer
period (1 attention + 7 Mamba, MoE every other slot).  Parameters for each
period slot are stacked on a leading ``n_periods`` axis and consumed by
``jax.lax.scan`` — keeping the HLO size independent of depth (95-layer
DeepSeek compiles as fast as the 24-layer Granite) and making remat policies
apply uniformly per period.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.kernels.ops import KernelTiles, DEFAULT_TILES
from repro.models import attention, layers, mamba, moe

ShardFn = Callable[[jax.Array, str], jax.Array]


def _identity_shard(x: jax.Array, name: str) -> jax.Array:
    return x


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _mlp_init(cfg: ModelConfig, key) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    o_scale = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    p = {
        "w_up": layers.dense_init(ks[0], (d, f), dt),
        "w_down": layers.dense_init(ks[1], (f, d), dt, scale=o_scale),
    }
    if cfg.act == "swiglu":
        p["w_gate"] = layers.dense_init(ks[2], (d, f), dt)
    return p


def _block_init(cfg: ModelConfig, spec: LayerSpec, key) -> dict:
    ks = jax.random.split(key, 2)
    dt = jnp.dtype(cfg.dtype)
    p: dict = {"norm1": jnp.ones((cfg.d_model,), dt)}
    if spec.mixer == "attn":
        p["attn"] = attention.init(cfg, ks[0])
    else:
        p["mamba"] = mamba.init(cfg, ks[0])
    if spec.mlp != "none":
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        p["mlp"] = moe.init(cfg, ks[1]) if spec.mlp == "moe" else _mlp_init(cfg, ks[1])
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    plan = cfg.layer_plan()
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)

    def init_period(pkey):
        pkeys = jax.random.split(pkey, len(plan))
        return {
            f"b{i}": _block_init(cfg, spec, pkeys[i]) for i, spec in enumerate(plan)
        }

    period_keys = jax.random.split(k_blocks, cfg.n_periods)
    blocks = jax.vmap(init_period)(period_keys)

    params = {
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.input_kind == "tokens":
        params["embed"] = layers.dense_init(k_emb, (cfg.vocab_size, cfg.d_model), dt)
    if not cfg.tie_embeddings:
        params["head"] = layers.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dt)
    return params


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------
def _mlp_forward(p: dict, cfg: ModelConfig, x: jax.Array, shard: ShardFn) -> jax.Array:
    up = x @ p["w_up"]
    up = shard(up, "act_btf")
    if cfg.act == "swiglu":
        gate = shard(x @ p["w_gate"], "act_btf")
        h = jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)
    else:
        h = layers.activate(up.astype(jnp.float32), cfg.act)
    return shard(h.astype(x.dtype) @ p["w_down"], "act_btd")


def _embed(params: dict, cfg: ModelConfig, inputs: jax.Array, positions) -> jax.Array:
    if cfg.input_kind == "tokens":
        h = params["embed"][inputs]  # (B, S, d)
    else:
        h = inputs.astype(jnp.dtype(cfg.dtype))
    if cfg.pos_kind == "sinusoidal":
        pos = positions if positions.ndim == 2 else positions[:, 0]
        h = h + layers.sinusoidal_pe(pos, cfg.d_model).astype(h.dtype)
    return h


def _logits(params: dict, cfg: ModelConfig, h: jax.Array, shard: ShardFn) -> jax.Array:
    h = layers.norm(h, params["final_norm"], cfg.norm)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", h, params["embed"])
    else:
        logits = h @ params["head"]
    return shard(logits, "logits")


def _block_forward(
    bp: dict,
    spec: LayerSpec,
    cfg: ModelConfig,
    h: jax.Array,
    positions,
    tiles: KernelTiles,
    shard: ShardFn,
    moe_dist=None,
) -> jax.Array:
    hn = layers.norm(h, bp["norm1"], cfg.norm)
    if spec.mixer == "attn":
        mixed = attention.forward(
            bp["attn"], cfg, hn, positions, tiles=tiles, shard=shard
        )
    else:
        mixed = mamba.forward(bp["mamba"], cfg, hn, tiles=tiles, shard=shard)
    h = h + mixed
    if spec.mlp != "none":
        hn = layers.norm(h, bp["norm2"], cfg.norm)
        if spec.mlp == "moe":
            out = moe.forward(bp["mlp"], cfg, hn, tiles=tiles, shard=shard,
                              dist=moe_dist)
        else:
            out = _mlp_forward(bp["mlp"], cfg, hn, shard)
        h = h + out
    return shard(h, "act_btd")


_REMAT_POLICIES = {
    "dots": "dots_with_no_batch_dims_saveable",
    "full": "nothing_saveable",
}


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    policy = getattr(jax.checkpoint_policies, _REMAT_POLICIES[remat])
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def forward(
    params: dict,
    cfg: ModelConfig,
    inputs: jax.Array,  # (B,S) tokens or (B,S,d) embeddings
    positions: jax.Array,  # (B,S) or (B,3,S) for mrope
    *,
    tiles: KernelTiles = DEFAULT_TILES,
    shard: ShardFn = _identity_shard,
    remat: str = "none",
    unroll: bool = False,
    moe_dist=None,
) -> jax.Array:
    """``unroll=True`` fully unrolls the period scan: required by the
    dry-run because XLA's ``cost_analysis`` does not fold while-loop trip
    counts into FLOPs (verified; see EXPERIMENTS.md §Dry-run notes)."""
    plan = cfg.layer_plan()
    h = shard(_embed(params, cfg, inputs, positions), "act_btd")

    def period_body(h, period_params):
        for i, spec in enumerate(plan):
            h = _block_forward(
                period_params[f"b{i}"], spec, cfg, h, positions, tiles, shard,
                moe_dist,
            )
        return h, None

    body = _maybe_remat(period_body, remat)
    h, _ = jax.lax.scan(
        body, h, params["blocks"], unroll=cfg.n_periods if unroll else 1
    )
    return _logits(params, cfg, h, shard)


# ---------------------------------------------------------------------------
# Decode (serve_step) with per-slot caches
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, kv_dtype: str = "bf16") -> dict:
    """Stacked (n_periods leading dim) cache matching the block structure."""
    plan = cfg.layer_plan()
    dt = jnp.dtype(cfg.dtype)

    def one_period(_key):
        out = {}
        for i, spec in enumerate(plan):
            if spec.mixer == "attn":
                out[f"b{i}"] = attention.init_cache(cfg, batch, max_len, dt, kv_dtype)
            else:
                out[f"b{i}"] = mamba.init_cache(cfg, batch, dt)
        return out

    caches = jax.vmap(one_period)(jnp.arange(cfg.n_periods))
    return caches


def decode_step(
    params: dict,
    cfg: ModelConfig,
    cache: dict,
    inputs: jax.Array,  # (B,1) token or (B,1,d) embedding
    cur: jax.Array,  # int32 position of the new token: scalar, or (B,) per-row
    *,
    tiles: KernelTiles = DEFAULT_TILES,
    shard: ShardFn = _identity_shard,
    unroll: bool = False,
    moe_dist=None,
) -> Tuple[jax.Array, dict]:
    plan = cfg.layer_plan()
    cur = jnp.asarray(cur, jnp.int32)
    pos = (
        cur[:, None] if cur.ndim == 1
        else jnp.broadcast_to(cur, (inputs.shape[0], 1)).astype(jnp.int32)
    )
    h = shard(_embed(params, cfg, inputs, pos), "act_btd")

    def period_body(h, xs):
        period_params, period_cache = xs
        new_cache = {}
        for i, spec in enumerate(plan):
            bp = period_params[f"b{i}"]
            hn = layers.norm(h, bp["norm1"], cfg.norm)
            if spec.mixer == "attn":
                mixed, new_cache[f"b{i}"] = attention.decode_step(
                    bp["attn"], cfg, period_cache[f"b{i}"], hn, cur, shard=shard
                )
            else:
                mixed, new_cache[f"b{i}"] = mamba.decode_step(
                    bp["mamba"], cfg, period_cache[f"b{i}"], hn, shard=shard
                )
            h = h + mixed
            if spec.mlp != "none":
                hn = layers.norm(h, bp["norm2"], cfg.norm)
                if spec.mlp == "moe":
                    out = moe.forward(bp["mlp"], cfg, hn, tiles=tiles,
                                      shard=shard, dist=moe_dist)
                else:
                    out = _mlp_forward(bp["mlp"], cfg, hn, shard)
                h = h + out
        return h, new_cache

    h, new_cache = jax.lax.scan(
        period_body, h, (params["blocks"], cache),
        unroll=cfg.n_periods if unroll else 1,
    )
    logits = _logits(params, cfg, h[:, -1, :], shard)  # (B, V)
    return logits, new_cache
