"""Losses: next-token cross-entropy with f32 logsumexp, optional z-loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(
    logits: jax.Array,  # (..., V)
    labels: jax.Array,  # (...,) int32
    *,
    z_loss: float = 0.0,
) -> jax.Array:
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    return jnp.mean(nll)


def next_token_loss(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """Shifted LM loss: predict tokens[t+1] from logits[t]."""
    return cross_entropy(logits[:, :-1, :], tokens[:, 1:])
