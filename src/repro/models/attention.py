"""GQA attention block: train/prefill forward + KV-cache decode step."""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.kernels.ops import KernelTiles
from repro.models import layers


def init(cfg: ModelConfig, key) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    o_scale = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    return {
        "wq": layers.dense_init(ks[0], (d, H * hd), dt),
        "wk": layers.dense_init(ks[1], (d, Hkv * hd), dt),
        "wv": layers.dense_init(ks[2], (d, Hkv * hd), dt),
        "wo": layers.dense_init(ks[3], (H * hd, d), dt, scale=o_scale),
    }


def _project(p, x, cfg):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    return q, k, v


def forward(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,
    *,
    tiles: KernelTiles,
    shard: Callable[[jax.Array, str], jax.Array],
    return_kv: bool = False,
):
    B, S, _ = x.shape
    q, k, v = _project(p, x, cfg)
    q = shard(q, "act_bhsd")
    k = shard(k, "act_bkvsd")
    v = shard(v, "act_bkvsd")
    q, k = layers.apply_positions(q, k, cfg, positions)
    o = ops.attention(q, k, v, causal=True, tiles=tiles)  # (B,H,S,hd)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, -1)
    out = shard(o @ p["wo"], "act_btd")
    if return_kv:
        return out, (k, v)
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype, kv_dtype: str = "bf16") -> dict:
    hd = cfg.resolved_head_dim
    shape = (batch, cfg.n_kv_heads, max_len, hd)
    if kv_dtype == "int8":
        # rowwise (per b,h,position) symmetric int8 + f32 scale: halves the
        # decode memory-roofline term (the KV read dominates long-context
        # decode) at ~0.3% attention error
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_s": jnp.ones(shape[:-1] + (1,), jnp.float32),
            "v_s": jnp.ones(shape[:-1] + (1,), jnp.float32),
        }
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _quant_kv(x: jax.Array):
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decode_step(
    p: dict,
    cfg: ModelConfig,
    cache: dict,
    x: jax.Array,  # (B, 1, d)
    cur: jax.Array,  # int32 position of the new token: scalar, or (B,) per-row
    *,
    shard: Callable[[jax.Array, str], jax.Array],
) -> Tuple[jax.Array, dict]:
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    cur = jnp.asarray(cur, jnp.int32)
    per_row = cur.ndim == 1  # continuous batching: each row at its own length

    def _write_at_cur(c, new):
        # KV write at the token position — per-row positions need a
        # per-row dynamic_update_slice (vmapped over the batch axis)
        if per_row:
            return jax.vmap(
                lambda cb, nb, pb: jax.lax.dynamic_update_slice(cb, nb, (0, pb, 0))
            )(c, new, cur)
        return jax.lax.dynamic_update_slice(c, new, (0, 0, cur, 0))

    q, k_new, v_new = _project(p, x, cfg)  # (B,H,1,hd), (B,Hkv,1,hd)
    pos = cur[:, None] if per_row else jnp.full((B, 1), cur, jnp.int32)
    if cfg.pos_kind == "mrope":
        pos = jnp.broadcast_to(pos[:, None, :], (B, 3, 1))
    q, k_new = layers.apply_positions(q, k_new, cfg, pos)
    int8_kv = "k_s" in cache
    new_cache = {}
    if int8_kv:
        kq, ks = _quant_kv(k_new)
        vq, vs = _quant_kv(v_new)
        kc = _write_at_cur(cache["k"], kq)
        vc = _write_at_cur(cache["v"], vq)
        kss = _write_at_cur(cache["k_s"], ks)
        vss = _write_at_cur(cache["v_s"], vs)
        kc = shard(kc, "kv_cache")
        vc = shard(vc, "kv_cache")
        new_cache = {"k": kc, "v": vc, "k_s": kss, "v_s": vss}
        # scales fold into the logits / probs (per b,h,t) — the int8 cache is
        # never dequantized to a full-width tensor
        k, v = kc, vc
        k_scale = kss[..., 0]  # (B, Hkv, S)
        v_scale = vss[..., 0]
    else:
        k = _write_at_cur(cache["k"], k_new.astype(cache["k"].dtype))
        v = _write_at_cur(cache["v"], v_new.astype(cache["v"].dtype))
        k = shard(k, "kv_cache")
        v = shard(v, "kv_cache")
        new_cache = {"k": k, "v": v}
        k_scale = v_scale = None
    # GQA-grouped masked attention over the full cache: query heads reshape
    # to (Hkv, groups) so the cache is NEVER repeated (a materialized
    # jnp.repeat was measured at 4e11 HBM bytes/device on deepseek decode —
    # §Perf). bf16 cache reads, f32 accumulation on the (tiny) logits.
    groups = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, cfg.n_kv_heads, groups, 1, hd)
    kk = k.astype(jnp.bfloat16) if k.dtype == jnp.int8 else k
    vv = v.astype(jnp.bfloat16) if v.dtype == jnp.int8 else v
    logits = jnp.einsum(
        "bkgqd,bktd->bkgqt", qg.astype(jnp.float32), kk.astype(jnp.float32)
    ) * (hd ** -0.5)
    if k_scale is not None:
        logits = logits * k_scale[:, :, None, None, :]
    t = jnp.arange(k.shape[2])
    lim = cur[:, None, None, None, None] if per_row else cur
    mask = t[None, None, None, None, :] <= lim
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    if v_scale is not None:
        probs = probs * v_scale[:, :, None, None, :]
    o = jnp.einsum(
        "bkgqt,bktd->bkgqd", probs, vv.astype(jnp.float32)
    ).astype(x.dtype)
    o = o.reshape(B, cfg.n_heads, 1, hd).transpose(0, 2, 1, 3).reshape(B, 1, -1)
    out = shard(o @ p["wo"], "act_btd")
    return out, new_cache
