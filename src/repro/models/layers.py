"""Shared layer primitives: norms, positional encodings, activations, init."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm(x: jax.Array, w: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    if kind == "rmsnorm":
        return ops.rmsnorm(x, w, eps=eps)
    # layernorm (no bias, like most modern stacks)
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------
def activate(x: jax.Array, kind: str) -> jax.Array:
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":  # nemotron squared-ReLU
        r = jax.nn.relu(x)
        return r * r
    if kind == "silu":
        return jax.nn.silu(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard, partial, and Qwen2-VL multimodal M-RoPE)
# ---------------------------------------------------------------------------
def _rope_freqs(rot_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))


def _apply_rot(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, H, S, R); cos/sin: (B, 1, S, R/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def rope(
    x: jax.Array,  # (B, H, S, D)
    positions: jax.Array,  # (B, S) int32
    theta: float,
    rotary_pct: float = 1.0,
) -> jax.Array:
    D = x.shape[-1]
    rot_dim = int(D * rotary_pct)
    rot_dim -= rot_dim % 2
    freqs = _rope_freqs(rot_dim, theta)  # (rot_dim/2,)
    ang = positions.astype(jnp.float32)[:, None, :, None] * freqs  # (B,1,S,R/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    xr = _apply_rot(xr.astype(jnp.float32), cos, sin).astype(x.dtype)
    return jnp.concatenate([xr, xp], axis=-1) if rot_dim < D else xr


def mrope(
    x: jax.Array,  # (B, H, S, D)
    positions: jax.Array,  # (B, 3, S) int32 — temporal / height / width
    theta: float,
    sections=(16, 24, 24),  # half-dim split (Qwen2-VL: 16+24+24 = 64 = D/2)
) -> jax.Array:
    D = x.shape[-1]
    half = D // 2
    assert sum(sections) == half, (sections, half)
    freqs = _rope_freqs(D, theta)  # (half,)
    # per-component angles, then stitch sections: (B, 3, S, half)
    ang = positions.astype(jnp.float32)[..., None] * freqs[None, None, None, :]
    parts = []
    off = 0
    for comp, sec in enumerate(sections):
        parts.append(ang[:, comp, :, off : off + sec])
        off += sec
    ang = jnp.concatenate(parts, axis=-1)[:, None, :, :]  # (B,1,S,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    return _apply_rot(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def sinusoidal_pe(positions: jax.Array, d_model: int) -> jax.Array:
    """(B, S) -> (B, S, d) classic transformer sinusoid (MusicGen)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,half)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def apply_positions(q, k, cfg, positions):
    """Rotate q/k according to cfg.pos_kind ('rope'/'mrope'); else identity."""
    if cfg.pos_kind == "rope":
        return (
            rope(q, positions, cfg.rope_theta, cfg.rotary_pct),
            rope(k, positions, cfg.rope_theta, cfg.rotary_pct),
        )
    if cfg.pos_kind == "mrope":
        hd = cfg.resolved_head_dim
        secs = _mrope_sections(hd)
        return (
            mrope(q, positions, cfg.rope_theta, secs),
            mrope(k, positions, cfg.rope_theta, secs),
        )
    return q, k


def _mrope_sections(head_dim: int):
    half = head_dim // 2
    if half == 64:
        return (16, 24, 24)  # Qwen2-VL published split
    t = half // 4
    rest = half - t
    h = rest // 2
    return (t, h, rest - h)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float = 0.02) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
