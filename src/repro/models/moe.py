"""Mixture-of-Experts MLP: top-k routing, sort-based capacity dispatch.

Two execution paths:

* **jit path** (``dist=None``): sort tokens by expert globally, scatter into
  a capacity-padded (E, C, d) buffer, grouped GEMM, weighted combine.
  Correct everywhere, but under SPMD the global argsort forces XLA to
  gather the full token array to every device — measured 142 s of
  collectives per step for phi-3.5-MoE on the 256-chip mesh
  (EXPERIMENTS.md §Perf iteration 1).

* **shard_map EP path** (``dist`` given, the beyond-paper optimization):
  routing and sort stay LOCAL to each data shard (argsort over T/dp
  tokens, no collective); every model rank holds E/ep experts and simply
  slices its experts' rows out of the locally-grouped buffer (tokens are
  replicated over the model axis, so no dispatch all-to-all is needed at
  all); the only cross-device traffic is one psum of the (T_local, d)
  partial outputs over the expert axis per layer — the same wire cost as
  a single TP all-reduce.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.kernels.ops import KernelTiles
from repro.models import layers

CAPACITY_FACTOR = 1.25


@dataclass(frozen=True)
class MoEDist:
    """Distribution context for the shard_map expert-parallel path."""

    mesh: Mesh
    model_axis: str = "model"
    data_axes: Tuple[str, ...] = ("data",)
    fsdp: bool = False  # expert weights additionally sharded over data_axes


def capacity(n_tokens: int, cfg: ModelConfig, block: int = 8) -> int:
    """Static per-expert capacity, rounded up to the MoE GEMM tile."""
    c = int(n_tokens * cfg.experts_per_token * CAPACITY_FACTOR / cfg.n_experts)
    c = max(c, block)
    return ((c + block - 1) // block) * block


def init(cfg: ModelConfig, key) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    o_scale = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    p = {
        "router": layers.dense_init(ks[0], (d, E), jnp.float32),
        "w_up": layers.dense_init(ks[1], (E, d, f), dt),
        "w_down": layers.dense_init(ks[2], (E, f, d), dt, scale=o_scale),
    }
    if cfg.act == "swiglu":
        p["w_gate"] = layers.dense_init(ks[3], (E, d, f), dt)
    return p


def forward(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    *,
    tiles: KernelTiles,
    shard: Callable[[jax.Array, str], jax.Array],
    dist: Optional[MoEDist] = None,
) -> jax.Array:
    if dist is not None:
        return _forward_ep_shard_map(p, cfg, x, tiles, dist)
    B, S, d = x.shape
    T = B * S
    k = cfg.experts_per_token
    E = cfg.n_experts
    C = capacity(T, cfg, block=tiles.moe_block_c if T >= tiles.moe_block_c else 8)

    xt = x.reshape(T, d)
    router_logits = (xt.astype(jnp.float32) @ p["router"])  # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)  # (T, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # --- sort-based dispatch ---
    flat_e = topi.reshape(-1)  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(flat_e, length=E)
    seg_start = jnp.cumsum(counts) - counts  # exclusive prefix
    pos = jnp.arange(T * k) - seg_start[se]  # rank within expert
    keep = pos < C
    pos = jnp.where(keep, pos, 0)

    grouped = jnp.zeros((E, C, d), x.dtype)
    src = jnp.where(keep[:, None], xt[st], 0).astype(x.dtype)
    grouped = grouped.at[se, pos].add(src)  # dropped tokens add 0
    grouped = shard(grouped, "moe_ecd")

    # --- expert FFN (grouped GEMMs) ---
    up = ops.moe_gemm(grouped, p["w_up"], tiles=tiles)
    if cfg.act == "swiglu":
        gate = ops.moe_gemm(grouped, p["w_gate"], tiles=tiles)
        hidden = jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)
    else:
        hidden = layers.activate(up.astype(jnp.float32), cfg.act)
    hidden = shard(hidden.astype(x.dtype), "moe_ecf")
    out = ops.moe_gemm(hidden, p["w_down"], tiles=tiles)  # (E, C, d)

    # --- combine ---
    gathered = out[se, pos] * sw[:, None].astype(out.dtype)
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = jnp.zeros((T, d), jnp.float32).at[st].add(gathered.astype(jnp.float32))
    return shard(y.astype(x.dtype).reshape(B, S, d), "act_btd")


# ---------------------------------------------------------------------------
# shard_map expert-parallel path
# ---------------------------------------------------------------------------
def _local_route_group(xt, router, k: int, E: int, C: int, dtype):
    """Local top-k routing + sort-based grouping: (T,d) -> (E, C, d) plus the
    bookkeeping to combine: (sorted_expert, sorted_token, sorted_weight, keep,
    pos)."""
    T = xt.shape[0]
    logits = xt.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    flat_e = topi.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(flat_e, length=E)
    seg_start = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - seg_start[se]
    keep = pos < C
    pos = jnp.where(keep, pos, 0)
    grouped = jnp.zeros((E, C, xt.shape[1]), dtype)
    src = jnp.where(keep[:, None], xt[st], 0).astype(dtype)
    grouped = grouped.at[se, pos].add(src)
    return grouped, (se, st, sw, keep, pos)


def _forward_ep_shard_map(
    p: dict, cfg: ModelConfig, x: jax.Array, tiles: KernelTiles, dist: MoEDist
) -> jax.Array:
    mesh = dist.mesh
    ep = mesh.shape[dist.model_axis]
    E, k = cfg.n_experts, cfg.experts_per_token
    assert E % ep == 0 or ep % E == 0, (E, ep)
    ep = min(ep, E)
    E_loc = E // ep
    B, S, d = x.shape

    w_up, w_down = p["w_up"], p["w_down"]
    w_gate = p.get("w_gate")
    router = p["router"]

    # in_specs mirror sharding/rules.py: experts over model, fsdp over data
    fs = dist.data_axes if dist.fsdp else None
    up_spec = P(dist.model_axis, None, fs)
    down_spec = P(dist.model_axis, fs, None)
    x_spec = P(dist.data_axes, None, None)

    def local_fn(x_loc, router_w, up, down, gate):
        # x_loc: (B/dp, S, d) — replicated over the model axis
        # up/gate: (E_loc, d, f[/dp]), down: (E_loc, f, d[/dp])
        if dist.fsdp:
            up = jax.lax.all_gather(up, dist.data_axes, axis=2, tiled=True)
            down = jax.lax.all_gather(down, dist.data_axes, axis=1, tiled=True)
            if gate is not None:
                gate = jax.lax.all_gather(gate, dist.data_axes, axis=2, tiled=True)
        Bl, Sl, dl = x_loc.shape
        T = Bl * Sl
        C = capacity(T, cfg, block=8)
        xt = x_loc.reshape(T, dl)
        grouped, (se, st, sw, keep, pos) = _local_route_group(
            xt, router_w, k, E, C, x_loc.dtype
        )
        # each model rank owns experts [r*E_loc, (r+1)*E_loc): slice locally —
        # no dispatch collective (tokens replicated over the expert axis)
        r = jax.lax.axis_index(dist.model_axis)
        mine = jax.lax.dynamic_slice_in_dim(grouped, r * E_loc, E_loc, axis=0)

        up_o = ops.moe_gemm(mine, up, tiles=tiles)
        if gate is not None:
            g_o = ops.moe_gemm(mine, gate, tiles=tiles)
            hidden = jax.nn.silu(g_o.astype(jnp.float32)) * up_o.astype(jnp.float32)
        else:
            hidden = layers.activate(up_o.astype(jnp.float32), cfg.act)
        out = ops.moe_gemm(hidden.astype(x_loc.dtype), down, tiles=tiles)
        # scatter back into the FULL (E, C, d) slot layout, zero elsewhere,
        # so the combine below can index it uniformly; psum merges ranks.
        full = jnp.zeros((E, C, dl), out.dtype)
        full = jax.lax.dynamic_update_slice_in_dim(full, out, r * E_loc, axis=0)
        gathered = full[se, pos] * sw[:, None].astype(out.dtype)
        gathered = jnp.where(keep[:, None], gathered, 0)
        y = jnp.zeros((T, dl), jnp.float32).at[st].add(gathered.astype(jnp.float32))
        # combine-AR in bf16: halves the wire bytes of the only EP collective
        # (each token's k experts live on ≤k ranks, so the sum has ≤k terms —
        # bf16 is ample; §Perf iteration 3)
        y = jax.lax.psum(y.astype(jnp.bfloat16), dist.model_axis)
        return y.astype(x_loc.dtype).reshape(Bl, Sl, dl)

    if w_gate is not None:
        fn = jax.shard_map(
            local_fn,
            mesh=mesh,
            in_specs=(x_spec, P(None, None), up_spec, down_spec, up_spec),
            out_specs=x_spec,
            check_vma=False,
        )
        return fn(x, router, w_up, w_down, w_gate)
    fn = jax.shard_map(
        lambda xl, r, u, dn: local_fn(xl, r, u, dn, None),
        mesh=mesh,
        in_specs=(x_spec, P(None, None), up_spec, down_spec),
        out_specs=x_spec,
        check_vma=False,
    )
    return fn(x, router, w_up, w_down)


def aux_loss(router_probs: jax.Array, topi: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style load-balancing loss (optional, used by the trainer)."""
    T = router_probs.shape[0]
    me = jnp.mean(router_probs, axis=0)
    ce = jnp.bincount(topi.reshape(-1), length=n_experts) / topi.size
    return n_experts * jnp.sum(me * ce) * (T / T)
