"""Mamba-1 block: causal conv + selective scan; O(1)-state decode step."""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.kernels.ops import KernelTiles
from repro.models import layers


def init(cfg: ModelConfig, key) -> dict:
    d, Di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, K = cfg.resolved_dt_rank, cfg.conv_width
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    o_scale = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    # S4D-real initialization for A: A[d, n] = -(n + 1)
    a = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (Di, N))
    return {
        "in_proj": layers.dense_init(ks[0], (d, 2 * Di), dt),
        "conv_w": layers.dense_init(ks[1], (K, Di), dt, scale=0.1),
        "conv_b": jnp.zeros((Di,), dt),
        "x_proj": layers.dense_init(ks[2], (Di, dtr + 2 * N), dt),
        "dt_w": layers.dense_init(ks[3], (dtr, Di), dt),
        "dt_b": jnp.log(jnp.expm1(jnp.full((Di,), 0.01, jnp.float32))).astype(dt),
        "A_log": jnp.log(a),
        "Dp": jnp.ones((Di,), jnp.float32),
        "out_proj": layers.dense_init(ks[4], (Di, d), dt, scale=o_scale),
    }


def _conv_causal(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. x: (B, L, Di), w: (K, Di)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):  # K is 4: unrolled adds, fuses cleanly
        y = y + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_inputs(p: dict, xc: jax.Array, cfg: ModelConfig):
    dtr, N = cfg.resolved_dt_rank, cfg.ssm_state
    proj = xc @ p["x_proj"]  # (..., dtr + 2N)
    dt_raw, Bm, Cm = jnp.split(proj, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) @ p["dt_w"].astype(jnp.float32)
        + p["dt_b"].astype(jnp.float32)
    )
    A = -jnp.exp(p["A_log"])
    return dt, A, Bm, Cm


def forward(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    *,
    tiles: KernelTiles,
    shard: Callable[[jax.Array, str], jax.Array],
) -> jax.Array:
    xz = x @ p["in_proj"]  # (B, S, 2*Di)
    xz = shard(xz, "act_bti")
    xi, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_conv_causal(xi, p["conv_w"], p["conv_b"]))
    dt, A, Bm, Cm = _ssm_inputs(p, xc, cfg)
    y = ops.selective_scan(
        xc, dt.astype(xc.dtype), A, Bm, Cm, p["Dp"], tiles=tiles
    )
    y = y * jax.nn.silu(z)
    return shard(y @ p["out_proj"], "act_btd")


def init_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def decode_step(
    p: dict,
    cfg: ModelConfig,
    cache: dict,
    x: jax.Array,  # (B, 1, d)
    *,
    shard: Callable[[jax.Array, str], jax.Array],
) -> Tuple[jax.Array, dict]:
    B = x.shape[0]
    xz = x[:, 0] @ p["in_proj"]  # (B, 2*Di)
    xi, z = jnp.split(xz, 2, axis=-1)
    # conv over (cached K-1 inputs, new input)
    window = jnp.concatenate([cache["conv"], xi[:, None, :]], axis=1)  # (B,K,Di)
    w = p["conv_w"].astype(jnp.float32)
    xc = jnp.sum(window.astype(jnp.float32) * w[None], axis=1) + p["conv_b"].astype(
        jnp.float32
    )
    xc = jax.nn.silu(xc).astype(x.dtype)  # (B, Di)
    dt, A, Bm, Cm = _ssm_inputs(p, xc, cfg)
    new_state, y = ops.selective_scan_step(
        cache["ssm"], xc, dt.astype(xc.dtype), A, Bm, Cm, p["Dp"]
    )
    y = y * jax.nn.silu(z)
    out = shard((y @ p["out_proj"])[:, None, :], "act_btd")
    return out, {"conv": window[:, 1:, :], "ssm": new_state}
