"""SchedulePlan → PartitionSpec rules for params, optimizer state,
activations, inputs, and caches.

Semantics:

* TP is active for a family iff ``param_strategy`` permits TP
  (``tp``/``fsdp_tp``) AND the family flag (``mixer_tp``/``ffn_tp``/
  ``vocab_shard``/``moe_mode``) asks for it.
* FSDP (ZeRO-3) shards every large weight's non-TP dim over the batch axes
  (``data`` or ``pod×data``).
* An axis is only assigned when the dim is divisible by the axis size —
  indivisible cases fall back to replicated on that axis (no silent padding).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.core.space import MeshSpec, SchedulePlan


def _axes_size(mesh: MeshSpec, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.axis(axes)
    n = 1
    for a in axes:
        n *= mesh.axis(a)
    return n


class ShardingRules:
    def __init__(
        self,
        cfg: ModelConfig,
        shape: InputShape,
        plan: SchedulePlan,
        mesh: MeshSpec,
    ):
        self.cfg = cfg
        self.shape = shape
        self.plan = plan
        self.mesh = mesh
        if plan.batch_axes == "pod_data" and mesh.multi_pod:
            self.batch = ("pod", "data")
        else:
            self.batch = ("data",)
        tp_on = plan.param_strategy in ("tp", "fsdp_tp", "tp2d")
        self.tp_mixer = tp_on and plan.mixer_tp
        self.tp_ffn = tp_on and plan.ffn_tp
        self.tp_vocab = tp_on and plan.vocab_shard
        # tp2d: inference-only 2D weight sharding (gather-on-use over the
        # batch axes) — same layout as ZeRO-3, no optimizer state involved
        self.fsdp = plan.param_strategy in ("fsdp", "fsdp_tp", "tp2d")
        self.fsdp_axes: Tuple[str, ...] = self.batch if self.fsdp else ()
        self.moe_mode = plan.moe_mode if tp_on or plan.moe_mode == "dense" else "dense"

    # -- helpers ---------------------------------------------------------------
    def _fit(self, axes, dim: int):
        """axes if dim divides by their product, else None (jit arguments
        demand exact divisibility; odd vocabs like 49155 stay unsharded)."""
        if not axes:
            return None
        if dim % _axes_size(self.mesh, axes) == 0:
            return axes if isinstance(axes, str) or len(axes) > 1 else axes[0]
        return None

    def _weight_spec(self, dims: Tuple[int, ...], tp_dim: Optional[int]) -> P:
        """Spec for one weight (without the stacked period axis)."""
        entries = [None] * len(dims)
        if tp_dim is not None:
            entries[tp_dim] = self._fit("model", dims[tp_dim])
        if self.fsdp_axes:
            # largest remaining divisible dim gets the ZeRO shard
            cand = sorted(
                (i for i in range(len(dims)) if entries[i] is None),
                key=lambda i: -dims[i],
            )
            for i in cand:
                fit = self._fit(self.fsdp_axes, dims[i])
                if fit is not None:
                    entries[i] = fit
                    break
        return P(*entries)

    # -- params ------------------------------------------------------------------
    def param_spec(self, path: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
        stacked = path[0] == "blocks"
        dims = shape[1:] if stacked else shape
        name = path[-1]
        parent = path[-2] if len(path) >= 2 else ""
        tp_dim: Optional[int] = None

        if name in ("norm1", "norm2", "final_norm", "conv_b", "dt_b", "Dp"):
            spec = P(*([None] * len(dims)))
            if name in ("conv_b", "dt_b", "Dp") and self.tp_mixer:
                spec = P(self._fit("model", dims[0]))
        elif name == "embed":
            tp = self._fit("model", dims[0]) if self.tp_vocab else None
            fs = self._fit(self.fsdp_axes, dims[1])
            spec = P(tp, fs)
        elif name == "head":
            tp = self._fit("model", dims[1]) if self.tp_vocab else None
            fs = self._fit(self.fsdp_axes, dims[0])
            spec = P(fs, tp)
        elif parent == "attn":
            if self.tp_mixer:
                tp_dim = 0 if name == "wo" else 1
            spec = self._weight_spec(dims, tp_dim)
        elif parent == "mamba":
            if self.tp_mixer:
                tp_dim = {
                    "in_proj": 1,
                    "conv_w": 1,
                    "x_proj": 0,
                    "dt_w": 1,
                    "A_log": 0,
                    "out_proj": 0,
                }.get(name)
            spec = self._weight_spec(dims, tp_dim)
        elif parent == "mlp" and len(dims) == 3:  # MoE expert weights (E, d, f)
            if self.moe_mode == "ep":
                ep = self._fit("model", dims[0])
                fs = self._fit(self.fsdp_axes, dims[2] if name != "w_down" else dims[1])
                if name == "w_down":
                    spec = P(ep, fs, None)
                else:
                    spec = P(ep, None, fs)
            elif self.moe_mode == "tp":
                tp_dim = 1 if name == "w_down" else 2
                spec = self._weight_spec(dims, tp_dim)
            else:
                spec = self._weight_spec(dims, None)
        elif parent == "mlp":
            if name == "router":
                spec = P(*([None] * len(dims)))
            else:
                if self.tp_ffn:
                    tp_dim = 0 if name == "w_down" else 1
                spec = self._weight_spec(dims, tp_dim)
        else:
            spec = self._weight_spec(dims, None)

        if stacked:
            spec = P(None, *spec)
        return spec

    def param_pspecs(self, params) -> dict:
        def f(path, leaf):
            keys = tuple(
                k.key if hasattr(k, "key") else str(k) for k in path
            )
            return self.param_spec(keys, leaf.shape)

        return jax.tree_util.tree_map_with_path(f, params)

    def _b(self, dim: int):
        """Batch-dim entry: only shard when the dim divides (batch-1 decode
        leaves the data axis for the sequence dim instead)."""
        return self._fit(self.batch, dim)

    # -- activations ----------------------------------------------------------------
    def act_spec(self, name: str, ndim: int, shape: Tuple[int, ...]) -> Optional[P]:
        b = self._b(shape[0])
        plan = self.plan
        if name == "act_btd":
            seq = "model" if plan.seq_shard else None
            return P(b, self._fit(seq, shape[1]) if seq else None, None)
        if name == "act_bhsd":
            h = self._fit("model", shape[1]) if self.tp_mixer else None
            return P(b, h, None, None)
        if name == "act_bkvsd":
            h = self._fit("model", shape[1]) if self.tp_mixer else None
            return P(b, h, None, None)
        if name == "act_btf":
            f = self._fit("model", shape[2]) if self.tp_ffn else None
            return P(b, None, f)
        if name == "act_bti":
            i = self._fit("model", shape[2]) if self.tp_mixer else None
            return P(b, None, i)
        if name == "moe_ecd":
            if self.moe_mode == "ep":
                return P(self._fit("model", shape[0]), None, None)
            return P(None, None, None)
        if name == "moe_ecf":
            if self.moe_mode == "ep":
                return P(self._fit("model", shape[0]), None, None)
            if self.moe_mode == "tp":
                return P(None, None, self._fit("model", shape[2]))
            return P(None, None, None)
        if name == "logits":
            v = self._fit("model", shape[-1]) if self.tp_vocab else None
            return P(*([b] + [None] * (ndim - 2) + [v]))
        if name == "kv_cache":
            h = self._fit("model", shape[1]) if self.tp_mixer else None
            if plan.seq_shard and b is None:
                # batch-1 long-context: the whole mesh shards the sequence
                axes = tuple(self.batch) + ("model",) if h is None else self.batch
                return P(None, h, self._fit(axes, shape[2]), None)
            if h is None and plan.seq_shard:
                return P(b, None, self._fit("model", shape[2]), None)
            return P(b, h, None, None)
        return None

    # -- inputs / cache ---------------------------------------------------------------
    def batch_spec(self, ndim: int, batch_dim: Optional[int] = None) -> P:
        b = self._b(batch_dim if batch_dim is not None else self.shape.global_batch)
        return P(*([b] + [None] * (ndim - 1)))

    def cache_pspecs(self, cache) -> dict:
        """Stacked caches: leading period axis, then (B, ...)."""

        def f(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if name in ("k", "v", "k_s", "v_s"):
                inner = self.act_spec("kv_cache", leaf.ndim - 1, leaf.shape[1:])
                return P(None, *inner)
            # mamba conv/ssm states: shard batch; d_inner over model if TP
            b = self._b(leaf.shape[1])
            if name == "ssm":
                di = self._fit("model", leaf.shape[2]) if self.tp_mixer else None
                return P(None, b, di, None)
            if name == "conv":
                di = self._fit("model", leaf.shape[3]) if self.tp_mixer else None
                return P(None, b, None, di)
            return P(*([None] * leaf.ndim))

        return jax.tree_util.tree_map_with_path(f, cache)


def make_shard_fn(mesh: Mesh, rules: Optional[ShardingRules]):
    """Returns the `shard(x, name)` callback threaded through the models."""
    if rules is None or mesh is None:
        return lambda x, name: x

    def shard(x, name):
        spec = rules.act_spec(name, x.ndim, x.shape)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard
