"""MusicGen-large decoder backbone over EnCodec tokens [arXiv:2306.05284; hf].

Audio: the EnCodec frontend is a STUB — ``input_specs`` supplies precomputed
frame embeddings (the sum of the four codebook embeddings per frame); the LM
head predicts the 2048-way codebook distribution.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,  # MHA (GQA with kv == heads)
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    act="gelu",
    norm="layernorm",
    pos_kind="sinusoidal",
    input_kind="embeddings",
    source="arXiv:2306.05284; hf",
)
