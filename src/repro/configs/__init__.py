"""Architecture registry: ``get_config(arch_id)`` / ``get_shape(name)``.

Arch ids are the assignment's ids (``--arch <id>``).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import SHAPES, InputShape, LayerSpec, ModelConfig

_MODULES = {
    "qwen2-vl-72b": "qwen2_vl_72b",
    "musicgen-large": "musicgen_large",
    "granite-3-2b": "granite_3_2b",
    "nemotron-4-15b": "nemotron_4_15b",
    "stablelm-12b": "stablelm_12b",
    "deepseek-67b": "deepseek_67b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}

ARCH_IDS: List[str] = list(_MODULES)
SHAPE_IDS: List[str] = list(SHAPES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {SHAPE_IDS}")
    return SHAPES[name]


def cells(include_skipped: bool = False):
    """Yield every (arch, shape) dry-run cell.

    ``long_500k`` requires sub-quadratic attention and is skipped for pure
    full-attention archs (DESIGN.md §Arch-applicability) unless
    ``include_skipped``.
    """
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape_name, shape in SHAPES.items():
            if (
                shape_name == "long_500k"
                and not cfg.sub_quadratic
                and not include_skipped
            ):
                continue
            yield cfg, shape


__all__ = [
    "ARCH_IDS",
    "SHAPE_IDS",
    "SHAPES",
    "InputShape",
    "LayerSpec",
    "ModelConfig",
    "cells",
    "get_config",
    "get_shape",
]
