"""Phi-3.5-MoE (42B total, 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct].

16 experts, top-2 routing.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    act="swiglu",
    norm="rmsnorm",
    pos_kind="rope",
    rope_theta=10000.0,
    n_experts=16,
    experts_per_token=2,
    moe_every=1,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
