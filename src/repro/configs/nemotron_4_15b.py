"""Nemotron-4-15B [arXiv:2402.16819; unverified]. Squared-ReLU MLP, GQA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    act="relu2",
    norm="layernorm",
    pos_kind="rope",
    rope_theta=10000.0,
    source="arXiv:2402.16819; unverified",
)
