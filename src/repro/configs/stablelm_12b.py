"""StableLM-2-12B [hf:stabilityai/stablelm-2-12b]. Partial rotary (25%)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    act="swiglu",
    norm="layernorm",
    pos_kind="rope",
    rope_theta=10000.0,
    rotary_pct=0.25,
    source="hf:stabilityai/stablelm-2-1_6b; hf",
)
