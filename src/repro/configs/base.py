"""Architecture + input-shape configuration for the repro framework.

Every assigned architecture is expressed as a frozen :class:`ModelConfig`.
The model zoo (``repro.models``) consumes only this dataclass, so adding an
architecture is purely additive.  ``reduced()`` derives the CPU-smoke-test
variant of the same family (same layer plan, tiny dims).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

# ---------------------------------------------------------------------------
# Layer plan: the repeating period of heterogeneous layers (Jamba interleave,
# MoE frequency).  The model stacks `n_layers / len(plan)` scanned periods.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    """One layer slot inside the repeating period."""

    mixer: str  # "attn" | "mamba"
    mlp: str  # "dense" | "moe" | "none"


@dataclass(frozen=True)
class InputShape:
    """One benchmark cell's input geometry."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int  # query heads; 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    act: str = "swiglu"  # swiglu | gelu | relu2
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    pos_kind: str = "rope"  # rope | mrope | sinusoidal | none
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    input_kind: str = "tokens"  # tokens | embeddings (stub modality frontend)
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1  # apply MoE MLP every k-th layer (1 = all layers)
    # --- SSM (Mamba-1) ---
    ssm_state: int = 0
    d_inner: int = 0  # mamba inner width (expand * d_model)
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    conv_width: int = 4
    attn_every: int = 0  # hybrid: one attention layer per `attn_every` layers
    # --- misc ---
    dtype: str = "bfloat16"
    source: str = ""

    # -- derived ------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def resolved_dt_rank(self) -> int:
        if self.dt_rank:
            return self.dt_rank
        return math.ceil(self.d_model / 16)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_attention_free(self) -> bool:
        return self.n_heads == 0

    @property
    def sub_quadratic(self) -> bool:
        """True when long-context decode shapes (500k) are admissible."""
        return self.family in ("ssm", "hybrid")

    def layer_plan(self) -> List[LayerSpec]:
        """The repeating period of layers."""
        period = 1
        if self.attn_every:
            period = self.attn_every
        if self.is_moe:
            period = _lcm(period, self.moe_every)
        plan = []
        for i in range(period):
            if self.is_attention_free:
                mixer = "mamba"
            elif self.attn_every:
                mixer = "attn" if i == 0 else "mamba"
            else:
                mixer = "attn"
            if self.d_ff == 0:
                mlp = "none"
            elif self.is_moe and (i % self.moe_every == self.moe_every - 1):
                mlp = "moe"
            else:
                mlp = "dense"
            plan.append(LayerSpec(mixer=mixer, mlp=mlp))
        assert self.n_layers % len(plan) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"period={len(plan)}"
        )
        return plan

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.layer_plan())

    # -- parameter accounting (used by the cost model and 6ND MFU) ----------
    def _mixer_params(self, spec: LayerSpec) -> int:
        d = self.d_model
        if spec.mixer == "attn":
            hd = self.resolved_head_dim
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            return q + kv + o
        # mamba-1
        di, ds, dtr = self.d_inner, self.ssm_state, self.resolved_dt_rank
        in_proj = d * 2 * di
        conv = self.conv_width * di + di
        x_proj = di * (dtr + 2 * ds)
        dt_proj = dtr * di + di
        a_d = di * ds + di
        out_proj = di * d
        return in_proj + conv + x_proj + dt_proj + a_d + out_proj

    def _mlp_params(self, spec: LayerSpec) -> Tuple[int, int]:
        """(total, active) parameters of the MLP slot."""
        d = self.d_model
        if spec.mlp == "none":
            return 0, 0
        mats = 3 if self.act == "swiglu" else 2
        one = mats * d * self.d_ff
        if spec.mlp == "moe":
            router = d * self.n_experts
            return one * self.n_experts + router, one * self.experts_per_token + router
        return one, one

    def param_count(self) -> int:
        plan = self.layer_plan()
        per_period = sum(
            self._mixer_params(s) + self._mlp_params(s)[0] + 2 * self.d_model
            for s in plan
        )
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        return per_period * self.n_periods + emb + head + self.d_model

    def active_param_count(self) -> int:
        plan = self.layer_plan()
        per_period = sum(
            self._mixer_params(s) + self._mlp_params(s)[1] + 2 * self.d_model
            for s in plan
        )
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        return per_period * self.n_periods + emb + head + self.d_model

    # -- smoke-test variant ---------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        plan_len = len(self.layer_plan())
        n_layers = plan_len * (2 if plan_len <= 4 else 1)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=64,
            n_heads=0 if self.is_attention_free else 4,
            n_kv_heads=0 if self.is_attention_free else min(self.n_kv_heads, 2),
            head_dim=0 if self.is_attention_free else 16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            d_inner=128 if self.d_inner else 0,
            dt_rank=8 if self.is_ssm else 0,
            dtype="float32",
        )


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)
