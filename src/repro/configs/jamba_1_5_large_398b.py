"""Jamba-1.5-Large (398B total) [arXiv:2403.19887; hf].

Hybrid: 1 attention layer per 8 (1:7 attn:mamba interleave), MoE (16 experts,
top-2) on every other layer.  72 layers = 9 periods of 8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    act="swiglu",
    norm="rmsnorm",
    pos_kind="none",  # Jamba uses no explicit positional encoding
    n_experts=16,
    experts_per_token=2,
    moe_every=2,
    ssm_state=16,
    d_inner=16384,  # expand=2
    conv_width=4,
    attn_every=8,
    source="arXiv:2403.19887; hf",
)
