"""IBM Granite-3.0-1B-A400M MoE base [hf:ibm-granite/granite-3.0-1b-a400m-base].

32 experts, top-8 routing, per-expert d_ff=512.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    act="swiglu",
    norm="rmsnorm",
    pos_kind="rope",
    rope_theta=10000.0,
    tie_embeddings=True,
    n_experts=32,
    experts_per_token=8,
    moe_every=1,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
