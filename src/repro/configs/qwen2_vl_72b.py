"""Qwen2-VL-72B backbone [arXiv:2409.12191; hf].

VLM: the vision frontend is a STUB — ``input_specs`` supplies precomputed
patch/text embeddings (batch, seq, d_model) plus 3-component M-RoPE position
ids (temporal, height, width).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    act="swiglu",
    norm="rmsnorm",
    pos_kind="mrope",
    rope_theta=1_000_000.0,
    input_kind="embeddings",
    source="arXiv:2409.12191; hf",
)
