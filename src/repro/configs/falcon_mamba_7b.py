"""Falcon-Mamba-7B (pure Mamba-1, attention-free) [arXiv:2410.05355; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,  # Mamba-1 block has no separate MLP
    vocab_size=65024,
    norm="rmsnorm",
    pos_kind="none",
    ssm_state=16,
    d_inner=8192,  # expand=2
    conv_width=4,
    source="arXiv:2410.05355; unverified",
)
