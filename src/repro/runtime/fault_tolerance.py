"""Fault tolerance for 1000+-node fleets: heartbeats, elastic re-mesh,
straggler mitigation.

All policies are host-side control-plane logic (pure Python, no jax device
state), so they are unit-testable in this container and identical on a real
fleet where the heartbeat source is the pod coordinator:

* ``HeartbeatMonitor`` — tracks per-host liveness with a deadline; a host
  that misses ``timeout`` is declared dead.
* ``rebalance`` — rendezvous-hashing assignment of data shards to the
  surviving hosts: minimal movement (only the dead host's shards move), and
  with the stateless pipeline index math every host can recompute any shard.
* ``StragglerPolicy`` — EWMA of per-host step times; hosts slower than
  ``threshold ×`` the fleet median get flagged; repeated offenders are
  evicted (treated as failed → re-mesh), which is the standard mitigation
  when synchronous collectives make one slow host gate the fleet.
* ``ElasticPlan`` — given survivors, picks the largest feasible mesh
  (data axis shrinks; model axis preserved) and the checkpoint step to
  restart from.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Heartbeats
# ---------------------------------------------------------------------------
class HeartbeatMonitor:
    def __init__(self, hosts: Sequence[str], timeout: float = 60.0, clock=time.monotonic):
        self.timeout = timeout
        self.clock = clock
        now = clock()
        self.last_seen: Dict[str, float] = {h: now for h in hosts}

    def beat(self, host: str, at: Optional[float] = None):
        self.last_seen[host] = self.clock() if at is None else at

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return sorted(h for h, t in self.last_seen.items() if now - t > self.timeout)

    def alive_hosts(self) -> List[str]:
        dead = set(self.dead_hosts())
        return sorted(h for h in self.last_seen if h not in dead)


# ---------------------------------------------------------------------------
# Rendezvous-hash shard assignment (minimal movement on failure)
# ---------------------------------------------------------------------------
def _score(host: str, shard: int) -> int:
    return int.from_bytes(
        hashlib.blake2b(f"{host}:{shard}".encode(), digest_size=8).digest(), "big"
    )


def rebalance(hosts: Sequence[str], n_shards: int) -> Dict[int, str]:
    """shard -> host via rendezvous hashing."""
    assert hosts, "no surviving hosts"
    return {
        s: max(hosts, key=lambda h: _score(h, s)) for s in range(n_shards)
    }


# ---------------------------------------------------------------------------
# Straggler detection
# ---------------------------------------------------------------------------
@dataclass
class StragglerPolicy:
    threshold: float = 1.5  # × median EWMA step time
    ewma: float = 0.9
    evict_after: int = 3  # consecutive flags
    _times: Dict[str, float] = field(default_factory=dict)
    _flags: Dict[str, int] = field(default_factory=dict)

    def observe(self, host: str, step_time: float):
        prev = self._times.get(host)
        self._times[host] = (
            step_time if prev is None else self.ewma * prev + (1 - self.ewma) * step_time
        )

    def median(self) -> float:
        ts = sorted(self._times.values())
        if not ts:
            return 0.0
        return ts[len(ts) // 2]

    def stragglers(self) -> List[str]:
        med = self.median()
        if med <= 0:
            return []
        out = []
        for h, t in self._times.items():
            if t > self.threshold * med:
                self._flags[h] = self._flags.get(h, 0) + 1
                out.append(h)
            else:
                self._flags[h] = 0
        return sorted(out)

    def evictions(self) -> List[str]:
        self.stragglers()
        return sorted(h for h, n in self._flags.items() if n >= self.evict_after)


# ---------------------------------------------------------------------------
# Elastic re-mesh plan
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ElasticPlan:
    hosts: Tuple[str, ...]
    data_parallel: int  # new data-axis size
    restart_step: int
    shard_map: Tuple[Tuple[int, str], ...]  # data shard -> host


def plan_restart(
    alive: Sequence[str],
    chips_per_host: int,
    model_parallel: int,
    latest_ckpt_step: int,
    global_batch: int,
) -> ElasticPlan:
    """Shrink the data axis to the largest size the survivors support.

    The model axis is preserved (weights shard layout unchanged → restore is
    a pure re-placement); the data axis must divide the global batch.
    """
    total_chips = len(alive) * chips_per_host
    assert total_chips % model_parallel == 0, (total_chips, model_parallel)
    dp = total_chips // model_parallel
    while dp > 1 and global_batch % dp != 0:
        dp -= 1
    assignment = rebalance(list(alive), dp)
    return ElasticPlan(
        hosts=tuple(sorted(alive)),
        data_parallel=dp,
        restart_step=latest_ckpt_step,
        shard_map=tuple(sorted(assignment.items())),
    )
