"""Sharded checkpointing: save/restore with mesh metadata, async writes,
elastic re-shard on restore.

Format: one ``.npz`` of flattened leaves + a msgpack sidecar with the
treedef paths, dtypes, mesh shape, step, and data-pipeline cursor.  Restore
never requires the saving mesh: arrays are loaded host-side and re-placed
under the *current* mesh's NamedShardings (elastic scaling = restore on a
different mesh).  On a real multi-host pod each host writes its addressable
shards (`_local_slices`); in this container that degenerates to full arrays.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(template, flat: Dict[str, np.ndarray]):
    def f(path, leaf):
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        return jnp.asarray(arr, dtype=leaf.dtype)

    return jax.tree_util.tree_map_with_path(f, template)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # -- save --------------------------------------------------------------------
    def save(
        self,
        step: int,
        params,
        opt_state=None,
        extra: Optional[dict] = None,
        blocking: bool = True,
    ) -> str:
        state = {"params": params}
        if opt_state is not None:
            state["opt"] = opt_state
        flat = _flatten_with_paths(state)
        meta = {
            "step": step,
            "extra": extra or {},
            "n_devices": jax.device_count(),
        }

        path = os.path.join(self.dir, f"step_{step:08d}")

        def _write():
            tmp = path + ".tmp.npz"
            np.savez(tmp, **flat)
            with open(path + ".meta", "wb") as f:
                f.write(msgpack.packb(meta))
            os.replace(tmp, path + ".npz")
            self._gc()

        if blocking:
            _write()
        else:
            self.wait()  # at most one async save in flight
            self._async_thread = threading.Thread(target=_write, daemon=True)
            self._async_thread.start()
        return path

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _gc(self):
        ckpts = self.list_steps()
        for step in ckpts[: -self.keep]:
            for ext in (".npz", ".meta"):
                try:
                    os.remove(os.path.join(self.dir, f"step_{step:08d}{ext}"))
                except FileNotFoundError:
                    pass

    # -- restore ---------------------------------------------------------------------
    def list_steps(self):
        steps = []
        for f in os.listdir(self.dir):
            if f.endswith(".npz") and f.startswith("step_"):
                steps.append(int(f[5:-4]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        template_params,
        template_opt=None,
        step: Optional[int] = None,
        shardings=None,
    ) -> Tuple[Any, Any, int, dict]:
        """Restore onto the CURRENT mesh (elastic: saving mesh irrelevant).

        ``shardings``: optional pytree of NamedShardings to place params with.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        base = os.path.join(self.dir, f"step_{step:08d}")
        with open(base + ".meta", "rb") as f:
            meta = msgpack.unpackb(f.read())
        flat = dict(np.load(base + ".npz"))
        template = {"params": template_params}
        if template_opt is not None:
            template["opt"] = template_opt
        state = _unflatten_like(template, flat)
        params = state["params"]
        if shardings is not None:
            params = jax.device_put(params, shardings)
        opt = state.get("opt")
        return params, opt, int(meta["step"]), meta.get("extra", {})
