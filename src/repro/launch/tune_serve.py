"""Tuner-as-a-service CLI: daemon and client ends of one socket.

Serve (long-lived; one worker pool + one fleet + one plan store for every
request it ever answers):

    python -m repro.launch.tune_serve serve --store /var/tune-store \
        --socket /tmp/tuner.sock --parallel

Client (per request; returns the tuned plan as JSON on stdout):

    python -m repro.launch.tune_serve tune --socket /tmp/tuner.sock \
        --arch granite-3-2b --shape train_4k --algo mcts_1s
    python -m repro.launch.tune_serve stats --socket /tmp/tuner.sock
    python -m repro.launch.tune_serve shutdown --socket /tmp/tuner.sock
"""
from __future__ import annotations

import argparse
import json
import socket


class TuneClient:
    """One JSON-lines request/response per call over the daemon socket."""

    def __init__(self, socket_path: str, timeout: float = 600.0):
        self.socket_path = socket_path
        self.timeout = timeout

    def call(self, msg: dict) -> dict:
        with socket.socket(socket.AF_UNIX, socket.SOCK_STREAM) as s:
            s.settimeout(self.timeout)
            s.connect(self.socket_path)
            with s.makefile("rwb") as f:
                f.write((json.dumps(msg) + "\n").encode())
                f.flush()
                line = f.readline()
        if not line:
            raise ConnectionError("daemon closed the connection")
        return json.loads(line)

    def tune(self, arch: str, shape: str, **settings) -> dict:
        return self.call({"op": "tune", "arch": arch, "shape": shape,
                          **settings})

    def stats(self) -> dict:
        return self.call({"op": "stats"})

    def ping(self) -> dict:
        return self.call({"op": "ping"})

    def shutdown(self) -> dict:
        return self.call({"op": "shutdown"})


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    sv = sub.add_parser("serve", help="run the daemon")
    sv.add_argument("--store", required=True, help="plan-store root dir")
    sv.add_argument("--socket", required=True, help="unix socket path")
    sv.add_argument("--parallel", action="store_true",
                    help="share one pinned worker pool across runs")
    sv.add_argument("--workers", type=int, default=None)
    sv.add_argument("--measure", default="none",
                    choices=["none", "stub", "real"],
                    help="shared measurement fleet for *real* algos "
                         "(stub = deterministic XLA-free target)")
    sv.add_argument("--max-requests", type=int, default=None,
                    help="exit after N tune requests (tests/CI smoke)")
    sv.add_argument("--read-timeout", type=float, default=30.0,
                    help="per-connection socket read timeout in seconds "
                         "(a silent client is closed, not waited on)")
    sv.add_argument("--queue-size", type=int, default=16,
                    help="bounded tune-request queue; a full queue answers "
                         "'overloaded' with a retry_after_s hint")
    sv.add_argument("--checkpoint-every", type=int, default=4,
                    help="persist a resumable search checkpoint every K "
                         "decision rounds (0 disables crash resume)")
    sv.add_argument("--deadline-s", type=float, default=None,
                    help="default per-request search deadline; requests "
                         "override with their own deadline_s")
    sv.add_argument("--degrade-after", type=int, default=5,
                    help="cumulative pool worker restarts before the "
                         "watchdog degrades to the sequential engine")
    sv.add_argument("--round-delay", type=float, default=0.0,
                    help=argparse.SUPPRESS)  # fault-injection: slow rounds
    sv.add_argument("--no-recover", action="store_true",
                    help="skip write-ahead-journal replay on startup")

    def add_request_args(p):
        p.add_argument("--socket", required=True)
        p.add_argument("--arch", required=True)
        p.add_argument("--shape", required=True)
        p.add_argument("--algo", default="mcts_30s")
        p.add_argument("--mesh", default="single", choices=["single", "multi"])
        p.add_argument("--seed", type=int, default=0)
        p.add_argument("--budget-s", type=float, default=None)
        p.add_argument("--n-standard", type=int, default=15)
        p.add_argument("--n-greedy", type=int, default=1)
        p.add_argument("--noise-sigma", type=float, default=0.0)
        p.add_argument("--cost", default="analytic",
                       choices=["analytic", "learned", "hybrid"])
        p.add_argument("--deadline-s", type=float, default=None,
                       help="interrupt the search at the next round "
                            "boundary after this many seconds; the "
                            "response is best-so-far with interrupted "
                            "provenance, and a repeat request resumes "
                            "from the checkpoint")

    tn = sub.add_parser("tune", help="submit one tuning request")
    add_request_args(tn)

    st = sub.add_parser("stats", help="daemon counters")
    st.add_argument("--socket", required=True)
    sd = sub.add_parser("shutdown", help="stop the daemon")
    sd.add_argument("--socket", required=True)

    args = ap.parse_args(argv)

    if args.cmd == "serve":
        from repro.service.daemon import TunerService, serve_forever

        service = TunerService(
            args.store, parallel=args.parallel, n_workers=args.workers,
            measure=args.measure,
            checkpoint_every=args.checkpoint_every,
            deadline_s=args.deadline_s,
            round_delay_s=args.round_delay,
            degrade_after=args.degrade_after,
        )
        served = serve_forever(service, args.socket,
                               max_requests=args.max_requests,
                               read_timeout_s=args.read_timeout,
                               queue_size=args.queue_size,
                               recover=not args.no_recover)
        print(f"[tune_serve] served {served} request(s)")
        return 0

    client = TuneClient(args.socket)
    if args.cmd == "stats":
        out = client.stats()
    elif args.cmd == "shutdown":
        out = client.shutdown()
    else:
        settings = dict(
            algo=args.algo, mesh=args.mesh,
            seed=args.seed, time_budget_s=args.budget_s,
            n_standard=args.n_standard, n_greedy=args.n_greedy,
            noise_sigma=args.noise_sigma, cost=args.cost,
        )
        if args.deadline_s is not None:
            settings["deadline_s"] = args.deadline_s
        out = client.tune(args.arch, args.shape, **settings)
    print(json.dumps(out, indent=1, default=str))
    return 0 if out.get("ok") else 1


if __name__ == "__main__":
    raise SystemExit(main())
