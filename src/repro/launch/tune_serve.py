"""Tuner-as-a-service CLI: daemon and client ends of one socket.

Serve (long-lived; one worker pool + one fleet + one plan store for every
request it ever answers):

    python -m repro.launch.tune_serve serve --store /var/tune-store \
        --socket /tmp/tuner.sock --parallel

Client (per request; returns the tuned plan as JSON on stdout):

    python -m repro.launch.tune_serve tune --socket /tmp/tuner.sock \
        --arch granite-3-2b --shape train_4k --algo mcts_1s
    python -m repro.launch.tune_serve stats --socket /tmp/tuner.sock
    python -m repro.launch.tune_serve shutdown --socket /tmp/tuner.sock
"""
from __future__ import annotations

import argparse
import json
import socket


class TuneClient:
    """One JSON-lines request/response per call over the daemon socket."""

    def __init__(self, socket_path: str, timeout: float = 600.0):
        self.socket_path = socket_path
        self.timeout = timeout

    def call(self, msg: dict) -> dict:
        with socket.socket(socket.AF_UNIX, socket.SOCK_STREAM) as s:
            s.settimeout(self.timeout)
            s.connect(self.socket_path)
            with s.makefile("rwb") as f:
                f.write((json.dumps(msg) + "\n").encode())
                f.flush()
                line = f.readline()
        if not line:
            raise ConnectionError("daemon closed the connection")
        return json.loads(line)

    def tune(self, arch: str, shape: str, **settings) -> dict:
        return self.call({"op": "tune", "arch": arch, "shape": shape,
                          **settings})

    def stats(self) -> dict:
        return self.call({"op": "stats"})

    def ping(self) -> dict:
        return self.call({"op": "ping"})

    def shutdown(self) -> dict:
        return self.call({"op": "shutdown"})


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    sv = sub.add_parser("serve", help="run the daemon")
    sv.add_argument("--store", required=True, help="plan-store root dir")
    sv.add_argument("--socket", required=True, help="unix socket path")
    sv.add_argument("--parallel", action="store_true",
                    help="share one pinned worker pool across runs")
    sv.add_argument("--workers", type=int, default=None)
    sv.add_argument("--measure", default="none",
                    choices=["none", "stub", "real"],
                    help="shared measurement fleet for *real* algos "
                         "(stub = deterministic XLA-free target)")
    sv.add_argument("--max-requests", type=int, default=None,
                    help="exit after N tune requests (tests/CI smoke)")

    def add_request_args(p):
        p.add_argument("--socket", required=True)
        p.add_argument("--arch", required=True)
        p.add_argument("--shape", required=True)
        p.add_argument("--algo", default="mcts_30s")
        p.add_argument("--mesh", default="single", choices=["single", "multi"])
        p.add_argument("--seed", type=int, default=0)
        p.add_argument("--budget-s", type=float, default=None)
        p.add_argument("--n-standard", type=int, default=15)
        p.add_argument("--n-greedy", type=int, default=1)
        p.add_argument("--noise-sigma", type=float, default=0.0)
        p.add_argument("--cost", default="analytic",
                       choices=["analytic", "learned", "hybrid"])

    tn = sub.add_parser("tune", help="submit one tuning request")
    add_request_args(tn)

    st = sub.add_parser("stats", help="daemon counters")
    st.add_argument("--socket", required=True)
    sd = sub.add_parser("shutdown", help="stop the daemon")
    sd.add_argument("--socket", required=True)

    args = ap.parse_args(argv)

    if args.cmd == "serve":
        from repro.service.daemon import TunerService, serve_forever

        service = TunerService(
            args.store, parallel=args.parallel, n_workers=args.workers,
            measure=args.measure,
        )
        served = serve_forever(service, args.socket,
                               max_requests=args.max_requests)
        print(f"[tune_serve] served {served} request(s)")
        return 0

    client = TuneClient(args.socket)
    if args.cmd == "stats":
        out = client.stats()
    elif args.cmd == "shutdown":
        out = client.shutdown()
    else:
        out = client.tune(
            args.arch, args.shape, algo=args.algo, mesh=args.mesh,
            seed=args.seed, time_budget_s=args.budget_s,
            n_standard=args.n_standard, n_greedy=args.n_greedy,
            noise_sigma=args.noise_sigma, cost=args.cost,
        )
    print(json.dumps(out, indent=1, default=str))
    return 0 if out.get("ok") else 1


if __name__ == "__main__":
    raise SystemExit(main())
