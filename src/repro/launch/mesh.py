"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never module-level device state):
importing this module must not initialize jax's device backend.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.space import MULTI_POD, SINGLE_POD, MeshSpec


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devices)} "
            "(launch via repro.launch.dryrun, which forces 512 host devices)"
        )
    import numpy as np

    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def mesh_spec(multi_pod: bool = False) -> MeshSpec:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_mesh_from_spec(spec: MeshSpec) -> jax.sharding.Mesh:
    import numpy as np

    n = spec.size
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, found {len(devices)}")
    dev_array = np.asarray(devices[:n]).reshape(spec.shape)
    return jax.sharding.Mesh(dev_array, spec.names)
