"""Drop-in CLI replacement for ``repro.launch.dryrun`` that never touches
XLA: same flags, same ``--json-out`` contract, but the record comes from
the analytic stub (``repro.core.measure_stub``).  Tests monkeypatch
``repro.core.measure.DRYRUN_MODULE`` to this module to exercise the real
subprocess path — tmp-file handling, timeout, exit codes — without a
compile.

``REPRO_STUB_SLEEP_S`` (env) sleeps before writing the record, so a test
can force ``subprocess.TimeoutExpired`` deterministically.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--plan-json", default=None)
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--devices", type=int, default=None)
    args = ap.parse_args(argv)

    sleep_s = float(os.environ.get("REPRO_STUB_SLEEP_S", "0"))
    if sleep_s:
        time.sleep(sleep_s)

    from repro.core.measure_stub import stub_measure

    rec = stub_measure(
        {
            "arch": args.arch,
            "shape": args.shape,
            "mesh": args.mesh,
            "plan": json.loads(args.plan_json) if args.plan_json else None,
            "devices": args.devices,
        }
    )
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rec, f, indent=1)
    else:
        json.dump(rec, sys.stdout, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
