"""Dry-run implementation: AOT-lower + compile one (arch × shape × mesh)
cell and extract the roofline record.

Import ONLY from repro.launch.dryrun (which sets XLA_FLAGS first) or from a
process that already forced the host device count.
"""
from __future__ import annotations

import json
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_shape
from repro.configs.base import InputShape, ModelConfig
from repro.core import hlo_analysis
from repro.core import measure as M
from repro.core.cost_model import HW, AnalyticCostModel
from repro.core.space import MULTI_POD, SINGLE_POD, SchedulePlan, ScheduleSpace
from repro.launch.mesh import make_mesh_from_spec, mesh_spec
from repro.models import transformer
from repro.sharding.rules import ShardingRules
from repro.training import optimizer as optim
from repro.training.train_step import (
    make_positions,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    shardings_for_train,
)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        tok_shape = (B, 1) if cfg.input_kind == "tokens" else (B, 1, cfg.d_model)
        tok_dtype = jnp.int32 if cfg.input_kind == "tokens" else jnp.dtype(cfg.dtype)
        return {
            "inputs": sd(tok_shape, tok_dtype),
            "cur": sd((), jnp.int32),
        }
    if cfg.input_kind == "tokens":
        inputs = sd((B, S), jnp.int32)
    else:
        inputs = sd((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    pos_shape = (B, 3, S) if cfg.pos_kind == "mrope" else (B, S)
    specs = {
        "inputs": inputs,
        "positions": sd(pos_shape, jnp.int32),
    }
    if shape.kind == "train":
        specs["labels"] = sd((B, S), jnp.int32)
    return specs


def _abstract(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def default_plan(cfg, shape, mspec) -> SchedulePlan:
    space = ScheduleSpace(cfg, shape, mspec)
    return space.plan_from_actions(space.default_actions())


def evaluate_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str = "single",
    plan: Optional[SchedulePlan] = None,
    verbose: bool = True,
) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    multi = mesh_kind == "multi"
    mspec = mesh_spec(multi)
    mesh = make_mesh_from_spec(mspec)
    if plan is None:
        plan = default_plan(cfg, shape, mspec)

    t0 = time.perf_counter()
    if shape.kind == "train":
        lowered = _lower_train(cfg, shape, plan, mesh, mspec)
    elif shape.kind == "prefill":
        lowered = _lower_prefill(cfg, shape, plan, mesh, mspec)
    else:
        lowered = _lower_decode(cfg, shape, plan, mesh, mspec)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    record = _extract(compiled, cfg, shape, plan, mspec)
    record.update(
        arch=arch,
        shape=shape_name,
        mesh=mesh_kind,
        plan=plan.to_dict(),
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
    )
    if verbose:
        ma = record["memory_analysis"]
        print(
            f"[dryrun] {arch} × {shape_name} × {mesh_kind}: "
            f"compile ok in {t_compile:.1f}s | "
            f"args/device={ma['argument_size_in_bytes']/2**30:.2f} GiB "
            f"temps/device={ma['temp_size_in_bytes']/2**30:.2f} GiB | "
            f"flops/device={record['flops_per_device']:.3e} | "
            f"coll bytes/device={record['coll_bytes_per_chip']:.3e}"
        )
        print(
            f"[dryrun]   terms: compute={record['compute_s']*1e3:.2f} ms "
            f"memory={record['memory_s']*1e3:.2f} ms "
            f"collective={record['collective_s']*1e3:.2f} ms "
            f"-> step={record['step_s']*1e3:.2f} ms "
            f"(dominant: {record['dominant']}, MFU={record['mfu']:.3f})"
        )
    return record


# ---------------------------------------------------------------------------
def _lower_train(cfg, shape, plan, mesh, mspec):
    oc = optim.OptimizerConfig(moment_dtype=plan.opt_dtype)
    params = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    opt_state = jax.eval_shape(lambda: optim.init_opt_state(params, oc))
    pshard, oshard, bshard, rules = shardings_for_train(
        cfg, shape, plan, mesh, mspec, params, opt_state
    )
    step = make_train_step(cfg, shape, plan, oc, mesh, mspec)
    batch = input_specs(cfg, shape)
    bshard = {k: bshard[k] for k in batch}
    jstep = jax.jit(
        step,
        in_shardings=(pshard, oshard, bshard),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1),
    )
    return jstep.lower(params, opt_state, batch)


def _lower_prefill(cfg, shape, plan, mesh, mspec):
    from jax.sharding import NamedSharding

    params = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    rules = ShardingRules(cfg, shape, plan, mspec)
    pspecs = rules.param_pspecs(params)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    batch = input_specs(cfg, shape)
    bshard = {
        "inputs": NamedSharding(mesh, rules.batch_spec(batch["inputs"].ndim)),
        "positions": NamedSharding(mesh, rules.batch_spec(batch["positions"].ndim)),
    }
    step = make_prefill_step(cfg, shape, plan, mesh, mspec)
    jstep = jax.jit(step, in_shardings=(ns(pspecs), bshard))
    return jstep.lower(params, batch)


def _lower_decode(cfg, shape, plan, mesh, mspec):
    from jax.sharding import NamedSharding

    params = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    cache = jax.eval_shape(
        lambda: transformer.init_cache(
            cfg, shape.global_batch, shape.seq_len, plan.kv_dtype
        )
    )
    rules = ShardingRules(cfg, shape, plan, mspec)
    pspecs = rules.param_pspecs(params)
    cspecs = rules.cache_pspecs(cache)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    specs = input_specs(cfg, shape)
    ishard = NamedSharding(mesh, rules.batch_spec(specs["inputs"].ndim))
    step = make_serve_step(cfg, shape, plan, mesh, mspec)
    jstep = jax.jit(
        step,
        in_shardings=(ns(pspecs), ns(cspecs), ishard, NamedSharding(mesh, jax.sharding.PartitionSpec())),
        out_shardings=(None, ns(cspecs)),
        donate_argnums=(1,),
    )
    return jstep.lower(params, cache, specs["inputs"], specs["cur"])


# ---------------------------------------------------------------------------
def _extract(compiled, cfg, shape, plan, mspec) -> dict:
    chips = mspec.size
    ca = compiled.cost_analysis() or {}
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    ma = compiled.memory_analysis()
    mem = {
        k: int(getattr(ma, k, 0))
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        )
    } if ma is not None else {}
    hlo = compiled.as_text()
    # trip-count-correct analysis (XLA cost_analysis counts loop bodies once;
    # see core/hlo_analysis.py)
    ha = hlo_analysis.analyze(hlo)
    coll = ha["coll"]
    counts = ha["counts"]
    wire = float(ha["coll_wire"])
    coll_bytes = float(sum(coll.values()))
    flops_dev_corrected = max(ha["dot_flops"], flops_dev)
    bytes_dev_corrected = max(ha["bytes"], bytes_dev)

    flops_total = flops_dev_corrected * chips
    bytes_total = bytes_dev_corrected * chips
    terms = M.combine_terms(flops_total, bytes_total, coll_bytes, chips, plan.overlap)
    n_active = cfg.active_param_count()
    model_flops = (
        6.0 * n_active * shape.tokens
        if shape.kind == "train"
        else 2.0 * n_active * shape.tokens
    )
    mfu = model_flops / (terms["step_s"] * chips * HW.peak_flops)
    useful = model_flops / flops_total if flops_total else 0.0
    bytes_per_device = (
        mem.get("argument_size_in_bytes", 0)
        + mem.get("temp_size_in_bytes", 0)
        + mem.get("output_size_in_bytes", 0)
        - mem.get("alias_size_in_bytes", 0)
    )
    return {
        **terms,
        "dominant": max(
            ("compute", "memory", "collective"),
            key=lambda k: terms[k + "_s"],
        ),
        "flops_per_device": flops_dev_corrected,
        "flops_per_device_xla_raw": flops_dev,
        "flops_total": flops_total,
        "hbm_bytes_total": bytes_total,
        "coll_bytes_per_chip": coll_bytes,
        "coll_wire_bytes_per_chip": wire,
        "coll_by_kind": coll,
        "coll_counts": counts,
        "memory_analysis": mem,
        "bytes_per_device": int(bytes_per_device),
        "fits_hbm": bool(bytes_per_device <= HW.hbm_bytes),
        "model_flops": model_flops,
        "useful_flops_ratio": useful,
        "mfu": mfu,
        "chips": chips,
        "hlo_bytes": len(hlo),
    }
