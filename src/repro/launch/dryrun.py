import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any other import: jax locks the host
# device count at first init, and the production meshes (16×16 single-pod,
# 2×16×16 multi-pod) need 512 placeholder devices.  Never set this globally —
# smoke tests and benches must see 1 device.
"""Multi-pod dry-run driver.

Usage:
    python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh multi --json-out experiments/dryrun_multi.json
    python -m repro.launch.dryrun --arch X --shape Y --plan-json '{"remat": "full", ...}'

Proves, for every (architecture × input-shape) cell, that
``jax.jit(step, in_shardings=..., out_shardings=...).lower(**input_specs)``
compiles on the production mesh; prints ``memory_analysis()`` /
``cost_analysis()`` and writes the roofline record.
"""
import argparse
import json
import sys
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="architecture id (see repro.configs.ARCH_IDS)")
    ap.add_argument("--shape", help="input shape id (train_4k/prefill_32k/decode_32k/long_500k)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true", help="run every (arch × shape) cell")
    ap.add_argument("--plan-json", default=None, help="SchedulePlan overrides as JSON")
    ap.add_argument("--json-out", default=None, help="write record(s) to this JSON file")
    ap.add_argument("--devices", type=int, default=None,
                    help="override forced host device count (testing only)")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    # imports AFTER the flag is pinned
    from repro.configs import cells, get_config, get_shape
    from repro.core.space import SchedulePlan
    from repro.launch.dryrun_impl import evaluate_cell, default_plan
    from repro.launch.mesh import mesh_spec

    plan = None
    if args.plan_json:
        base = json.loads(args.plan_json)
        mspec = mesh_spec(args.mesh == "multi")
        if args.arch and args.shape:
            d = default_plan(get_config(args.arch), get_shape(args.shape), mspec).to_dict()
        else:
            d = SchedulePlan().to_dict()
        d.update(base)
        plan = SchedulePlan.from_dict(d)

    records = []
    failures = []
    if args.all:
        todo = [(c.name, s.name) for c, s in cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        todo = [(args.arch, args.shape)]

    for arch, shape in todo:
        try:
            rec = evaluate_cell(arch, shape, args.mesh, plan)
            records.append(rec)
        except Exception as e:  # noqa: BLE001 - report all failures at end
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))

    if args.json_out:
        out = records[0] if (not args.all and records) else records
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=1)
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for a, s, e in failures:
            print(f"  {a} × {s}: {e}")
        return 1
    print(f"[dryrun] all {len(records)} cell(s) compiled OK on mesh={args.mesh}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
