"""Training driver.

    python -m repro.launch.train --arch granite-3-2b --smoke --steps 100
    python -m repro.launch.train --arch deepseek-67b --shape train_4k \
        --plan-json '{"microbatches": 8}'          # full config: AOT check only

Full (non-smoke) configs on this CPU container stop after AOT lowering; on a
TPU pod the same invocation runs the real loop (the step function is
identical — see launch/dryrun.py for the mesh bring-up).
"""
from __future__ import annotations

import argparse
import json


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, real optimization on CPU")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--plan-json", default=None)
    ap.add_argument("--autotune", default=None,
                    help="run this search algo first (e.g. mcts_1s) and train "
                         "with the found schedule")
    args = ap.parse_args(argv)

    from repro.configs import get_config, get_shape
    from repro.configs.base import InputShape
    from repro.core.space import SINGLE_POD, SchedulePlan, ScheduleSpace
    from repro.training.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    shape = get_shape(args.shape)
    space = ScheduleSpace(cfg, shape, SINGLE_POD)
    plan = space.plan_from_actions(space.default_actions())
    if args.autotune:
        from repro.core.autotuner import autotune

        res = autotune(args.arch, args.shape, algo=args.autotune)
        plan = res.plan
        print(f"[train] autotuned plan ({args.autotune}): {plan}")
    if args.plan_json:
        d = plan.to_dict()
        d.update(json.loads(args.plan_json))
        plan = SchedulePlan.from_dict(d)

    if args.smoke:
        cfg = cfg.reduced()
        shape = InputShape("smoke", args.seq, args.batch, "train")
        plan = SchedulePlan(
            microbatches=min(plan.microbatches, 2),
            remat=plan.remat,
            grad_comm="fp32",
            opt_dtype=plan.opt_dtype,
        )
        tc = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                           ckpt_every=max(args.steps // 2, 1))
        trainer = Trainer(cfg, shape, plan, tc)
        params, opt_state, step = trainer.run()
        for rec in trainer.metrics_log:
            print(f"[train] step={rec['step']:5d} loss={rec['loss']:.4f} "
                  f"lr={rec['lr']:.2e} dt={rec['step_time_s']*1e3:.0f}ms")
        if trainer.metrics_log:
            print(f"[train] done at step {step}; "
                  f"final loss {trainer.metrics_log[-1]['loss']:.4f}")
        else:
            print(f"[train] done at step {step} (resumed past total_steps)")
        return 0

    # full config: prove the step compiles for this plan (AOT), then exit —
    # use repro.launch.dryrun for the production-mesh version.
    import jax

    from repro.launch.dryrun_impl import evaluate_cell  # noqa: PLC0415

    n_dev = len(jax.devices())
    print(f"[train] {args.arch}×{args.shape}: full config on {n_dev} device(s); "
          "AOT-compiling the train step (no allocation)...")
    print("[train] use `python -m repro.launch.dryrun` for the production mesh.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
