"""Serving driver: batched decode with the continuous-batching engine.

    python -m repro.launch.serve --arch granite-3-2b --smoke --requests 6
"""
from __future__ import annotations

import argparse

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax

    from repro.configs import get_config
    from repro.models import transformer
    from repro.serving.engine import ServingEngine

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.input_kind != "tokens":
        print(f"[serve] {args.arch} uses a stub modality frontend; serving "
              "demo drives token-input archs — pick granite/deepseek/etc.")
        return 0
    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = ServingEngine(cfg, params, batch_slots=args.slots, max_len=64)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(1, 6))
        eng.submit(rng.integers(0, cfg.vocab_size, plen), max_new_tokens=args.max_new)
    done = eng.run()
    for r in sorted(done, key=lambda r: r.uid):
        print(f"[serve] req {r.uid}: prompt {r.prompt.tolist()} -> {r.generated}")
    print(f"[serve] completed {len(done)}/{args.requests} requests")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
