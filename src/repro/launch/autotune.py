"""ProTuner CLI: search for the best schedule of one (arch × shape) cell.

    python -m repro.launch.autotune --arch phi3.5-moe-42b-a6.6b --shape train_4k
    python -m repro.launch.autotune --arch deepseek-67b --shape decode_32k \
        --algo mcts_cost+real_1s --measure     # compile-in-the-loop
"""
from __future__ import annotations

import argparse
import json


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--algo", default="mcts_30s")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--measure", action="store_true",
                    help="real measurement (XLA compile) at root syncs")
    ap.add_argument("--measure-workers", type=int, default=None,
                    help="with --measure: fan measurements out to N "
                         "persistent fleet workers (core/measure_fleet) "
                         "instead of serial in-loop compiles")
    ap.add_argument("--budget-s", type=float, default=None)
    ap.add_argument("--engine", default="array",
                    choices=["reference", "array"],
                    help="MCTS tree engine (array = vectorized + shared "
                         "transposition cache; identical results)")
    ap.add_argument("--cost", default="analytic",
                    choices=["analytic", "learned", "hybrid"],
                    help="cost serving mode: analytic (exact), learned "
                         "(online-trained MLP prices cache misses), hybrid "
                         "(learned only while confident; analytic fallback)")
    ap.add_argument("--pricing", default=None,
                    choices=["scalar", "columnar", "jit"],
                    help="analytic pricing kernel: columnar (exact, "
                         "default), scalar (exact oracle replay), jit "
                         "(jax-jitted — ULP-level drift, versioned tag; "
                         "see cost_model.py)")
    ap.add_argument("--store", default=None,
                    help="PlanStore root directory: answer repeats from "
                         "disk, record this run, and (evolve/portfolio) "
                         "seed the population from stored plans")
    ap.add_argument("--parallel", action="store_true",
                    help="run ensemble trees on persistent pinned worker "
                         "processes (per-round deltas both directions; "
                         "identical results)")
    ap.add_argument("--workers", type=int, default=None,
                    help="cap the pinned worker pool (default: one per "
                         "core, up to the tree count)")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    from repro.core.autotuner import autotune, make_mdp
    from repro.core.measure import make_measure_fn

    plan_store = None
    if args.store:
        from repro.service.store import PlanStore

        plan_store = PlanStore(args.store)
    measure_fn = measure_backend = fleet = None
    if args.measure and args.measure_workers:
        from repro.core.measure_fleet import MeasurementFleet

        fleet = MeasurementFleet(n_workers=args.measure_workers)
        measure_backend = fleet.bind(args.arch, args.shape, args.mesh)
    elif args.measure:
        measure_fn = make_measure_fn(args.arch, args.shape, args.mesh)
    try:
        res = autotune(
            args.arch,
            args.shape,
            algo=args.algo,
            mesh=args.mesh,
            seed=args.seed,
            measure_fn=measure_fn,
            measure_backend=measure_backend,
            time_budget_s=args.budget_s,
            engine=args.engine,
            parallel=args.parallel,
            cost=args.cost,
            n_workers=args.workers,
            pricing=args.pricing,
            plan_store=plan_store,
        )
    finally:
        if fleet is not None:
            fleet.shutdown()
    mdp = make_mdp(args.arch, args.shape, args.mesh)
    terms = mdp.cost_model.terms(res.plan)
    print(f"[autotune] {args.arch}×{args.shape} algo={res.algo}")
    if res.cost_mode != "analytic":
        print(f"[autotune] cost serving: {res.cost_mode} "
              f"(model v{res.model_version}, {res.n_fits} fits, "
              f"{res.learned_evals} learned-priced plans)")
    if res.submit_bytes:
        print(f"[autotune] pinned pool: {res.submit_bytes:,}B submitted / "
              f"{res.return_bytes:,}B returned over "
              f"{len(res.submit_bytes_rounds)} rounds, "
              f"{res.snapshot_bytes:,}B snapshot, "
              f"{res.n_worker_restarts} worker restarts")
    if fleet is not None:
        print(f"[autotune] measurement fleet: {fleet.stats()}")
    if res.n_measure_failures:
        print(f"[autotune] WARNING: {res.n_measure_failures} candidate(s) "
              f"degraded to analytic cost after measurement failure")
    print(f"[autotune] best cost {res.cost*1e3:.2f} ms "
          f"(measured: {res.measured and f'{res.measured*1e3:.2f} ms'}) "
          f"evals={res.n_evals} measurements={res.n_measurements} "
          f"wall={res.wall_time_s:.1f}s")
    print(f"[autotune] plan: {json.dumps(res.plan.to_dict())}")
    print(f"[autotune] terms: compute={terms.compute_s*1e3:.2f}ms "
          f"memory={terms.memory_s*1e3:.2f}ms "
          f"collective={terms.collective_s*1e3:.2f}ms "
          f"dominant={terms.dominant} feasible={terms.feasible} "
          f"MFU={terms.details['mfu']:.3f}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(res.to_dict(), f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
