"""Tuner-as-a-service: persistent daemon + content-addressed plan store.

``PlanStore`` (store.py) is the on-disk tier — tuned plans and per-cell
transposition-cache snapshots, atomic-published and quarantine-validated.
``TunerService``/``serve_forever`` (daemon.py) is the long-lived loop
sharing one pinned worker pool and one measurement fleet across runs.
CLI: ``python -m repro.launch.tune_serve``.
"""
from repro.service.daemon import TunerService, serve_forever
from repro.service.store import (
    PlanStore,
    canonical_request,
    cell_key,
    request_key,
)

__all__ = [
    "PlanStore",
    "TunerService",
    "canonical_request",
    "cell_key",
    "request_key",
    "serve_forever",
]
