"""Tuner-as-a-service: the long-lived daemon loop.

``TunerService`` is the in-process core (directly usable from tests and
benchmarks, no socket): requests arrive as plain dicts, the plan store
answers repeats instantly, and cold requests run the normal search — but
against *persistent* shared machinery instead of one-shot copies:

* one ``PinnedWorkerPool`` across ALL runs — worker processes spawn once
  per daemon, each run rebinds them to its trees
  (``PinnedWorkerPool.rebind``) and ships per-round deltas as usual;
* one ``MeasurementFleet`` across all measuring runs;
* one in-memory ``TranspositionCache`` per cell, warm-started from the
  store's cell tier and synced back after every run (exact-wins both
  ways, see ``service/store.py``).

Cold-path results are bit-identical to one-shot ``autotune()`` — the
warm cache is a pure memo of exact values, so plan/cost/decisions match
and only eval counts drop (certified by ``tests/test_differential.py``).

``serve_forever`` wraps the service in a Unix-domain-socket JSON-lines
protocol (one request object per line, one response object per line):

    {"op": "tune", "arch": ..., "shape": ..., "algo": ..., ...}
    {"op": "stats"} | {"op": "ping"} | {"op": "shutdown"}

``repro.launch.tune_serve`` is the CLI for both ends.
"""
from __future__ import annotations

import json
import os
import socket
import time
from typing import Dict, Optional

from repro.core.autotuner import autotune, make_mdp
from repro.core.engine.cache import CachedMDP, TranspositionCache
from repro.core.engine.workers import PinnedWorkerPool
from repro.service.store import PlanStore, canonical_request, cell_key

_EXEC_KEYS = ("engine", "parallel", "n_workers")


class _CellState:
    """Daemon-lifetime state for one cell: the shared in-memory cache and
    the store-sync cursor (``None`` until the first sync → full export)."""

    __slots__ = ("cache", "store_wm")

    def __init__(self):
        self.cache = TranspositionCache()
        self.store_wm = None


class TunerService:
    def __init__(
        self,
        store_dir: str,
        *,
        parallel: bool = False,
        n_workers: Optional[int] = None,
        measure: str = "none",
        fleet_kwargs: Optional[dict] = None,
        log=print,
    ):
        assert measure in ("none", "stub", "real"), measure
        self.store = PlanStore(store_dir)
        self.parallel = parallel
        self.n_workers = n_workers
        self.measure = measure
        self.fleet_kwargs = dict(fleet_kwargs or {})
        self.log = log
        self.cells: Dict[str, _CellState] = {}
        self.pool: Optional[PinnedWorkerPool] = None
        self.fleet = None
        self.n_requests = 0
        self.n_searches = 0
        self.time_to_plan: list = []  # seconds per request, store hits incl.

    # -- shared machinery (lazy, daemon-lifetime) ----------------------
    def _shared_pool(self, mdp) -> Optional[PinnedWorkerPool]:
        if not self.parallel:
            return None
        if self.pool is None:
            # pre-spawn at the requested width with no trees; every run
            # rebinds (workers.py keeps the width for empty trees)
            self.pool = PinnedWorkerPool([], mdp, n_workers=self.n_workers)
        return self.pool

    def _shared_fleet(self):
        if self.measure == "none":
            return None
        if self.fleet is None:
            from repro.core.measure_fleet import MeasurementFleet

            fkw = dict(self.fleet_kwargs)
            if self.measure == "stub":
                from repro.core.measure_stub import stub_measure

                fkw.setdefault("target", stub_measure)
            fkw.setdefault(
                "cache_dir", os.path.join(self.store.root, "measure_cache"))
            self.fleet = MeasurementFleet(**fkw)
        return self.fleet

    # -- request handling ----------------------------------------------
    def handle(self, request: dict) -> dict:
        """One tuning request → one response dict.  ``request`` carries
        the ``canonical_request`` settings plus optional execution knobs
        (engine/parallel/n_workers), which never enter the store key."""
        t0 = time.perf_counter()
        exec_knobs = {k: request[k] for k in _EXEC_KEYS if k in request}
        req = canonical_request(**{
            k: v for k, v in request.items() if k not in _EXEC_KEYS})
        self.n_requests += 1

        res = self.store.lookup(req)
        served = "store"
        if res is None:
            res = self._tune(req, exec_knobs)
            served = "search"
        dt = time.perf_counter() - t0
        self.time_to_plan.append(dt)
        return {
            "ok": True,
            "served": served,
            "request": req,
            "time_to_plan_s": dt,
            "result": res.to_dict(),
        }

    def _tune(self, req: dict, exec_knobs: dict):
        ckey = cell_key(req)
        cell = self.cells.setdefault(ckey, _CellState())
        if not cell.cache.n_entries:
            n = self.store.warm_cell(
                ckey, cell.cache, include_learned=req["cost"] != "analytic")
            if n:
                self.log(f"[tuner-service] cell {ckey[:8]}: warmed "
                         f"{n} entries from store")
        # a "pricing" entry in the canonical request is the versioned jit
        # kernel tag (store.canonical_request); absent means exact
        mdp = CachedMDP(make_mdp(
            req["arch"], req["shape"], req["mesh"],
            req["noise_sigma"], req["noise_seed"],
            pricing="jit" if req.get("pricing") else None,
        ), cache=cell.cache)
        fleet = self._shared_fleet()
        measure_backend = (
            fleet.bind(req["arch"], req["shape"], req["mesh"])
            if fleet is not None and "real" in req["algo"] else None
        )
        parallel = exec_knobs.get("parallel", self.parallel)
        self.n_searches += 1
        res = autotune(
            req["arch"], req["shape"],
            algo=req["algo"], mesh=req["mesh"], seed=req["seed"],
            n_standard=req["n_standard"], n_greedy=req["n_greedy"],
            time_budget_s=req["time_budget_s"],
            noise_sigma=req["noise_sigma"], cost=req["cost"],
            mdp=mdp,
            engine=exec_knobs.get("engine", "array"),
            parallel=parallel,
            n_workers=exec_knobs.get("n_workers", self.n_workers),
            worker_pool=self._shared_pool(mdp) if parallel else None,
            shm=exec_knobs.get("shm"),
            worker_batch=exec_knobs.get("worker_batch"),
            measure_backend=measure_backend,
        )
        self.store.record(req, res)
        cell.store_wm = self.store.sync_cell(ckey, cell.cache, cell.store_wm)
        return res

    def stats(self) -> dict:
        out = {
            "n_requests": self.n_requests,
            "n_searches": self.n_searches,
            "store": self.store.stats(),
            "cells": {k: v.cache.stats() for k, v in self.cells.items()},
        }
        if self.fleet is not None:
            out["fleet"] = self.fleet.stats()
        if self.pool is not None:
            out["pool"] = {
                "submit_bytes": self.pool.submit_bytes,
                "return_bytes": self.pool.return_bytes,
                "snapshot_bytes": self.pool.snapshot_bytes,
                "n_worker_restarts": self.pool.n_worker_restarts,
                # last run's serving split + cross-worker duplicate evals
                # (per-worker hit/miss/dedup and shm-vs-export counters)
                **self.pool.stats(),
            }
        return out

    def shutdown(self) -> None:
        if self.pool is not None:
            self.pool.shutdown()
            self.pool = None
        if self.fleet is not None:
            self.fleet.shutdown()
            self.fleet = None


# ---------------------------------------------------------------------------
# Socket front end (JSON lines over a Unix domain socket)
# ---------------------------------------------------------------------------
def serve_forever(service: TunerService, socket_path: str,
                  *, max_requests: Optional[int] = None) -> int:
    """Accept loop: one JSON object per line in, one per line out.
    ``max_requests`` bounds the loop for tests/CI smoke.  Returns the
    number of requests served."""
    if os.path.exists(socket_path):
        os.remove(socket_path)
    served = 0
    srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    try:
        srv.bind(socket_path)
        srv.listen(8)
        service.log(f"[tuner-service] listening on {socket_path}")
        stop = False
        while not stop and (max_requests is None or served < max_requests):
            conn, _ = srv.accept()
            with conn, conn.makefile("rwb") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        msg = json.loads(line)
                        op = msg.pop("op", "tune")
                        if op == "ping":
                            out = {"ok": True, "pong": True}
                        elif op == "stats":
                            out = {"ok": True, "stats": service.stats()}
                        elif op == "shutdown":
                            out = {"ok": True, "stopping": True}
                            stop = True
                        elif op == "tune":
                            out = service.handle(msg)
                            served += 1
                        else:
                            out = {"ok": False, "error": f"unknown op {op!r}"}
                    except Exception as e:  # a bad request never kills the daemon
                        out = {"ok": False, "error": f"{type(e).__name__}: {e}"}
                    f.write((json.dumps(out) + "\n").encode())
                    f.flush()
                    if stop or (max_requests is not None
                                and served >= max_requests):
                        break
    finally:
        srv.close()
        if os.path.exists(socket_path):
            os.remove(socket_path)
        service.shutdown()
    return served
