"""Tuner-as-a-service: the long-lived daemon loop.

``TunerService`` is the in-process core (directly usable from tests and
benchmarks, no socket): requests arrive as plain dicts, the plan store
answers repeats instantly, and cold requests run the normal search — but
against *persistent* shared machinery instead of one-shot copies:

* one ``PinnedWorkerPool`` across ALL runs — worker processes spawn once
  per daemon, each run rebinds them to its trees
  (``PinnedWorkerPool.rebind``) and ships per-round deltas as usual;
* one ``MeasurementFleet`` across all measuring runs;
* one in-memory ``TranspositionCache`` per cell, warm-started from the
  store's cell tier and synced back after every run (exact-wins both
  ways, see ``service/store.py``).

Cold-path results are bit-identical to one-shot ``autotune()`` — the
warm cache is a pure memo of exact values, so plan/cost/decisions match
and only eval counts drop (certified by ``tests/test_differential.py``).

Crash safety and deadlines (PR 10) ride on the round-boundary
``RunController`` seam (``repro.core.run_control``):

* every search is **journaled** before it starts and released after its
  result lands (``store.journal_begin``/``journal_release``), and
  **checkpointed** every ``checkpoint_every`` decision rounds
  (``store.save_checkpoint`` — pickled ``ProTuner.snapshot()``s,
  atomically published).  ``recover()`` replays pending journal entries
  on restart, resuming from the checkpoint — the recovered result is
  bit-identical to an uninterrupted run (SIGKILL-tested);
* a per-request ``deadline_s`` execution knob interrupts the search at
  the next round boundary: the caller gets best-so-far with
  ``result["stats"]["interrupted"]`` provenance, the checkpoint is KEPT
  (a retry resumes and completes), and the partial result is never
  recorded as the stored plan;
* a failed search syncs the warm cell cache (the progress it DID make),
  releases its journal/checkpoint state, and returns structured error
  provenance (``error_info``) instead of a bare ``{"ok": false}``;
* a health watchdog **degrades** a repeatedly-restarting pinned pool
  (``degrade_after`` cumulative worker restarts) to the bit-identical
  sequential engine, counted on ``stats()``.

``serve_forever`` wraps the service in a Unix-domain-socket JSON-lines
protocol (one request object per line, one response object per line):

    {"op": "tune", "arch": ..., "shape": ..., "algo": ..., ...}
    {"op": "stats"} | {"op": "ping"} | {"op": "shutdown"}

The front end is concurrent and supervised: a threaded accept loop, a
read timeout per accepted connection (a silent client is closed, never
blocking the daemon), and a bounded request queue drained by ONE search
worker (the pool/cells/fleet are single-run state) — a full queue
answers ``{"ok": false, "error": "overloaded", "retry_after_s": ...}``
immediately.  Shutdown cancels the in-flight search (it checkpoints and
returns best-so-far to its waiting client) and answers queued requests
with ``shutting_down``.

``repro.launch.tune_serve`` is the CLI for both ends.
"""
from __future__ import annotations

import json
import os
import queue
import socket
import threading
import time
from typing import Dict, List, Optional

from repro.core.autotuner import autotune, make_mdp
from repro.core.engine.cache import CachedMDP, TranspositionCache
from repro.core.engine.workers import PinnedWorkerPool
from repro.core.run_control import RunController
from repro.service.store import (
    PlanStore,
    canonical_request,
    cell_key,
    request_key,
)

_EXEC_KEYS = ("engine", "parallel", "n_workers", "shm", "worker_batch",
              "deadline_s")


class _CellState:
    """Daemon-lifetime state for one cell: the shared in-memory cache and
    the store-sync cursor (``None`` until the first sync → full export)."""

    __slots__ = ("cache", "store_wm")

    def __init__(self):
        self.cache = TranspositionCache()
        self.store_wm = None


class _LatencyRing:
    """Fixed-size ring of recent per-request latencies with running
    aggregates — a long-lived daemon must not grow per-request state.
    ``append``/``len`` keep the old list surface; ``summary`` feeds
    ``stats()`` (running count/mean over ALL requests, p50/p99 over the
    retained window)."""

    __slots__ = ("cap", "buf", "_idx", "count", "total")

    def __init__(self, cap: int = 256):
        self.cap = max(int(cap), 1)
        self.buf: List[float] = []
        self._idx = 0
        self.count = 0
        self.total = 0.0

    def append(self, dt: float) -> None:
        if len(self.buf) < self.cap:
            self.buf.append(dt)
        else:
            self.buf[self._idx] = dt
            self._idx = (self._idx + 1) % self.cap
        self.count += 1
        self.total += dt

    def __len__(self) -> int:
        return self.count

    def percentile(self, q: float) -> Optional[float]:
        if not self.buf:
            return None
        s = sorted(self.buf)
        return s[min(int(q * len(s)), len(s) - 1)]

    def summary(self) -> dict:
        return {
            "count": self.count,
            "window": len(self.buf),
            "mean_s": self.total / self.count if self.count else 0.0,
            "p50_s": self.percentile(0.50),
            "p99_s": self.percentile(0.99),
        }


class TunerService:
    def __init__(
        self,
        store_dir: str,
        *,
        parallel: bool = False,
        n_workers: Optional[int] = None,
        measure: str = "none",
        fleet_kwargs: Optional[dict] = None,
        log=print,
        checkpoint_every: int = 4,
        deadline_s: Optional[float] = None,
        round_delay_s: float = 0.0,
        degrade_after: int = 5,
        latency_window: int = 256,
    ):
        assert measure in ("none", "stub", "real"), measure
        self.store = PlanStore(store_dir)
        self.parallel = parallel
        self.n_workers = n_workers
        self.measure = measure
        self.fleet_kwargs = dict(fleet_kwargs or {})
        self.log = log
        # crash-safety / deadline knobs: checkpoint cadence in decision
        # rounds (0 disables checkpoints AND journal resume), the default
        # per-request deadline (None = unbounded; requests override with
        # the ``deadline_s`` exec knob), the deterministic per-round
        # fault-injection delay (tests/benchmarks only), and the watchdog
        # threshold on cumulative pool worker restarts
        self.checkpoint_every = checkpoint_every
        self.deadline_s = deadline_s
        self.round_delay_s = round_delay_s
        self.degrade_after = degrade_after
        self.cells: Dict[str, _CellState] = {}
        self.pool: Optional[PinnedWorkerPool] = None
        self.fleet = None
        self.n_requests = 0
        self.n_searches = 0
        self.n_errors = 0
        self.n_interrupted = 0
        self.n_recovered = 0
        self.degraded = False  # watchdog tripped: sequential engine only
        self.n_pool_restarts = 0  # last observed cumulative restart count
        self.time_to_plan = _LatencyRing(latency_window)
        self._active_controller: Optional[RunController] = None

    # -- shared machinery (lazy, daemon-lifetime) ----------------------
    def _shared_pool(self, mdp) -> Optional[PinnedWorkerPool]:
        if not self.parallel or self.degraded:
            return None
        if self.pool is None:
            # pre-spawn at the requested width with no trees; every run
            # rebinds (workers.py keeps the width for empty trees)
            self.pool = PinnedWorkerPool([], mdp, n_workers=self.n_workers)
        return self.pool

    def _shared_fleet(self):
        if self.measure == "none":
            return None
        if self.fleet is None:
            from repro.core.measure_fleet import MeasurementFleet

            fkw = dict(self.fleet_kwargs)
            if self.measure == "stub":
                from repro.core.measure_stub import stub_measure

                fkw.setdefault("target", stub_measure)
            fkw.setdefault(
                "cache_dir", os.path.join(self.store.root, "measure_cache"))
            self.fleet = MeasurementFleet(**fkw)
        return self.fleet

    # -- request handling ----------------------------------------------
    def handle(self, request: dict) -> dict:
        """One tuning request → one response dict.  ``request`` carries
        the ``canonical_request`` settings plus optional execution knobs
        (engine/parallel/n_workers/shm/worker_batch/deadline_s), which
        never enter the store key.  Never raises: a failed request
        returns ``ok=False`` with the legacy ``error`` string plus
        structured ``error_info`` provenance."""
        t0 = time.perf_counter()
        self.n_requests += 1
        req = None
        try:
            exec_knobs = {k: request[k] for k in _EXEC_KEYS if k in request}
            req = canonical_request(**{
                k: v for k, v in request.items() if k not in _EXEC_KEYS})
            res = self.store.lookup(req)
            served = "store"
            if res is None:
                res = self._tune(req, exec_knobs)
                served = "search"
        except Exception as e:  # noqa: BLE001 - a bad request never kills the daemon
            dt = time.perf_counter() - t0
            self.n_errors += 1
            self.time_to_plan.append(dt)
            return {
                "ok": False,
                "error": f"{type(e).__name__}: {e}",
                "error_info": {
                    "type": type(e).__name__,
                    "message": str(e),
                    "phase": "request" if req is None else "search",
                    "request": req if req is not None else request,
                },
                "time_to_plan_s": dt,
            }
        dt = time.perf_counter() - t0
        self.time_to_plan.append(dt)
        return {
            "ok": True,
            "served": served,
            "request": req,
            "time_to_plan_s": dt,
            "result": res.to_dict(),
        }

    def _tune(self, req: dict, exec_knobs: dict):
        ckey = cell_key(req)
        cell = self.cells.setdefault(ckey, _CellState())
        if not cell.cache.n_entries:
            n = self.store.warm_cell(
                ckey, cell.cache, include_learned=req["cost"] != "analytic")
            if n:
                self.log(f"[tuner-service] cell {ckey[:8]}: warmed "
                         f"{n} entries from store")
        # a "pricing" entry in the canonical request is the versioned jit
        # kernel tag (store.canonical_request); absent means exact
        mdp = CachedMDP(make_mdp(
            req["arch"], req["shape"], req["mesh"],
            req["noise_sigma"], req["noise_seed"],
            pricing="jit" if req.get("pricing") else None,
        ), cache=cell.cache)
        fleet = self._shared_fleet()
        measure_backend = (
            fleet.bind(req["arch"], req["shape"], req["mesh"])
            if fleet is not None and "real" in req["algo"] else None
        )
        parallel = exec_knobs.get("parallel", self.parallel)
        if self.degraded:
            # watchdog tripped: the sequential engine is certified
            # bit-identical to the pool, so degrading changes nothing but
            # wall clock
            parallel = False
        controller = RunController(
            deadline_s=exec_knobs.get("deadline_s", self.deadline_s),
            checkpoint_every=self.checkpoint_every,
            checkpoint_fn=(
                (lambda snap: self.store.save_checkpoint(req, snap))
                if self.checkpoint_every else None
            ),
            round_delay_s=self.round_delay_s,
        )
        resume = (
            self.store.load_checkpoint(req) if self.checkpoint_every else None
        )
        # write-ahead journal: the request is on record BEFORE the search
        # starts, so a crash anywhere below leaves a pending entry for
        # recover() to replay
        self.store.journal_begin(req)
        self._active_controller = controller
        self.n_searches += 1
        try:
            res = autotune(
                req["arch"], req["shape"],
                algo=req["algo"], mesh=req["mesh"], seed=req["seed"],
                n_standard=req["n_standard"], n_greedy=req["n_greedy"],
                time_budget_s=req["time_budget_s"],
                noise_sigma=req["noise_sigma"], cost=req["cost"],
                mdp=mdp,
                engine=exec_knobs.get("engine", "array"),
                parallel=parallel,
                n_workers=exec_knobs.get("n_workers", self.n_workers),
                worker_pool=self._shared_pool(mdp) if parallel else None,
                shm=exec_knobs.get("shm"),
                worker_batch=exec_knobs.get("worker_batch"),
                measure_backend=measure_backend,
                controller=controller,
                resume=resume,
            )
        except Exception:
            # the search's progress lives in the warm cell cache — persist
            # it before surfacing the error, then release the journal and
            # checkpoint so a poisoned request is not replayed forever on
            # every restart (the caller gets structured provenance and
            # decides whether to retry)
            cell.store_wm = self.store.sync_cell(
                ckey, cell.cache, cell.store_wm)
            self.store.journal_release(req)
            self.store.clear_checkpoint(req)
            raise
        finally:
            self._active_controller = None
            self._watchdog()
        if (res.stats or {}).get("interrupted"):
            # deadline/cancel best-so-far: answer the caller, KEEP the
            # checkpoint (a retry resumes and completes), never record the
            # partial plan (store.record also guards)
            self.n_interrupted += 1
        else:
            self.store.record(req, res)
            self.store.clear_checkpoint(req)
        cell.store_wm = self.store.sync_cell(ckey, cell.cache, cell.store_wm)
        self.store.journal_release(req)
        return res

    # -- crash recovery ------------------------------------------------
    def recover(self) -> int:
        """Replay the write-ahead journal: every pending entry is a
        request that was accepted but never released (the daemon died
        mid-search).  An entry whose plan actually landed (death between
        ``record`` and ``journal_release``) is just released; the rest
        re-run through ``_tune``, which picks the round-boundary
        checkpoint up automatically — the replay RESUMES rather than
        starting over, and its result is bit-identical to an
        uninterrupted run.  Returns the number of requests re-run."""
        n = 0
        swept = self.store.sweep_tmp()
        if swept:
            self.log(f"[tuner-service] swept {swept} orphaned tmp file(s) "
                     f"from a crashed writer")
        for req in self.store.pending_requests():
            key = request_key(req)
            if self.store.lookup(req) is not None:
                self.store.journal_release(req)
                self.store.clear_checkpoint(req)
                continue
            self.log(f"[tuner-service] recovering journaled request {key}")
            self.n_requests += 1
            try:
                self._tune(req, {})
            except Exception as e:  # noqa: BLE001 - recovery must not kill startup
                self.n_errors += 1
                self.log(f"[tuner-service] recovery of {key} failed: "
                         f"{type(e).__name__}: {e}")
                continue
            n += 1
            self.n_recovered += 1
        return n

    # -- supervision ---------------------------------------------------
    def cancel_active(self) -> None:
        """Cancel the in-flight search, if any (thread-safe; called by
        the socket front end on shutdown).  The search finishes its
        current round, checkpoints, and returns best-so-far to whoever
        is waiting on it."""
        controller = self._active_controller
        if controller is not None:
            controller.cancel()

    def _watchdog(self) -> None:
        """Health check after every search: a pool whose workers keep
        dying gets shut down and the daemon degrades to the sequential
        engine (certified bit-identical — same plans, no worker
        processes to babysit)."""
        if self.pool is None or self.degraded:
            return
        restarts = self.pool.n_worker_restarts
        self.n_pool_restarts = restarts
        if restarts >= self.degrade_after:
            self.log(
                f"[tuner-service] pool hit {restarts} worker restarts "
                f"(threshold {self.degrade_after}); degrading to the "
                f"sequential engine")
            pool, self.pool = self.pool, None
            self.degraded = True
            try:
                pool.shutdown()
            except Exception:  # noqa: BLE001 - a dying pool must not block degrade
                pass

    def stats(self) -> dict:
        out = {
            "n_requests": self.n_requests,
            "n_searches": self.n_searches,
            "n_errors": self.n_errors,
            "n_interrupted": self.n_interrupted,
            "n_recovered": self.n_recovered,
            "degraded": self.degraded,
            "pool_restarts": self.n_pool_restarts,
            "time_to_plan": self.time_to_plan.summary(),
            "store": self.store.stats(),
            "cells": {k: v.cache.stats() for k, v in self.cells.items()},
        }
        if self.fleet is not None:
            out["fleet"] = self.fleet.stats()
        if self.pool is not None:
            out["pool"] = {
                "submit_bytes": self.pool.submit_bytes,
                "return_bytes": self.pool.return_bytes,
                "snapshot_bytes": self.pool.snapshot_bytes,
                # last run's serving split + cross-worker duplicate evals
                # (per-worker hit/miss/dedup counters) + restart counts,
                # cumulative and since the last rebind
                **self.pool.stats(),
            }
        return out

    def shutdown(self) -> None:
        if self.pool is not None:
            self.pool.shutdown()
            self.pool = None
        if self.fleet is not None:
            self.fleet.shutdown()
            self.fleet = None


# ---------------------------------------------------------------------------
# Socket front end (JSON lines over a Unix domain socket)
# ---------------------------------------------------------------------------
class _Job:
    """One queued tune request: the message, a slot for the response, and
    the event its connection thread waits on."""

    __slots__ = ("msg", "result", "done")

    def __init__(self, msg: dict):
        self.msg = msg
        self.result: Optional[dict] = None
        self.done = threading.Event()

    def finish(self, out: dict) -> None:
        self.result = out
        self.done.set()


class _Server:
    """Threaded front end state: the accept loop spawns one reader
    thread per connection; tune requests flow through a bounded queue
    into ONE search-worker thread (the pool/cells/fleet are single-run
    state, so searches serialize); ping/stats/shutdown answer inline on
    the connection thread, so they work while a search is running."""

    def __init__(self, service: TunerService, *, max_requests: Optional[int],
                 queue_size: int, read_timeout_s: float):
        self.service = service
        self.max_requests = max_requests
        self.read_timeout_s = read_timeout_s
        self.q: "queue.Queue[_Job]" = queue.Queue(maxsize=max(queue_size, 1))
        self.stop = threading.Event()
        self.served = 0  # successful tune responses (max_requests counts these)
        self.n_overloaded = 0
        self.n_idle_closed = 0

    # -- search worker -------------------------------------------------
    def worker_loop(self) -> None:
        while True:
            try:
                job = self.q.get(timeout=0.05)
            except queue.Empty:
                if self.stop.is_set():
                    return
                continue
            if self.stop.is_set():
                job.finish({"ok": False, "error": "shutting_down"})
                continue
            try:
                out = self.service.handle(job.msg)
            except Exception as e:  # noqa: BLE001 - handle() shouldn't raise; belt & braces
                out = {"ok": False, "error": f"{type(e).__name__}: {e}"}
            if out.get("ok"):
                self.served += 1
            job.finish(out)
            if (self.max_requests is not None
                    and self.served >= self.max_requests):
                self.stop.set()
                return

    def drain(self) -> None:
        """Answer every still-queued job after stop — no client is left
        waiting on a dead queue."""
        while True:
            try:
                job = self.q.get_nowait()
            except queue.Empty:
                return
            job.finish({"ok": False, "error": "shutting_down"})

    # -- per-connection reader -----------------------------------------
    def client_loop(self, conn: socket.socket) -> None:
        conn.settimeout(self.read_timeout_s)
        try:
            with conn, conn.makefile("rwb") as f:
                while not self.stop.is_set():
                    try:
                        line = f.readline()
                    except socket.timeout:
                        # a silent client no longer wedges the daemon:
                        # close the idle connection and move on
                        self.n_idle_closed += 1
                        self.service.log(
                            "[tuner-service] closing idle connection")
                        return
                    except OSError:
                        return
                    if not line:
                        return  # clean client close
                    line = line.strip()
                    if not line:
                        continue
                    out = self.dispatch(line)
                    try:
                        f.write((json.dumps(out) + "\n").encode())
                        f.flush()
                    except OSError:
                        return
        except Exception as e:  # noqa: BLE001 - one bad connection never kills the daemon
            self.service.log(f"[tuner-service] connection error: {e!r}")

    def _retry_after(self) -> float:
        """Back-off hint for overloaded clients: the recent p50 search
        latency times the queue they'd be behind."""
        p50 = self.service.time_to_plan.summary().get("p50_s") or 1.0
        return round(p50 * (self.q.qsize() + 1), 3)

    def dispatch(self, line: bytes) -> dict:
        try:
            msg = json.loads(line)
            op = msg.pop("op", "tune")
        except Exception as e:  # noqa: BLE001
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}
        if op == "ping":
            return {"ok": True, "pong": True}
        if op == "stats":
            try:
                stats = self.service.stats()
            except Exception as e:  # noqa: BLE001
                return {"ok": False, "error": f"{type(e).__name__}: {e}"}
            stats["serve"] = {
                "served": self.served,
                "queue_depth": self.q.qsize(),
                "n_overloaded": self.n_overloaded,
                "n_idle_closed": self.n_idle_closed,
            }
            return {"ok": True, "stats": stats}
        if op == "shutdown":
            self.stop.set()
            # graceful drain-and-checkpoint: the in-flight search stops at
            # its next round boundary, checkpoints, and answers its client
            # with best-so-far; queued jobs get "shutting_down"
            self.service.cancel_active()
            return {"ok": True, "stopping": True}
        if op != "tune":
            return {"ok": False, "error": f"unknown op {op!r}"}
        if self.stop.is_set():
            return {"ok": False, "error": "shutting_down"}
        job = _Job(msg)
        try:
            self.q.put_nowait(job)
        except queue.Full:
            # bounded-queue backpressure: an explicit, immediate response
            # beats an unbounded queue growing until the box dies
            self.n_overloaded += 1
            return {"ok": False, "error": "overloaded",
                    "retry_after_s": self._retry_after()}
        job.done.wait()
        return job.result


def serve_forever(service: TunerService, socket_path: str,
                  *, max_requests: Optional[int] = None,
                  read_timeout_s: float = 30.0,
                  queue_size: int = 16,
                  recover: bool = True) -> int:
    """Supervised accept loop: one JSON object per line in, one per line
    out, concurrent connections, bounded tune queue (see ``_Server``).
    ``max_requests`` bounds the loop for tests/CI smoke (counting
    SUCCESSFUL tune responses, as before).  ``recover=True`` replays the
    write-ahead journal before accepting — clients connecting during
    recovery queue in the listen backlog.  Returns the number of
    requests served."""
    if os.path.exists(socket_path):
        os.remove(socket_path)
    srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    server = _Server(service, max_requests=max_requests,
                     queue_size=queue_size, read_timeout_s=read_timeout_s)
    worker = threading.Thread(
        target=server.worker_loop, name="tune-worker", daemon=True)
    conn_threads: List[threading.Thread] = []
    try:
        srv.bind(socket_path)
        srv.listen(16)
        srv.settimeout(0.1)  # poll the stop flag between accepts
        worker.start()
        if recover:
            try:
                n = service.recover()
                if n:
                    service.log(
                        f"[tuner-service] recovered {n} journaled request(s)")
            except Exception as e:  # noqa: BLE001 - never refuse to start
                service.log(f"[tuner-service] journal recovery failed: {e!r}")
        service.log(f"[tuner-service] listening on {socket_path}")
        while not server.stop.is_set():
            try:
                conn, _ = srv.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            t = threading.Thread(
                target=server.client_loop, args=(conn,), daemon=True)
            t.start()
            conn_threads.append(t)
    finally:
        server.stop.set()
        service.cancel_active()
        worker.join(timeout=60.0)
        server.drain()
        for t in conn_threads:
            t.join(timeout=5.0)
        srv.close()
        if os.path.exists(socket_path):
            os.remove(socket_path)
        service.shutdown()
    return server.served
