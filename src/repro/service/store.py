"""Persistent content-addressed plan store: the tuner's on-disk tier.

Two tiers, both under one root directory, both JSON, both published with
the measurement-cache discipline (``core/measure.py``): tmp-sibling +
``os.replace`` atomic writes, validate-and-quarantine on read.

``plans/<request-key>.json`` — complete tuned results.  The key hashes
every *value-affecting* request setting (arch, shape, mesh, algo, seed,
budget, ensemble size, noise, cost mode) and deliberately EXCLUDES
execution knobs (``engine``, ``parallel``, ``n_workers``) — the engines
are certified bit-identical (``tests/test_differential.py``), so a plan
tuned by any of them answers the same request.  A hit reproduces the full
``TuneResult`` (plan, exact cost, decision trace) with ``from_store=True``
and zero search evals.

``cells/<cell-key>.json`` — per-cell ``TranspositionCache`` snapshots.
The cell key hashes only what cache *values* depend on (arch, shape,
mesh, noise), so every algo/seed/budget tuning the same cell shares one
warm-start file.  Sync reuses the pinned-worker delta protocol
(``TranspositionCache.watermark``/``export_since``/``apply_export``):
each sync exports the in-memory cache's new entries since the last sync,
merges them into the on-disk state under the exact-wins rule, and
publishes atomically.  Writers are lock-free — concurrent daemons race on
the ``os.replace`` and the loser's delta simply lands on its next sync
(its in-memory cache still holds everything); exact-wins makes the merge
order-independent for exact entries, so the store converges.

Two further tiers back the daemon's crash safety (PR 10):
``journal/<request-key>.json`` — the write-ahead request log (journaled
before search, released after the result lands; pending entries are what
``TunerService.recover`` replays after a crash) — and
``checkpoints/<request-key>.pkl`` — pickled round-boundary
``ProTuner.snapshot()`` states, published with the same tmp-sibling +
``os.replace`` discipline and quarantined on unreadable load.

Warm starts load only EXACT (untagged) entries by default: a memo of
exact analytic costs changes hit counts but never values, so a warmed
search's plan/cost/decisions stay bit-identical to a cold one.  Learned-
tagged entries (model predictions) are persisted — exact-wins applies
across restarts too — but are only loaded into runs that themselves serve
a learned model (``include_learned=True``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import uuid
from typing import List, Optional, Tuple

from repro.core.engine.cache import TranspositionCache, Watermark
from repro.core.ensemble import TuneResult
from repro.core.space import SchedulePlan

STORE_VERSION = 1

# the TuneResult fields a stored plan must round-trip (everything else
# defaults on decode)
_REQUIRED_RESULT = ("plan", "cost", "decisions")


def canonical_request(
    arch: str,
    shape: str,
    *,
    mesh: str = "single",
    algo: str = "mcts_30s",
    seed: int = 0,
    time_budget_s: Optional[float] = None,
    n_standard: int = 15,
    n_greedy: int = 1,
    noise_sigma: float = 0.0,
    noise_seed: Optional[int] = None,
    cost: str = "analytic",
    pricing: Optional[str] = None,
    **_ignored,
) -> dict:
    """Normalize a tuning request to the value-affecting settings only.
    ``noise_seed`` defaults to ``seed`` — exactly ``autotune()``'s own
    ``make_mdp(..., noise_sigma, seed)`` default — and normalizes to 0
    when ``noise_sigma`` is 0 (no noise → the seed is value-inert, and
    every noise-free run of a cell should share one cell file).
    ``pricing`` normalizes to the versioned kernel tag: None/"scalar"/
    "columnar" are all the exact analytic value and collapse to "exact" —
    OMITTED from the dict so every pre-existing request key is unchanged
    — while "jit" records ``cost_model.JIT_PRICING_TAG`` (a tag bump on
    any kernel revision re-keys stored plans and cells, so ULP-level
    value drift never answers a stale request).  Execution knobs
    (engine/parallel/n_workers) are accepted and dropped."""
    req = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh,
        "algo": algo,
        "seed": seed,
        "time_budget_s": time_budget_s,
        "n_standard": n_standard,
        "n_greedy": n_greedy,
        "noise_sigma": noise_sigma,
        "noise_seed": (
            (seed if noise_seed is None else noise_seed) if noise_sigma else 0
        ),
        "cost": cost,
    }
    if pricing == "jit":
        from repro.core.cost_model import JIT_PRICING_TAG

        req["pricing"] = JIT_PRICING_TAG
    elif pricing not in (None, "scalar", "columnar"):
        raise ValueError(f"unknown pricing {pricing!r}")
    return req


def request_key(req: dict) -> str:
    blob = json.dumps([STORE_VERSION, req], sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:20]


def cell_key(req: dict) -> str:
    """Cache-value identity: every request whose cache entries are
    interchangeable (same cost function) maps to one cell file.  A
    non-exact pricing tag (jit kernel, ULP-level drift from the exact
    path) is part of that identity — appended only when present, so all
    exact-path cell keys are unchanged."""
    fields = [STORE_VERSION, req["arch"], req["shape"], req["mesh"],
              req["noise_sigma"], req["noise_seed"]]
    if req.get("pricing"):
        fields.append(req["pricing"])
    blob = json.dumps(fields, sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:20]


# ---------------------------------------------------------------------------
# Atomic file discipline (the measurement-cache pattern)
# ---------------------------------------------------------------------------
def _write_json(path: str, obj: dict) -> None:
    tmp = f"{path}.tmp.{os.getpid()}.{uuid.uuid4().hex[:8]}"
    try:
        with open(tmp, "w") as f:
            json.dump(obj, f)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def _load_json(path: str, validate) -> Optional[dict]:
    """Validated read: a corrupt, truncated, or schema-violating file is
    QUARANTINED (deleted) so the next request re-tunes, instead of being
    served forever or crashing every lookup."""
    try:
        with open(path) as f:
            obj = json.load(f)
    except FileNotFoundError:
        return None
    except (OSError, ValueError):
        obj = None
    if isinstance(obj, dict) and obj.get("version") == STORE_VERSION:
        try:
            if validate(obj):
                return obj
        except (KeyError, TypeError, ValueError):
            pass
    try:
        os.remove(path)
    except OSError:
        pass
    return None


# ---------------------------------------------------------------------------
# Cache table codec (state tuples <-> JSON lists)
# ---------------------------------------------------------------------------
def _encode_tbl(tbl: dict) -> list:
    return [[list(k), v] for k, v in tbl.items()]


def _decode_tbl(rows: list) -> dict:
    out = {}
    for k, v in rows:
        out[tuple(int(a) for a in k)] = v
    return out


def _result_to_dict(res: TuneResult) -> dict:
    return res.to_dict()


def _result_from_dict(d: dict) -> TuneResult:
    d = dict(d)
    d["plan"] = SchedulePlan.from_dict(d["plan"])
    known = {f.name for f in dataclasses.fields(TuneResult)}
    res = TuneResult(**{k: v for k, v in d.items() if k in known})
    res.from_store = True
    return res


class PlanStore:
    """On-disk tier shared by every daemon (and any one-shot ``autotune``
    pointed at the same root)."""

    def __init__(self, root: str):
        self.root = root
        self.plans_dir = os.path.join(root, "plans")
        self.cells_dir = os.path.join(root, "cells")
        # crash-safety tiers (service/daemon.py): the write-ahead request
        # journal and the round-boundary search checkpoints
        self.journal_dir = os.path.join(root, "journal")
        self.checkpoints_dir = os.path.join(root, "checkpoints")
        os.makedirs(self.plans_dir, exist_ok=True)
        os.makedirs(self.cells_dir, exist_ok=True)
        os.makedirs(self.journal_dir, exist_ok=True)
        os.makedirs(self.checkpoints_dir, exist_ok=True)
        self.hits = 0
        self.misses = 0

    # -- plan tier -----------------------------------------------------
    def _plan_path(self, req: dict) -> str:
        return os.path.join(self.plans_dir, request_key(req) + ".json")

    def lookup(self, req: dict) -> Optional[TuneResult]:
        obj = _load_json(
            self._plan_path(req),
            lambda o: all(k in o["result"] for k in _REQUIRED_RESULT),
        )
        if obj is None:
            self.misses += 1
            return None
        self.hits += 1
        return _result_from_dict(obj["result"])

    def seed_plans(
        self,
        arch: Optional[str] = None,
        shape: Optional[str] = None,
        mesh: Optional[str] = None,
        limit: int = 16,
    ):
        """Every stored plan matching the cell filters, decoded — the
        evolutionary backend's warm-start population (any algo/seed/budget
        qualifies: a good plan for the cell is a good seed regardless of
        which searcher found it).  Files are scanned in sorted filename
        order through the validating loader, so the result is
        deterministic for a given store state and corrupt entries are
        quarantined rather than crashing the seeding pass."""
        out = []
        for fname in sorted(os.listdir(self.plans_dir)):
            if not fname.endswith(".json"):
                continue
            obj = _load_json(
                os.path.join(self.plans_dir, fname),
                lambda o: all(k in o["result"] for k in _REQUIRED_RESULT),
            )
            if obj is None:
                continue
            req = obj.get("request") or {}
            if arch is not None and req.get("arch") != arch:
                continue
            if shape is not None and req.get("shape") != shape:
                continue
            if mesh is not None and req.get("mesh") != mesh:
                continue
            try:
                out.append(SchedulePlan.from_dict(obj["result"]["plan"]))
            except (KeyError, TypeError, ValueError):
                continue
            if len(out) >= limit:
                break
        return out

    def record(self, req: dict, res: TuneResult) -> None:
        if res.plan is None:
            return  # an aborted run is not knowledge worth persisting
        if (res.stats or {}).get("interrupted"):
            # a deadline/cancel best-so-far is a PARTIAL answer — recording
            # it would serve it to every future request for this key; the
            # round-boundary checkpoint (not the plan tier) carries the
            # interrupted run's progress
            return
        _write_json(self._plan_path(req), {
            "version": STORE_VERSION,
            "request": req,
            "result": _result_to_dict(res),
        })

    # -- cell tier -----------------------------------------------------
    def _cell_path(self, ckey: str) -> str:
        return os.path.join(self.cells_dir, ckey + ".json")

    def _load_cell_tables(self, ckey: str):
        obj = _load_json(
            self._cell_path(ckey),
            lambda o: all(isinstance(o[k], list) for k in
                          ("terminal", "partial",
                           "terminal_version", "partial_version")),
        )
        if obj is None:
            return None
        return (
            _decode_tbl(obj["terminal"]),
            _decode_tbl(obj["partial"]),
            _decode_tbl(obj["terminal_version"]),
            _decode_tbl(obj["partial_version"]),
        )

    def warm_cell(self, ckey: str, cache: TranspositionCache,
                  include_learned: bool = False) -> int:
        """Load the stored cell state into ``cache``; returns the number
        of entries applied.  Exact-only by default (see module doc)."""
        tables = self._load_cell_tables(ckey)
        if tables is None:
            return 0
        t, p, tv, pv = tables
        if not include_learned:
            t = {k: v for k, v in t.items() if k not in tv}
            p = {k: v for k, v in p.items() if k not in pv}
            tv, pv = {}, {}
        cache.apply_export((t, p, tv, pv))
        return len(t) + len(p)

    def sync_cell(self, ckey: str, cache: TranspositionCache,
                  wm: Optional[Watermark]) -> Watermark:
        """Merge ``cache``'s entries since ``wm`` into the stored cell
        state and publish atomically; returns the new watermark.  Merge-
        on-write: the CURRENT disk state is re-read and the delta folded
        into it under exact-wins, so two daemons writing the same cell
        converge (the ``os.replace`` race loser's delta rides its next
        sync)."""
        new_wm = cache.watermark()
        entries, _full = cache.export_since(wm)
        scratch = TranspositionCache()
        tables = self._load_cell_tables(ckey)
        if tables is not None:
            t, p, tv, pv = tables
            scratch.apply_export((t, p, tv, pv))
        scratch.apply_export(entries)
        _write_json(self._cell_path(ckey), {
            "version": STORE_VERSION,
            "terminal": _encode_tbl(scratch.terminal),
            "partial": _encode_tbl(scratch.partial),
            "terminal_version": _encode_tbl(scratch.terminal_version),
            "partial_version": _encode_tbl(scratch.partial_version),
        })
        return new_wm

    # -- journal tier (write-ahead request log) ------------------------
    # A request is journaled BEFORE its search starts and released only
    # after its result landed in the plan tier (or was answered on an
    # error/interrupt path).  A daemon that died mid-search therefore
    # leaves a pending entry behind; ``TunerService.recover`` replays
    # those on restart, resuming from the checkpoint tier.
    def _journal_path(self, req: dict) -> str:
        return os.path.join(self.journal_dir, request_key(req) + ".json")

    def journal_begin(self, req: dict) -> None:
        _write_json(self._journal_path(req), {
            "version": STORE_VERSION,
            "request": req,
            "state": "pending",
        })

    def journal_release(self, req: dict) -> None:
        try:
            os.remove(self._journal_path(req))
        except OSError:
            pass

    def pending_requests(self) -> List[dict]:
        """Validated scan of the journal, sorted by filename (so replay
        order is deterministic); corrupt entries quarantine like every
        other tier."""
        out = []
        for fname in sorted(os.listdir(self.journal_dir)):
            if not fname.endswith(".json"):
                continue
            obj = _load_json(
                os.path.join(self.journal_dir, fname),
                lambda o: isinstance(o["request"], dict)
                and o["state"] == "pending",
            )
            if obj is not None:
                out.append(obj["request"])
        return out

    def sweep_tmp(self) -> int:
        """Remove tmp-sibling debris left by writers that died mid-write
        (a SIGKILL between ``open(tmp)`` and ``os.replace`` orphans the
        tmp file forever — the atomic publish means the TIER is clean,
        but the directory isn't).  Tmp names embed the writer's pid, so
        a file whose writer is still alive (another daemon sharing this
        store, mid-publish right now) is left alone.  Called from the
        daemon's crash ``recover()``; returns the number removed."""
        n = 0
        for d in (self.plans_dir, self.cells_dir, self.journal_dir,
                  self.checkpoints_dir):
            for fname in os.listdir(d):
                parts = fname.rsplit(".tmp.", 1)
                if len(parts) != 2:
                    continue
                pid = parts[1].split(".", 1)[0]
                try:
                    os.kill(int(pid), 0)
                    continue  # writer still alive: in-flight publish
                except ValueError:
                    pass  # malformed pid: debris
                except ProcessLookupError:
                    pass  # writer is gone: debris
                except PermissionError:
                    continue  # pid exists under another uid: leave it
                try:
                    os.remove(os.path.join(d, fname))
                    n += 1
                except OSError:
                    pass
        return n

    # -- checkpoint tier (round-boundary search snapshots) -------------
    # Pickle, not JSON: a ``ProTuner.snapshot()`` carries live tree
    # objects (numpy stat arrays, ``random.Random`` state).  Same publish
    # discipline as every tier: tmp-sibling + ``os.replace``, so a
    # SIGKILL mid-write can never publish a torn file; unpicklable or
    # schema-violating checkpoints are quarantined on read and the run
    # simply starts fresh.
    def _checkpoint_path(self, req: dict) -> str:
        return os.path.join(self.checkpoints_dir, request_key(req) + ".pkl")

    def save_checkpoint(self, req: dict, snap: dict) -> None:
        path = self._checkpoint_path(req)
        tmp = f"{path}.tmp.{os.getpid()}.{uuid.uuid4().hex[:8]}"
        try:
            with open(tmp, "wb") as f:
                pickle.dump({
                    "version": STORE_VERSION,
                    "request": req,
                    "snapshot": snap,
                }, f)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)

    def load_checkpoint(self, req: dict) -> Optional[dict]:
        path = self._checkpoint_path(req)
        try:
            with open(path, "rb") as f:
                obj = pickle.load(f)
        except FileNotFoundError:
            return None
        except Exception:  # noqa: BLE001 - any unpickling failure quarantines
            obj = None
        if (isinstance(obj, dict) and obj.get("version") == STORE_VERSION
                and isinstance(obj.get("snapshot"), dict)):
            return obj["snapshot"]
        try:
            os.remove(path)
        except OSError:
            pass
        return None

    def clear_checkpoint(self, req: dict) -> None:
        try:
            os.remove(self._checkpoint_path(req))
        except OSError:
            pass

    # -- stats ---------------------------------------------------------
    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "stored_plans": len(os.listdir(self.plans_dir)),
            "stored_cells": len(os.listdir(self.cells_dir)),
            "pending_journal": len(os.listdir(self.journal_dir)),
            "stored_checkpoints": len(os.listdir(self.checkpoints_dir)),
        }
