"""AdamW from scratch (pure JAX) with optional int8-quantized moments.

Int8 moments (rowwise symmetric, dequant→update→requant each step) cut
optimizer-state HBM from 8 to 2 bytes/param — this is what lets the 398B
Jamba config fit a single 256-chip v5e pod (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # float32 | int8


def lr_at(oc: OptimizerConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / max(oc.warmup_steps, 1)
    prog = jnp.clip(
        (s - oc.warmup_steps) / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return oc.peak_lr * jnp.where(s < oc.warmup_steps, warm, cos)


# -- int8 moment codecs -------------------------------------------------------
def _quantizable(leaf: jax.Array) -> bool:
    return leaf.ndim >= 2 and leaf.shape[-1] >= 16


def _mom_zero(leaf: jax.Array, oc: OptimizerConfig):
    if oc.moment_dtype == "int8" and _quantizable(leaf):
        return {
            "q": jnp.zeros(leaf.shape, jnp.int8),
            "s": jnp.zeros(leaf.shape[:-1] + (1,), jnp.float32),
        }
    return jnp.zeros(leaf.shape, jnp.float32)


def _mom_read(m) -> jax.Array:
    if isinstance(m, dict):
        return m["q"].astype(jnp.float32) * m["s"]
    return m


def _mom_write(val: jax.Array, like) :
    if isinstance(like, dict):
        amax = jnp.max(jnp.abs(val), axis=-1, keepdims=True)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(val / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "s": scale}
    return val


# -- public API ---------------------------------------------------------------
def init_opt_state(params, oc: OptimizerConfig) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(lambda p: _mom_zero(p, oc), params)
    return {"mu": zeros(), "nu": zeros(), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)
    )
    return jnp.sqrt(sq)


def apply_updates(
    params, grads, state, oc: OptimizerConfig
) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1
    lr = lr_at(oc, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / (gnorm + 1e-9))
    bc1 = 1.0 - oc.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - oc.b2 ** step.astype(jnp.float32)

    is_moment = lambda x: isinstance(x, dict) and set(x) == {"q", "s"}

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        m = oc.b1 * _mom_read(mu) + (1 - oc.b1) * g
        v = oc.b2 * _mom_read(nu) + (1 - oc.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + oc.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, _mom_write(m, mu), _mom_write(v, nu)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, mu, nu) for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics


def opt_state_pspecs(state, param_pspecs):
    """Optimizer-state PartitionSpecs mirroring the param specs."""
    from jax.sharding import PartitionSpec as P

    def mom_spec(mspec):
        def f(m, pspec=mspec):
            return pspec

        return f

    def per_moment(mom_tree):
        flat_m, treedef = jax.tree.flatten(
            mom_tree, is_leaf=lambda x: isinstance(x, dict) and set(x) == {"q", "s"}
        )
        flat_spec = treedef.flatten_up_to(param_pspecs)
        out = []
        for m, spec in zip(flat_m, flat_spec):
            if isinstance(m, dict):
                out.append({"q": spec, "s": P(*spec[:-1], None)})
            else:
                out.append(spec)
        return treedef.unflatten(out)

    return {
        "mu": per_moment(state["mu"]),
        "nu": per_moment(state["nu"]),
        "step": P(),
    }
