"""Int8 error-feedback ring all-reduce for DP gradients (shard_map).

Wire cost: a bf16/f32 ring all-reduce moves ~2·size·dtype bytes per device;
the int8 ring reduce-scatter + all-gather moves ~2·size·1 byte — a 4–8×
reduction on the DP axis, which matters on the multi-pod mesh where the DP
collective crosses the (slow) pod links.  Error feedback keeps the
quantization noise unbiased across steps: the residual (g - dequant(q)) is
carried and added to the next step's gradient.

This is one of the schedule-space actions (``grad_comm = int8``); it is also
independently property-tested (tests/test_grad_compress.py).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s


def _ring_allreduce_int8(x: jax.Array, axis: str) -> jax.Array:
    """All-reduce a (rows, cols) f32 array with int8 payload on the wire.

    Ring reduce-scatter (n-1 ppermute steps of int8 chunks) followed by an
    int8 ring all-gather.  Chunking is along rows; rows must divide by the
    axis size (callers pad).
    """
    n = jax.lax.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    rows = x.shape[0]
    chunk = rows // n
    perm_fwd = [(i, (i + 1) % n) for i in range(n)]

    def get_chunk(arr, c):
        return jax.lax.dynamic_slice_in_dim(arr, c * chunk, chunk, axis=0)

    # ---- reduce-scatter: after n-1 steps, device i owns the full sum of
    # chunk (i+1) % n ----
    def rs_body(step, carry):
        acc_q, acc_s = carry  # the in-flight chunk, quantized
        recv_q = jax.lax.ppermute(acc_q, axis, perm_fwd)
        recv_s = jax.lax.ppermute(acc_s, axis, perm_fwd)
        # chunk index this device must add at this step
        c = (idx - step - 1) % n
        local = get_chunk(x, c)
        summed = _dequant(recv_q, recv_s) + local
        q, s = _quant(summed)
        return q, s

    q0, s0 = _quant(get_chunk(x, idx))  # first hop carries our own chunk
    acc_q, acc_s = jax.lax.fori_loop(0, n - 1, rs_body, (q0, s0))
    # device i now owns reduced chunk (i + 1) % n
    own = (idx + 1) % n

    # ---- all-gather the reduced chunks (n-1 int8 hops) ----
    def ag_body(step, carry):
        out, cur_q, cur_s = carry
        c = (own - step) % n  # chunk id currently held
        out = jax.lax.dynamic_update_slice_in_dim(
            out, _dequant(cur_q, cur_s), c * chunk, axis=0
        )
        cur_q = jax.lax.ppermute(cur_q, axis, perm_fwd)
        cur_s = jax.lax.ppermute(cur_s, axis, perm_fwd)
        return out, cur_q, cur_s

    out = jnp.zeros_like(x)
    out, last_q, last_s = jax.lax.fori_loop(
        0, n, ag_body, (out, acc_q, acc_s)
    )
    return out


def compressed_psum(
    x: jax.Array, axis: str, *, error: jax.Array | None = None
) -> Tuple[jax.Array, jax.Array]:
    """Inside shard_map: int8-wire all-reduce with error feedback.

    Returns (reduced, new_error). ``x`` is flattened to (rows, 128) lanes.
    """
    n = jax.lax.axis_size(axis)
    flat = x.astype(jnp.float32).reshape(-1)
    if error is not None:
        flat = flat + error.reshape(-1)
    cols = 128
    pad = (-flat.size) % (cols * n)
    fp = jnp.pad(flat, (0, pad)).reshape(-1, cols)
    # pad rows to divide by n
    rpad = (-fp.shape[0]) % n
    fp = jnp.pad(fp, ((0, rpad), (0, 0)))
    reduced = _ring_allreduce_int8(fp, axis)
    # error feedback: local contribution actually transmitted vs intended
    sent_q, sent_s = _quant(fp)
    new_err = (fp - _dequant(sent_q, sent_s)).reshape(-1)
    total = fp.size
    reduced = reduced.reshape(-1)[: flat.size].reshape(x.shape)
    new_err = new_err[: flat.size].reshape(x.shape)
    return reduced.astype(x.dtype), new_err.astype(jnp.float32)


def make_compressed_allreduce(mesh: Mesh, axis: str = "data"):
    """Tree-level compressed all-reduce: grads replicated-out over `axis`."""

    def _one(g, e):
        return compressed_psum(g, axis, error=e)

    @functools.partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
    )
    def _sm(gs, es):  # leaves stacked on axis 0 per-device
        out, err = _one(gs, es)
        return out, err

    def allreduce(grads_tree, error_tree):
        return jax.tree.map(
            lambda g, e: _sm(g, e), grads_tree, error_tree
        )

    return allreduce
