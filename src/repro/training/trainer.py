"""Training loop: data pipeline + train step + checkpoints + fault tolerance.

Single-process reference loop (the multi-host deployment wires the same
object to per-host pipelines and the pod coordinator's heartbeat stream —
all decisions below are host-side control-plane logic, identical at fleet
scale).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import Checkpointer
from repro.configs.base import InputShape, ModelConfig
from repro.core.space import SchedulePlan
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import transformer
from repro.runtime.fault_tolerance import (
    HeartbeatMonitor,
    StragglerPolicy,
    plan_restart,
    rebalance,
)
from repro.training import optimizer as optim
from repro.training.train_step import make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_async: bool = True
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        shape: InputShape,
        plan: SchedulePlan,
        tc: TrainerConfig = TrainerConfig(),
        opt_cfg: Optional[optim.OptimizerConfig] = None,
        data_cfg: DataConfig = DataConfig(),
        mesh=None,
        mesh_spec=None,
    ):
        self.cfg, self.shape, self.plan, self.tc = cfg, shape, plan, tc
        self.opt_cfg = opt_cfg or optim.OptimizerConfig(
            total_steps=tc.total_steps, moment_dtype=plan.opt_dtype
        )
        self.pipe = Pipeline(cfg, shape, data_cfg)
        self.ckpt = Checkpointer(tc.ckpt_dir)
        self.step_fn = jax.jit(
            make_train_step(cfg, shape, plan, self.opt_cfg, mesh, mesh_spec)
        )
        self.metrics_log: List[Dict] = []
        self.monitor: Optional[HeartbeatMonitor] = None
        self.stragglers = StragglerPolicy()

    # -- state ------------------------------------------------------------------
    def init_state(self):
        params = transformer.init_params(self.cfg, jax.random.PRNGKey(self.tc.seed))
        opt_state = optim.init_opt_state(params, self.opt_cfg)
        return params, opt_state, 0

    def restore_or_init(self):
        params, opt_state, step = self.init_state()
        if self.ckpt.latest_step() is not None:
            params, opt_state, step, _ = self.ckpt.restore(params, opt_state)
        return params, opt_state, step

    # -- loop --------------------------------------------------------------------
    def run(self, params=None, opt_state=None, start_step: Optional[int] = None):
        if params is None:
            params, opt_state, start_step = self.restore_or_init()
        step = start_step or 0
        host = f"host{self.pipe.dc.host_index}"
        while step < self.tc.total_steps:
            t0 = time.perf_counter()
            batch = {
                k: jnp.asarray(v) for k, v in self.pipe.batch_at(step).items()
            }
            params, opt_state, m = self.step_fn(params, opt_state, batch)
            jax.block_until_ready(m)  # honest step timing (async dispatch)
            dt = time.perf_counter() - t0
            self.stragglers.observe(host, dt)
            if self.monitor is not None:
                self.monitor.beat(host)
            step += 1
            if step % self.tc.log_every == 0 or step == 1:
                rec = {
                    "step": step,
                    "loss": float(m["loss"]),
                    "grad_norm": float(m["grad_norm"]),
                    "lr": float(m["lr"]),
                    "step_time_s": dt,
                }
                self.metrics_log.append(rec)
            if step % self.tc.ckpt_every == 0:
                self.ckpt.save(
                    step, params, opt_state,
                    extra={"data_step": step},
                    blocking=not self.tc.ckpt_async,
                )
        self.ckpt.wait()
        return params, opt_state, step

    # -- failure handling (exercised by tests and the fleet coordinator) ---------
    def handle_failure(self, alive_hosts, chips_per_host: int, model_parallel: int):
        """On node loss: build the elastic restart plan from the last
        checkpoint; the data pipeline's stateless indexing makes the
        re-sharded resume exact."""
        latest = self.ckpt.latest_step() or 0
        return plan_restart(
            alive_hosts,
            chips_per_host,
            model_parallel,
            latest,
            self.shape.global_batch,
        )
