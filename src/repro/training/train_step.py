"""Builders for the jit-able train / prefill / decode step functions.

``make_train_step`` is THE function the dry-run lowers and the autotuner's
real-measurement compiles: everything the SchedulePlan decides (sharding,
remat, microbatches, kernel tiles, optimizer dtype) is threaded through here.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.core.space import MeshSpec, SchedulePlan
from repro.kernels.ops import KernelTiles
from repro.models import transformer
from repro.models.losses import cross_entropy
from repro.models.moe import MoEDist
from repro.sharding.rules import ShardingRules, make_shard_fn
from repro.training import optimizer as optim


def tiles_from_plan(plan: SchedulePlan) -> KernelTiles:
    return KernelTiles(
        attn_block_q=plan.attn_block[0],
        attn_block_kv=plan.attn_block[1],
        scan_chunk=plan.scan_chunk,
    )


def moe_dist_for(cfg, shape, plan, mesh, mesh_spec) -> Optional[MoEDist]:
    """shard_map EP context when the plan asks for expert parallelism and the
    batch can shard over the data axes (see models/moe.py).

    REPRO_DISABLE_MOE_SHARDMAP=1 falls back to the jit global-sort dispatch
    (the §Perf baseline measurement path)."""
    import os

    if os.environ.get("REPRO_DISABLE_MOE_SHARDMAP"):
        return None
    if not (cfg.is_moe and plan.moe_mode == "ep" and mesh is not None and mesh_spec):
        return None
    if plan.param_strategy not in ("tp", "fsdp_tp", "tp2d"):
        return None
    if plan.batch_axes == "pod_data" and mesh_spec.multi_pod:
        batch_axes = ("pod", "data")
    else:
        batch_axes = ("data",)
    dp = 1
    for a in batch_axes:
        dp *= mesh_spec.axis(a)
    if shape.global_batch % dp != 0:
        return None
    if cfg.n_experts % min(mesh_spec.axis("model"), cfg.n_experts) != 0:
        return None
    return MoEDist(
        mesh=mesh,
        data_axes=batch_axes,
        fsdp=plan.param_strategy in ("fsdp_tp", "tp2d"),
    )


def make_positions(cfg: ModelConfig, batch: int, seq: int) -> jax.Array:
    if cfg.pos_kind == "mrope":
        return jnp.broadcast_to(
            jnp.arange(seq, dtype=jnp.int32)[None, None, :], (batch, 3, seq)
        )
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None, :], (batch, seq))


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
def make_train_step(
    cfg: ModelConfig,
    shape: InputShape,
    plan: SchedulePlan,
    opt_cfg: Optional[optim.OptimizerConfig] = None,
    mesh: Optional[Mesh] = None,
    mesh_spec: Optional[MeshSpec] = None,
    unroll: bool = False,
) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``batch``: {"inputs": (B,S) or (B,S,d), "labels": (B,S), "positions": ...}
    ``unroll``: fully unroll layer/microbatch loops (dry-run FLOP accounting;
    see transformer.forward).
    """
    opt_cfg = opt_cfg or optim.OptimizerConfig(
        moment_dtype=plan.opt_dtype if plan.opt_dtype != "float32" else "float32"
    )
    tiles = tiles_from_plan(plan)
    rules = ShardingRules(cfg, shape, plan, mesh_spec) if mesh_spec else None
    shard = make_shard_fn(mesh, rules)
    moe_dist = moe_dist_for(cfg, shape, plan, mesh, mesh_spec)
    n_mb = plan.microbatches

    def loss_fn(params, inputs, labels, positions):
        logits = transformer.forward(
            params,
            cfg,
            inputs,
            positions,
            tiles=tiles,
            shard=shard,
            remat=plan.remat,
            unroll=unroll,
            moe_dist=moe_dist,
        )
        return cross_entropy(logits[:, :-1, :], labels[:, 1:])

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, batch):
        inputs, labels = batch["inputs"], batch["labels"]
        positions = batch["positions"]
        if n_mb > 1:
            B = inputs.shape[0]
            assert B % n_mb == 0, (B, n_mb)
            mb = B // n_mb
            r = lambda x: x.reshape((n_mb, mb) + x.shape[1:])
            mb_batches = (r(inputs), r(labels), r(positions))

            def acc_body(carry, xs):
                loss_acc, grads_acc = carry
                i, l, p = xs
                loss, grads = grad_fn(params, i, l, p)
                grads_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grads_acc, grads
                )
                return (loss_acc + loss, grads_acc), None

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss_sum, grads), _ = jax.lax.scan(
                acc_body,
                (jnp.zeros((), jnp.float32), zero_grads),
                mb_batches,
                unroll=n_mb if unroll else 1,
            )
            loss = loss_sum / n_mb
            grads = jax.tree.map(lambda g: g / n_mb, grads)
        else:
            loss, grads = grad_fn(params, inputs, labels, positions)

        if plan.grad_comm == "int8":
            # fake-quant on the DP-reduced gradient: preserves the numerics of
            # the compressed collective; the wire-level int8 ring lives in
            # training/grad_compress.py (shard_map) for pure-DP plans.
            grads = jax.tree.map(_fake_quant_rowwise, grads)

        params, opt_state, opt_metrics = optim.apply_updates(
            params, grads, opt_state, opt_cfg
        )
        metrics = {"loss": loss, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def _fake_quant_rowwise(g: jax.Array) -> jax.Array:
    if g.ndim < 2 or g.shape[-1] < 16:
        return g
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    return (jnp.round(gf / scale).clip(-127, 127) * scale).astype(g.dtype)


# ---------------------------------------------------------------------------
# Serve steps (prefill / decode)
# ---------------------------------------------------------------------------
def make_prefill_step(
    cfg: ModelConfig,
    shape: InputShape,
    plan: SchedulePlan,
    mesh: Optional[Mesh] = None,
    mesh_spec: Optional[MeshSpec] = None,
    unroll: bool = False,
) -> Callable:
    """(params, batch) -> logits for the full prompt (inference forward)."""
    tiles = tiles_from_plan(plan)
    rules = ShardingRules(cfg, shape, plan, mesh_spec) if mesh_spec else None
    shard = make_shard_fn(mesh, rules)

    moe_dist = moe_dist_for(cfg, shape, plan, mesh, mesh_spec)

    def prefill_step(params, batch):
        return transformer.forward(
            params,
            cfg,
            batch["inputs"],
            batch["positions"],
            tiles=tiles,
            shard=shard,
            remat="none",
            unroll=unroll,
            moe_dist=moe_dist,
        )

    return prefill_step


def make_serve_step(
    cfg: ModelConfig,
    shape: InputShape,
    plan: SchedulePlan,
    mesh: Optional[Mesh] = None,
    mesh_spec: Optional[MeshSpec] = None,
    unroll: bool = False,
) -> Callable:
    """(params, cache, inputs, cur) -> (logits, cache): one decode token."""
    tiles = tiles_from_plan(plan)
    rules = ShardingRules(cfg, shape, plan, mesh_spec) if mesh_spec else None
    shard = make_shard_fn(mesh, rules)

    moe_dist = moe_dist_for(cfg, shape, plan, mesh, mesh_spec)

    def serve_step(params, cache, inputs, cur):
        return transformer.decode_step(
            params, cfg, cache, inputs, cur, tiles=tiles, shard=shard,
            unroll=unroll, moe_dist=moe_dist,
        )

    return serve_step


# ---------------------------------------------------------------------------
# Sharding entries for jit(in_shardings/out_shardings)
# ---------------------------------------------------------------------------
def shardings_for_train(
    cfg, shape, plan, mesh: Mesh, mesh_spec: MeshSpec, params, opt_state
):
    rules = ShardingRules(cfg, shape, plan, mesh_spec)
    pspecs = rules.param_pspecs(params)
    ospecs = optim.opt_state_pspecs(opt_state, pspecs)
    ns = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    batch_specs = {
        "inputs": rules.batch_spec(3 if cfg.input_kind == "embeddings" else 2),
        "labels": rules.batch_spec(2),
        "positions": rules.batch_spec(3 if cfg.pos_kind == "mrope" else 2),
    }
    return ns(pspecs), ns(ospecs), ns(batch_specs), rules
