"""Serving engine: prefill + batched decode with continuous-batching slots.

The engine keeps a fixed batch of decode slots (static shapes → one compiled
``serve_step``); finished sequences release their slot and the next queued
request is prefix-filled into it.  Mamba/hybrid archs carry conv+SSM state
instead of (or alongside) KV cache — the cache pytree comes from
``transformer.init_cache`` and is opaque here.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.space import SchedulePlan
from repro.models import transformer
from repro.training.train_step import make_serve_step, tiles_from_plan


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (prompt_len,) int32 token ids
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        batch_slots: int = 4,
        max_len: int = 128,
        plan: Optional[SchedulePlan] = None,
        greedy: bool = True,
        seed: int = 0,
    ):
        assert cfg.input_kind == "tokens", "engine drives token-input archs"
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.plan = plan or SchedulePlan()
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.cache = transformer.init_cache(cfg, batch_slots, max_len)
        self.tokens = np.zeros((batch_slots,), np.int32)
        self.lengths = np.zeros((batch_slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._uid = 0

        tiles = tiles_from_plan(self.plan)
        step = make_serve_step(cfg, None, self.plan)

        @jax.jit
        def _decode(params, cache, tokens, cur, mask):
            # cur: (B,) per-slot positions — every slot reads/writes its OWN
            # length, so requests of different lengths can share the batch.
            # mask: (B,) bool — only masked slots' cache entries (KV rows,
            # conv/SSM state) are committed; the rest keep their old state,
            # so a prefill feed for one slot can never clobber its
            # neighbours' caches.
            logits, new_cache = step(params, cache, tokens[:, None], cur)
            new_cache = jax.tree_util.tree_map(
                lambda new, old: jnp.where(
                    mask.reshape((1, -1) + (1,) * (new.ndim - 2)), new, old
                ),
                new_cache,
                cache,
            )
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok, new_cache

        @jax.jit
        def _reset_slot(cache, slot):
            # zero one slot's cache state on (re)assignment: stale KV past
            # the new request's length is masked by position anyway, but
            # mamba/hybrid conv+SSM state is NOT position-addressed — a new
            # request must not inherit the previous occupant's state
            return jax.tree_util.tree_map(
                lambda c: c.at[:, slot].set(jnp.zeros_like(c[:, slot])), cache
            )

        self._decode = _decode
        self._reset_slot = _reset_slot

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32), max_new_tokens))
        return self._uid

    def run(self, max_steps: int = 1000) -> List[Request]:
        """Drive until queue + slots drain (or max_steps).

        Returns THIS call's completions only (not the engine-lifetime
        accumulation) — requests still in flight when ``max_steps``
        exhausts stay active and finish on the next ``run``; check
        ``pending()`` for the still-active/queued counts."""
        n0 = len(self.finished)
        for _ in range(max_steps):
            self._fill_slots()
            if all(r is None for r in self.active):
                break
            self._step()
        return self.finished[n0:]

    def pending(self) -> dict:
        """Requests not yet completed: in-slot actives and queued waiters."""
        return {
            "active": sum(r is not None for r in self.active),
            "queued": len(self.queue),
        }

    # -- internals -----------------------------------------------------------------
    def _fill_slots(self):
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                self.cache = self._reset_slot(self.cache, i)
                # sequential prompt feed (prefill via decode steps keeps the
                # engine single-kernel; bulk prefill uses make_prefill_step)
                self.lengths[i] = 0
                for t in req.prompt[:-1]:
                    self.tokens[i] = t
                    self._single_feed(i)
                self.tokens[i] = req.prompt[-1]

    def _single_feed(self, slot: int):
        # prefill one token for ONE slot: per-slot positions plus a one-hot
        # commit mask — other slots' KV/state are untouched (pre-fix, this
        # decoded the full batch at the new slot's position and clobbered
        # every active neighbour's cache)
        mask = np.zeros((self.slots,), bool)
        mask[slot] = True
        _, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.lengths), jnp.asarray(mask),
        )
        self.lengths[slot] += 1

    def _step(self):
        # one decode step for every ACTIVE slot at its own position
        # (pre-fix: one shared cur = lengths.max() wrote every slot's KV at
        # the longest slot's position)
        mask = np.array([r is not None for r in self.active], bool)
        next_tok, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.lengths), jnp.asarray(mask),
        )
        next_np = np.asarray(next_tok)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            req.generated.append(int(next_np[i]))
            self.tokens[i] = next_np[i]
            self.lengths[i] += 1
            if (
                len(req.generated) >= req.max_new_tokens
                or self.lengths[i] >= self.max_len - 1
            ):
                req.done = True
                self.finished.append(req)
                self.active[i] = None
