"""Serving engine: prefill + batched decode with continuous-batching slots.

The engine keeps a fixed batch of decode slots (static shapes → one compiled
``serve_step``); finished sequences release their slot and the next queued
request is prefix-filled into it.  Mamba/hybrid archs carry conv+SSM state
instead of (or alongside) KV cache — the cache pytree comes from
``transformer.init_cache`` and is opaque here.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.space import SchedulePlan
from repro.models import transformer
from repro.training.train_step import make_serve_step, tiles_from_plan


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (prompt_len,) int32 token ids
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        batch_slots: int = 4,
        max_len: int = 128,
        plan: Optional[SchedulePlan] = None,
        greedy: bool = True,
        seed: int = 0,
    ):
        assert cfg.input_kind == "tokens", "engine drives token-input archs"
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.plan = plan or SchedulePlan()
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.cache = transformer.init_cache(cfg, batch_slots, max_len)
        self.tokens = np.zeros((batch_slots,), np.int32)
        self.lengths = np.zeros((batch_slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._uid = 0

        tiles = tiles_from_plan(self.plan)
        step = make_serve_step(cfg, None, self.plan)

        @jax.jit
        def _decode(params, cache, tokens, cur):
            logits, cache = step(params, cache, tokens[:, None], cur)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok, cache

        self._decode = _decode

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32), max_new_tokens))
        return self._uid

    def run(self, max_steps: int = 1000) -> List[Request]:
        """Drive until queue + slots drain (or max_steps)."""
        for _ in range(max_steps):
            self._fill_slots()
            if all(r is None for r in self.active):
                break
            self._step()
        return self.finished

    # -- internals -----------------------------------------------------------------
    def _fill_slots(self):
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                # sequential prompt feed (prefill via decode steps keeps the
                # engine single-kernel; bulk prefill uses make_prefill_step)
                self.lengths[i] = 0
                for t in req.prompt[:-1]:
                    self.tokens[i] = t
                    self._single_feed(i)
                self.tokens[i] = req.prompt[-1]

    def _single_feed(self, slot: int):
        cur = jnp.int32(int(self.lengths[slot]))
        toks = jnp.asarray(self.tokens)
        _, self.cache = self._decode(self.params, self.cache, toks, cur)
        self.lengths[slot] += 1

    def _step(self):
        cur = jnp.int32(int(self.lengths.max()))
        toks = jnp.asarray(self.tokens)
        next_tok, self.cache = self._decode(self.params, self.cache, toks, cur)
        next_np = np.asarray(next_tok)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            req.generated.append(int(next_np[i]))
            self.tokens[i] = next_np[i]
            self.lengths[i] += 1
            if (
                len(req.generated) >= req.max_new_tokens
                or self.lengths[i] >= self.max_len - 1
            ):
                req.done = True
                self.finished.append(req)
                self.active[i] = None
