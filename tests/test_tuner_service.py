"""Tuner-as-a-service: PlanStore durability + daemon behaviour (XLA-free).

Covers the ISSUE-7 store contract: atomic-published entries quarantined
when corrupt, exact-wins convergence across writers, warm starts that
never change results, plan-tier hits with zero search, and the socket
protocol end to end (in-process server thread)."""
import json
import os
import threading

import pytest

from repro.core.autotuner import autotune
from repro.core.engine.cache import TranspositionCache
from repro.service import (
    PlanStore,
    TunerService,
    canonical_request,
    cell_key,
    serve_forever,
)
from repro.service.store import request_key

from conftest import TRAIN_CELL as CELL

REQ = dict(arch=CELL[0], shape=CELL[1], algo="mcts_1s", seed=0,
           n_standard=2, n_greedy=1)


def _service(tmp_path, **kw):
    kw.setdefault("log", lambda *a: None)
    return TunerService(str(tmp_path / "store"), **kw)


# ---------------------------------------------------------------------------
# Store tier: round-trip, quarantine, exact-wins
# ---------------------------------------------------------------------------
def test_plan_roundtrip_bit_identical(tmp_path):
    store = PlanStore(str(tmp_path / "store"))
    req = canonical_request(**REQ)
    assert store.lookup(req) is None
    res = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                   n_standard=2, n_greedy=1)
    store.record(req, res)
    hit = store.lookup(req)
    assert hit is not None and hit.from_store
    # JSON float round-trip is exact (shortest repr), so the stored
    # result is the original bit-for-bit
    assert hit.plan == res.plan
    assert hit.cost == res.cost
    assert hit.decisions == res.decisions


def test_request_key_excludes_execution_knobs():
    # engine/parallel/n_workers never reach the canonical request — the
    # engines are certified bit-identical, so one stored plan answers all
    a = canonical_request(**REQ)
    b = canonical_request(**REQ, engine="reference", parallel=True,
                          n_workers=7)
    assert request_key(a) == request_key(b)
    c = canonical_request(**dict(REQ, seed=1))
    assert request_key(a) != request_key(c)


def test_corrupt_plan_entry_quarantined(tmp_path):
    store = PlanStore(str(tmp_path / "store"))
    req = canonical_request(**REQ)
    path = store._plan_path(req)
    with open(path, "w") as f:
        f.write('{"version": 1, "result": {"cost"')  # torn write
    assert store.lookup(req) is None
    assert not os.path.exists(path)  # quarantined, not served forever
    # schema-violating but valid JSON is quarantined too
    with open(path, "w") as f:
        json.dump({"version": 1, "result": {}}, f)
    assert store.lookup(req) is None
    assert not os.path.exists(path)


def test_corrupt_cell_entry_quarantined(tmp_path):
    store = PlanStore(str(tmp_path / "store"))
    ckey = cell_key(canonical_request(**REQ))
    cache = TranspositionCache()
    cache.terminal[(1, 2, 3)] = 0.5
    store.sync_cell(ckey, cache, None)
    path = store._cell_path(ckey)
    with open(path, "w") as f:
        f.write('{"version": 1, "terminal": [[')  # truncated
    fresh = TranspositionCache()
    assert store.warm_cell(ckey, fresh) == 0
    assert fresh.n_entries == 0
    assert not os.path.exists(path)
    # and the next sync republishes cleanly from the in-memory cache
    store.sync_cell(ckey, cache, None)
    assert store.warm_cell(ckey, fresh) == 1
    assert fresh.terminal[(1, 2, 3)] == 0.5


def test_two_writers_converge_exact_wins(tmp_path):
    """Two daemons race on one cell: whatever the sync order, a learned
    prediction never shadows an exact analytic entry on disk."""
    store = PlanStore(str(tmp_path / "store"))
    ckey = "cafecafecafecafecafe"
    exact = TranspositionCache()
    exact.terminal[(0, 1)] = 0.5
    learned = TranspositionCache()
    learned.terminal[(0, 1)] = 0.9
    learned.terminal_version[(0, 1)] = 3
    learned.terminal[(0, 2)] = 0.7  # untagged entry unique to this writer

    for first, second in ((exact, learned), (learned, exact)):
        for f in os.listdir(store.cells_dir):
            os.remove(os.path.join(store.cells_dir, f))
        store.sync_cell(ckey, first, None)
        store.sync_cell(ckey, second, None)
        merged = TranspositionCache()
        store.warm_cell(ckey, merged, include_learned=True)
        assert merged.terminal[(0, 1)] == 0.5, "learned shadowed exact"
        assert (0, 1) not in merged.terminal_version
        assert merged.terminal[(0, 2)] == 0.7  # both writers' entries kept


def test_warm_start_excludes_learned_by_default(tmp_path):
    store = PlanStore(str(tmp_path / "store"))
    cache = TranspositionCache()
    cache.terminal[(0, 1)] = 0.5
    cache.terminal[(0, 2)] = 0.9
    cache.terminal_version[(0, 2)] = 4  # a model prediction
    store.sync_cell("k" * 20, cache, None)
    fresh = TranspositionCache()
    # an analytic run must only see exact entries (values change nothing,
    # so plan/cost/decisions stay bit-identical to a cold run)
    assert store.warm_cell("k" * 20, fresh) == 1
    assert fresh.terminal == {(0, 1): 0.5}
    both = TranspositionCache()
    assert store.warm_cell("k" * 20, both, include_learned=True) == 2
    assert both.terminal_version == {(0, 2): 4}


def test_sync_cell_is_incremental(tmp_path):
    store = PlanStore(str(tmp_path / "store"))
    cache = TranspositionCache()
    cache.terminal[(0,)] = 1.0
    wm = store.sync_cell("a" * 20, cache, None)
    cache.terminal[(1,)] = 2.0
    # second sync ships only the delta but the stored state keeps both
    store.sync_cell("a" * 20, cache, wm)
    fresh = TranspositionCache()
    assert store.warm_cell("a" * 20, fresh) == 2


# ---------------------------------------------------------------------------
# Daemon: plan-tier hits, warm cells, restart persistence
# ---------------------------------------------------------------------------
def test_repeat_request_is_store_hit_zero_search(tmp_path):
    svc = _service(tmp_path)
    out1 = svc.handle(dict(REQ))
    out2 = svc.handle(dict(REQ))
    assert out1["served"] == "search" and out2["served"] == "store"
    assert svc.n_searches == 1  # the repeat ran no search
    assert out2["result"]["from_store"]
    assert out2["result"]["plan"] == out1["result"]["plan"]
    assert out2["result"]["cost"] == out1["result"]["cost"]
    svc.shutdown()


def test_store_warm_starts_fresh_process(tmp_path):
    """A store populated by one service answers a FRESH service's repeat
    request with no search at all, and warm-starts the cell cache for a
    new (different-seed) request without changing its result."""
    svc1 = _service(tmp_path)
    out1 = svc1.handle(dict(REQ))
    svc1.shutdown()

    svc2 = _service(tmp_path)
    out2 = svc2.handle(dict(REQ))
    assert out2["served"] == "store" and svc2.n_searches == 0
    assert out2["result"]["plan"] == out1["result"]["plan"]

    # new seed on the same cell: searches, but from a warmed cache —
    # and the result matches a from-scratch run bit-for-bit
    out3 = svc2.handle(dict(REQ, seed=1))
    assert out3["served"] == "search"
    ckey = cell_key(canonical_request(**REQ))
    assert svc2.cells[ckey].cache.hits > 0  # the warm entries were used
    ref = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=1,
                   n_standard=2, n_greedy=1)
    assert out3["result"]["plan"] == ref.plan.to_dict()
    assert out3["result"]["cost"] == ref.cost
    assert out3["result"]["decisions"] == ref.decisions
    svc2.shutdown()


def test_socket_protocol_roundtrip(tmp_path):
    from repro.launch.tune_serve import TuneClient

    svc = _service(tmp_path)
    sock = str(tmp_path / "tuner.sock")
    t = threading.Thread(
        target=serve_forever, args=(svc, sock), kwargs={"max_requests": 2},
        daemon=True,
    )
    t.start()
    deadline = 50
    while not os.path.exists(sock) and deadline:
        deadline -= 1
        threading.Event().wait(0.1)
    client = TuneClient(sock)
    assert client.ping() == {"ok": True, "pong": True}
    out1 = client.tune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                       n_standard=2, n_greedy=1)
    assert out1["ok"] and out1["served"] == "search"
    out2 = client.tune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                       n_standard=2, n_greedy=1)
    assert out2["ok"] and out2["served"] == "store"
    assert out2["result"]["plan"] == out1["result"]["plan"]
    t.join(timeout=30)
    assert not t.is_alive()


def test_bad_request_never_kills_daemon(tmp_path):
    from repro.launch.tune_serve import TuneClient

    svc = _service(tmp_path)
    sock = str(tmp_path / "tuner.sock")
    t = threading.Thread(
        target=serve_forever, args=(svc, sock), kwargs={"max_requests": 1},
        daemon=True,
    )
    t.start()
    deadline = 50
    while not os.path.exists(sock) and deadline:
        deadline -= 1
        threading.Event().wait(0.1)
    client = TuneClient(sock)
    bad = client.call({"op": "tune", "arch": "no-such-arch", "shape": "x"})
    assert not bad["ok"] and "no-such-arch" in bad["error"]
    good = client.tune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                       n_standard=2, n_greedy=1)
    assert good["ok"]
    t.join(timeout=30)


# ---------------------------------------------------------------------------
# Shared pinned pool across runs
# ---------------------------------------------------------------------------
def test_shared_pool_reused_across_runs(tmp_path):
    svc = _service(tmp_path, parallel=True, n_workers=2)
    out1 = svc.handle(dict(REQ))
    pids = {w.proc.pid for w in svc.pool._workers}
    out2 = svc.handle(dict(REQ, seed=1))
    assert {w.proc.pid for w in svc.pool._workers} == pids
    assert svc.pool.n_worker_restarts == 0
    # parallel shared-pool results == sequential one-shot results
    for out, seed in ((out1, 0), (out2, 1)):
        ref = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=seed,
                       n_standard=2, n_greedy=1)
        assert out["result"]["plan"] == ref.plan.to_dict()
        assert out["result"]["cost"] == ref.cost
        assert out["result"]["decisions"] == ref.decisions
    svc.shutdown()
    assert svc.pool is None


def test_pool_rebind_direct():
    """PinnedWorkerPool.rebind repoints live workers at a new run's trees:
    same processes, same results as a fresh pool."""
    from repro.core.autotuner import make_mdp
    from repro.core.engine.cache import CachedMDP
    from repro.core.ensemble import ProTuner
    from repro.core.engine.workers import PinnedWorkerPool
    from repro.core.mcts import MCTSConfig

    mc = MCTSConfig(iters_per_decision=4)
    pool = PinnedWorkerPool([], CachedMDP(make_mdp(*CELL)), n_workers=2)
    assert len(pool._workers) == 2  # empty trees keep the requested width
    try:
        pids = {w.proc.pid for w in pool._workers}
        results = []
        for seed in (0, 1):
            tuner = ProTuner(CachedMDP(make_mdp(*CELL)), n_standard=2,
                             n_greedy=1, mcts_config=mc, seed=seed,
                             worker_pool=pool)
            results.append(tuner.run())
        assert {w.proc.pid for w in pool._workers} == pids
        for seed, res in zip((0, 1), results):
            ref = ProTuner(CachedMDP(make_mdp(*CELL)), n_standard=2,
                           n_greedy=1, mcts_config=mc, seed=seed).run()
            assert res.plan == ref.plan and res.cost == ref.cost
            assert [d["action"] for d in res.decisions] == [
                d["action"] for d in ref.decisions]
    finally:
        pool.shutdown()


# ---------------------------------------------------------------------------
# autotune(plan_store=) one-shot convenience
# ---------------------------------------------------------------------------
def test_autotune_plan_store_kwarg(tmp_path):
    store = PlanStore(str(tmp_path / "store"))
    res1 = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                    n_standard=2, n_greedy=1, plan_store=store)
    assert not res1.from_store
    res2 = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                    n_standard=2, n_greedy=1, plan_store=store)
    assert res2.from_store
    assert res2.plan == res1.plan and res2.cost == res1.cost
    assert store.stats()["hits"] == 1
