"""Tuner-as-a-service: PlanStore durability + daemon behaviour (XLA-free).

Covers the ISSUE-7 store contract: atomic-published entries quarantined
when corrupt, exact-wins convergence across writers, warm starts that
never change results, plan-tier hits with zero search, and the socket
protocol end to end (in-process server thread)."""
import json
import os
import threading

import pytest

from repro.core.autotuner import autotune
from repro.core.engine.cache import TranspositionCache
from repro.service import (
    PlanStore,
    TunerService,
    canonical_request,
    cell_key,
    serve_forever,
)
from repro.service.store import request_key

from conftest import TRAIN_CELL as CELL

REQ = dict(arch=CELL[0], shape=CELL[1], algo="mcts_1s", seed=0,
           n_standard=2, n_greedy=1)


def _service(tmp_path, **kw):
    kw.setdefault("log", lambda *a: None)
    return TunerService(str(tmp_path / "store"), **kw)


# ---------------------------------------------------------------------------
# Store tier: round-trip, quarantine, exact-wins
# ---------------------------------------------------------------------------
def test_plan_roundtrip_bit_identical(tmp_path):
    store = PlanStore(str(tmp_path / "store"))
    req = canonical_request(**REQ)
    assert store.lookup(req) is None
    res = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                   n_standard=2, n_greedy=1)
    store.record(req, res)
    hit = store.lookup(req)
    assert hit is not None and hit.from_store
    # JSON float round-trip is exact (shortest repr), so the stored
    # result is the original bit-for-bit
    assert hit.plan == res.plan
    assert hit.cost == res.cost
    assert hit.decisions == res.decisions


def test_request_key_excludes_execution_knobs():
    # engine/parallel/n_workers never reach the canonical request — the
    # engines are certified bit-identical, so one stored plan answers all
    a = canonical_request(**REQ)
    b = canonical_request(**REQ, engine="reference", parallel=True,
                          n_workers=7)
    assert request_key(a) == request_key(b)
    c = canonical_request(**dict(REQ, seed=1))
    assert request_key(a) != request_key(c)


def test_corrupt_plan_entry_quarantined(tmp_path):
    store = PlanStore(str(tmp_path / "store"))
    req = canonical_request(**REQ)
    path = store._plan_path(req)
    with open(path, "w") as f:
        f.write('{"version": 1, "result": {"cost"')  # torn write
    assert store.lookup(req) is None
    assert not os.path.exists(path)  # quarantined, not served forever
    # schema-violating but valid JSON is quarantined too
    with open(path, "w") as f:
        json.dump({"version": 1, "result": {}}, f)
    assert store.lookup(req) is None
    assert not os.path.exists(path)


def test_corrupt_cell_entry_quarantined(tmp_path):
    store = PlanStore(str(tmp_path / "store"))
    ckey = cell_key(canonical_request(**REQ))
    cache = TranspositionCache()
    cache.terminal[(1, 2, 3)] = 0.5
    store.sync_cell(ckey, cache, None)
    path = store._cell_path(ckey)
    with open(path, "w") as f:
        f.write('{"version": 1, "terminal": [[')  # truncated
    fresh = TranspositionCache()
    assert store.warm_cell(ckey, fresh) == 0
    assert fresh.n_entries == 0
    assert not os.path.exists(path)
    # and the next sync republishes cleanly from the in-memory cache
    store.sync_cell(ckey, cache, None)
    assert store.warm_cell(ckey, fresh) == 1
    assert fresh.terminal[(1, 2, 3)] == 0.5


def test_two_writers_converge_exact_wins(tmp_path):
    """Two daemons race on one cell: whatever the sync order, a learned
    prediction never shadows an exact analytic entry on disk."""
    store = PlanStore(str(tmp_path / "store"))
    ckey = "cafecafecafecafecafe"
    exact = TranspositionCache()
    exact.terminal[(0, 1)] = 0.5
    learned = TranspositionCache()
    learned.terminal[(0, 1)] = 0.9
    learned.terminal_version[(0, 1)] = 3
    learned.terminal[(0, 2)] = 0.7  # untagged entry unique to this writer

    for first, second in ((exact, learned), (learned, exact)):
        for f in os.listdir(store.cells_dir):
            os.remove(os.path.join(store.cells_dir, f))
        store.sync_cell(ckey, first, None)
        store.sync_cell(ckey, second, None)
        merged = TranspositionCache()
        store.warm_cell(ckey, merged, include_learned=True)
        assert merged.terminal[(0, 1)] == 0.5, "learned shadowed exact"
        assert (0, 1) not in merged.terminal_version
        assert merged.terminal[(0, 2)] == 0.7  # both writers' entries kept


def test_warm_start_excludes_learned_by_default(tmp_path):
    store = PlanStore(str(tmp_path / "store"))
    cache = TranspositionCache()
    cache.terminal[(0, 1)] = 0.5
    cache.terminal[(0, 2)] = 0.9
    cache.terminal_version[(0, 2)] = 4  # a model prediction
    store.sync_cell("k" * 20, cache, None)
    fresh = TranspositionCache()
    # an analytic run must only see exact entries (values change nothing,
    # so plan/cost/decisions stay bit-identical to a cold run)
    assert store.warm_cell("k" * 20, fresh) == 1
    assert fresh.terminal == {(0, 1): 0.5}
    both = TranspositionCache()
    assert store.warm_cell("k" * 20, both, include_learned=True) == 2
    assert both.terminal_version == {(0, 2): 4}


def test_sync_cell_is_incremental(tmp_path):
    store = PlanStore(str(tmp_path / "store"))
    cache = TranspositionCache()
    cache.terminal[(0,)] = 1.0
    wm = store.sync_cell("a" * 20, cache, None)
    cache.terminal[(1,)] = 2.0
    # second sync ships only the delta but the stored state keeps both
    store.sync_cell("a" * 20, cache, wm)
    fresh = TranspositionCache()
    assert store.warm_cell("a" * 20, fresh) == 2


# ---------------------------------------------------------------------------
# Daemon: plan-tier hits, warm cells, restart persistence
# ---------------------------------------------------------------------------
def test_repeat_request_is_store_hit_zero_search(tmp_path):
    svc = _service(tmp_path)
    out1 = svc.handle(dict(REQ))
    out2 = svc.handle(dict(REQ))
    assert out1["served"] == "search" and out2["served"] == "store"
    assert svc.n_searches == 1  # the repeat ran no search
    assert out2["result"]["from_store"]
    assert out2["result"]["plan"] == out1["result"]["plan"]
    assert out2["result"]["cost"] == out1["result"]["cost"]
    svc.shutdown()


def test_store_warm_starts_fresh_process(tmp_path):
    """A store populated by one service answers a FRESH service's repeat
    request with no search at all, and warm-starts the cell cache for a
    new (different-seed) request without changing its result."""
    svc1 = _service(tmp_path)
    out1 = svc1.handle(dict(REQ))
    svc1.shutdown()

    svc2 = _service(tmp_path)
    out2 = svc2.handle(dict(REQ))
    assert out2["served"] == "store" and svc2.n_searches == 0
    assert out2["result"]["plan"] == out1["result"]["plan"]

    # new seed on the same cell: searches, but from a warmed cache —
    # and the result matches a from-scratch run bit-for-bit
    out3 = svc2.handle(dict(REQ, seed=1))
    assert out3["served"] == "search"
    ckey = cell_key(canonical_request(**REQ))
    assert svc2.cells[ckey].cache.hits > 0  # the warm entries were used
    ref = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=1,
                   n_standard=2, n_greedy=1)
    assert out3["result"]["plan"] == ref.plan.to_dict()
    assert out3["result"]["cost"] == ref.cost
    assert out3["result"]["decisions"] == ref.decisions
    svc2.shutdown()


def test_socket_protocol_roundtrip(tmp_path):
    from repro.launch.tune_serve import TuneClient

    svc = _service(tmp_path)
    sock = str(tmp_path / "tuner.sock")
    t = threading.Thread(
        target=serve_forever, args=(svc, sock), kwargs={"max_requests": 2},
        daemon=True,
    )
    t.start()
    deadline = 50
    while not os.path.exists(sock) and deadline:
        deadline -= 1
        threading.Event().wait(0.1)
    client = TuneClient(sock)
    assert client.ping() == {"ok": True, "pong": True}
    out1 = client.tune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                       n_standard=2, n_greedy=1)
    assert out1["ok"] and out1["served"] == "search"
    out2 = client.tune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                       n_standard=2, n_greedy=1)
    assert out2["ok"] and out2["served"] == "store"
    assert out2["result"]["plan"] == out1["result"]["plan"]
    t.join(timeout=30)
    assert not t.is_alive()


def test_bad_request_never_kills_daemon(tmp_path):
    from repro.launch.tune_serve import TuneClient

    svc = _service(tmp_path)
    sock = str(tmp_path / "tuner.sock")
    t = threading.Thread(
        target=serve_forever, args=(svc, sock), kwargs={"max_requests": 1},
        daemon=True,
    )
    t.start()
    deadline = 50
    while not os.path.exists(sock) and deadline:
        deadline -= 1
        threading.Event().wait(0.1)
    client = TuneClient(sock)
    bad = client.call({"op": "tune", "arch": "no-such-arch", "shape": "x"})
    assert not bad["ok"] and "no-such-arch" in bad["error"]
    good = client.tune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                       n_standard=2, n_greedy=1)
    assert good["ok"]
    t.join(timeout=30)


# ---------------------------------------------------------------------------
# Shared pinned pool across runs
# ---------------------------------------------------------------------------
def test_shared_pool_reused_across_runs(tmp_path):
    svc = _service(tmp_path, parallel=True, n_workers=2)
    out1 = svc.handle(dict(REQ))
    pids = {w.proc.pid for w in svc.pool._workers}
    out2 = svc.handle(dict(REQ, seed=1))
    assert {w.proc.pid for w in svc.pool._workers} == pids
    assert svc.pool.n_worker_restarts == 0
    # parallel shared-pool results == sequential one-shot results
    for out, seed in ((out1, 0), (out2, 1)):
        ref = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=seed,
                       n_standard=2, n_greedy=1)
        assert out["result"]["plan"] == ref.plan.to_dict()
        assert out["result"]["cost"] == ref.cost
        assert out["result"]["decisions"] == ref.decisions
    svc.shutdown()
    assert svc.pool is None


def test_pool_rebind_direct():
    """PinnedWorkerPool.rebind repoints live workers at a new run's trees:
    same processes, same results as a fresh pool."""
    from repro.core.autotuner import make_mdp
    from repro.core.engine.cache import CachedMDP
    from repro.core.ensemble import ProTuner
    from repro.core.engine.workers import PinnedWorkerPool
    from repro.core.mcts import MCTSConfig

    mc = MCTSConfig(iters_per_decision=4)
    pool = PinnedWorkerPool([], CachedMDP(make_mdp(*CELL)), n_workers=2)
    assert len(pool._workers) == 2  # empty trees keep the requested width
    try:
        pids = {w.proc.pid for w in pool._workers}
        results = []
        for seed in (0, 1):
            tuner = ProTuner(CachedMDP(make_mdp(*CELL)), n_standard=2,
                             n_greedy=1, mcts_config=mc, seed=seed,
                             worker_pool=pool)
            results.append(tuner.run())
        assert {w.proc.pid for w in pool._workers} == pids
        for seed, res in zip((0, 1), results):
            ref = ProTuner(CachedMDP(make_mdp(*CELL)), n_standard=2,
                           n_greedy=1, mcts_config=mc, seed=seed).run()
            assert res.plan == ref.plan and res.cost == ref.cost
            assert [d["action"] for d in res.decisions] == [
                d["action"] for d in ref.decisions]
    finally:
        pool.shutdown()


# ---------------------------------------------------------------------------
# autotune(plan_store=) one-shot convenience
# ---------------------------------------------------------------------------
def test_autotune_plan_store_kwarg(tmp_path):
    store = PlanStore(str(tmp_path / "store"))
    res1 = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                    n_standard=2, n_greedy=1, plan_store=store)
    assert not res1.from_store
    res2 = autotune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                    n_standard=2, n_greedy=1, plan_store=store)
    assert res2.from_store
    assert res2.plan == res1.plan and res2.cost == res1.cost
    assert store.stats()["hits"] == 1


# ---------------------------------------------------------------------------
# PR 10: crash safety, deadlines, backpressure, degradation
# ---------------------------------------------------------------------------
def _ref(seed=0):
    return autotune(CELL[0], CELL[1], algo="mcts_1s", seed=seed,
                    n_standard=2, n_greedy=1)


def test_tune_error_path_syncs_cache_and_releases_journal(tmp_path, monkeypatch):
    """An exception mid-search must not drop the cell cache's progress or
    leave journal/checkpoint state behind, and the response carries
    structured provenance, not a bare ok=False."""
    svc = _service(tmp_path)
    req = canonical_request(**REQ)
    ckey = cell_key(req)

    def boom(*a, **kw):
        kw["mdp"].cache.terminal[(1, 2, 3)] = 0.125  # progress before dying
        raise RuntimeError("search exploded")

    monkeypatch.setattr("repro.service.daemon.autotune", boom)
    out = svc.handle(dict(REQ))
    assert not out["ok"]
    assert "RuntimeError: search exploded" in out["error"]
    info = out["error_info"]
    assert info["type"] == "RuntimeError" and info["phase"] == "search"
    assert info["request"] == req
    assert svc.n_errors == 1
    # the progress the search DID make was synced to the store's cell tier
    fresh = TranspositionCache()
    assert svc.store.warm_cell(ckey, fresh) >= 1
    assert fresh.terminal[(1, 2, 3)] == 0.125
    # journal + checkpoint released: the failed request won't replay forever
    assert svc.store.pending_requests() == []
    assert svc.store.load_checkpoint(req) is None
    svc.shutdown()


def test_latency_ring_bounded_with_percentiles(tmp_path):
    from repro.service.daemon import _LatencyRing

    ring = _LatencyRing(cap=8)
    for i in range(100):
        ring.append(float(i))
    assert len(ring.buf) == 8  # bounded, not 100
    assert ring.count == 100 and ring.total == sum(range(100))
    assert ring.percentile(0.5) in ring.buf
    s = ring.summary()
    assert s["count"] == 100 and s["window"] == 8
    assert s["p50_s"] is not None and s["p99_s"] is not None

    svc = _service(tmp_path, latency_window=4)
    for _ in range(6):
        svc.handle(dict(REQ))
    assert len(svc.time_to_plan.buf) == 4
    tp = svc.stats()["time_to_plan"]
    assert tp["count"] == 6 and tp["window"] == 4
    assert tp["p50_s"] > 0 and tp["p99_s"] > 0
    svc.shutdown()


def test_deadline_interrupt_then_resume_bit_identical(tmp_path):
    """A deadlined request returns best-so-far with provenance and keeps
    its checkpoint; the retry resumes and lands the full result — plan,
    cost, and decisions bit-identical to an uninterrupted run."""
    svc = _service(tmp_path, checkpoint_every=1, round_delay_s=0.05)
    req = canonical_request(**REQ)
    out = svc.handle(dict(REQ, deadline_s=0.12))
    assert out["ok"] and out["served"] == "search"
    info = out["result"]["stats"]["interrupted"]
    assert info["reason"] == "deadline"
    assert 0 < info["rounds_done"] < info["rounds_total"]
    assert svc.n_interrupted == 1
    # partial result never recorded; checkpoint kept; journal released
    assert svc.store.lookup(req) is None
    assert svc.store.load_checkpoint(req) is not None
    assert svc.store.pending_requests() == []

    out2 = svc.handle(dict(REQ))  # no deadline: resumes and completes
    assert out2["ok"] and out2["served"] == "search"
    assert "interrupted" not in out2["result"]["stats"]
    ref = _ref()
    assert out2["result"]["plan"] == ref.plan.to_dict()
    assert out2["result"]["cost"] == ref.cost
    assert out2["result"]["decisions"] == ref.decisions
    # completion cleared the checkpoint and recorded the plan
    assert svc.store.load_checkpoint(req) is None
    assert svc.store.lookup(req) is not None
    svc.shutdown()


def test_sweep_tmp_removes_dead_writer_debris_only(tmp_path):
    """A writer SIGKILLed between open(tmp) and os.replace orphans its
    tmp sibling; recover()'s sweep removes exactly that debris — never a
    live writer's in-flight tmp, never a published tier file."""
    store = PlanStore(str(tmp_path / "store"))
    req = canonical_request(**REQ)
    store.journal_begin(req)  # a real published tier file

    dead = os.path.join(store.checkpoints_dir, "abc.pkl.tmp.999999.deadbeef")
    live = os.path.join(store.journal_dir,
                        f"def.json.tmp.{os.getpid()}.cafe0123")
    junk = os.path.join(store.plans_dir, "ghi.json.tmp.notapid.f00d")
    for p in (dead, live, junk):
        with open(p, "w") as f:
            f.write("partial write")

    assert store.sweep_tmp() == 2  # the dead pid and the malformed pid
    assert not os.path.exists(dead) and not os.path.exists(junk)
    assert os.path.exists(live)  # this process is alive: in-flight
    assert store.pending_requests() == [req]  # tier files untouched
    os.remove(live)
    assert store.sweep_tmp() == 0  # idempotent once clean


def test_recover_replays_pending_journal(tmp_path):
    """A pending journal entry (daemon died mid-search) is replayed on
    recover(), resuming from the checkpoint, and the landed plan is
    bit-identical to an uninterrupted run."""
    svc1 = _service(tmp_path, checkpoint_every=1, round_delay_s=0.05)
    req = canonical_request(**REQ)
    svc1.handle(dict(REQ, deadline_s=0.12))  # leaves a checkpoint behind
    assert svc1.store.load_checkpoint(req) is not None
    svc1.store.journal_begin(req)  # simulate dying before journal_release
    svc1.shutdown()

    svc2 = _service(tmp_path)
    assert svc2.store.pending_requests() == [req]
    assert svc2.recover() == 1
    assert svc2.n_recovered == 1
    assert svc2.store.pending_requests() == []
    assert svc2.store.load_checkpoint(req) is None
    hit = svc2.store.lookup(req)
    ref = _ref()
    assert hit is not None
    assert hit.plan == ref.plan and hit.cost == ref.cost
    assert hit.decisions == ref.decisions
    # an entry whose plan already landed is released without re-running
    svc2.store.journal_begin(req)
    assert svc2.recover() == 0
    assert svc2.store.pending_requests() == []
    svc2.shutdown()


def test_watchdog_degrades_repeatedly_restarting_pool(tmp_path):
    """Past the restart threshold the pool is shut down and later runs go
    sequential — same results (the engines are certified bit-identical),
    no more worker processes to babysit."""
    svc = _service(tmp_path, parallel=True, n_workers=2, degrade_after=3)
    out1 = svc.handle(dict(REQ))
    assert svc.pool is not None and not svc.degraded
    svc.pool.n_worker_restarts = 3  # the pool has been dying repeatedly
    out2 = svc.handle(dict(REQ, seed=1))  # this run's watchdog trips
    assert svc.degraded and svc.pool is None
    st = svc.stats()
    assert st["degraded"] and st["pool_restarts"] == 3
    out3 = svc.handle(dict(REQ, seed=2))  # served by the sequential engine
    assert out3["ok"] and out3["served"] == "search"
    for out, seed in ((out1, 0), (out2, 1), (out3, 2)):
        ref = _ref(seed)
        assert out["result"]["plan"] == ref.plan.to_dict()
        assert out["result"]["cost"] == ref.cost
        assert out["result"]["decisions"] == ref.decisions
    svc.shutdown()


def _start_server(svc, sock, **kw):
    t = threading.Thread(target=serve_forever, args=(svc, sock), kwargs=kw,
                         daemon=True)
    t.start()
    deadline = 50
    while not os.path.exists(sock) and deadline:
        deadline -= 1
        threading.Event().wait(0.1)
    return t


def test_idle_connection_closed_not_wedging_daemon(tmp_path):
    """A client that connects and sends nothing is closed after the read
    timeout, and the daemon keeps serving other clients throughout."""
    import socket as socketlib

    from repro.launch.tune_serve import TuneClient

    svc = _service(tmp_path)
    sock = str(tmp_path / "tuner.sock")
    t = _start_server(svc, sock, read_timeout_s=0.3)
    client = TuneClient(sock)
    silent = socketlib.socket(socketlib.AF_UNIX, socketlib.SOCK_STREAM)
    silent.connect(sock)  # ... and says nothing
    # the daemon answers OTHER clients while the silent one sits there
    assert client.ping() == {"ok": True, "pong": True}
    silent.settimeout(2.0)
    assert silent.recv(1) == b""  # closed by the read timeout, not hung
    silent.close()
    assert client.ping() == {"ok": True, "pong": True}
    out = client.call({"op": "shutdown"})
    assert out["ok"] and out["stopping"]
    t.join(timeout=10)
    assert not t.is_alive()


def test_overload_backpressure_and_graceful_shutdown(tmp_path):
    """With a bounded queue of 1: one request in flight, one queued, and
    every further request gets an immediate structured 'overloaded'
    response with a retry hint — nobody hangs, nobody is dropped."""
    from repro.launch.tune_serve import TuneClient

    svc = _service(tmp_path, round_delay_s=0.08)
    sock = str(tmp_path / "tuner.sock")
    t = _start_server(svc, sock, queue_size=1)
    client = TuneClient(sock)

    results = {}

    def submit(name, seed):
        results[name] = client.tune(CELL[0], CELL[1], algo="mcts_1s",
                                    seed=seed, n_standard=2, n_greedy=1)

    t1 = threading.Thread(target=submit, args=("inflight", 0), daemon=True)
    t1.start()
    deadline = 100
    while svc.n_requests < 1 and deadline:  # until the search is IN handle
        deadline -= 1
        threading.Event().wait(0.05)
    t2 = threading.Thread(target=submit, args=("queued", 0), daemon=True)
    t2.start()
    deadline = 100
    while client.stats()["stats"]["serve"]["queue_depth"] < 1 and deadline:
        deadline -= 1
        threading.Event().wait(0.05)
    over1 = client.tune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                        n_standard=2, n_greedy=1)
    over2 = client.tune(CELL[0], CELL[1], algo="mcts_1s", seed=0,
                        n_standard=2, n_greedy=1)
    for over in (over1, over2):
        assert not over["ok"] and over["error"] == "overloaded"
        assert over["retry_after_s"] > 0
    t1.join(timeout=30)
    t2.join(timeout=30)
    assert results["inflight"]["ok"] and results["inflight"]["served"] == "search"
    assert results["queued"]["ok"] and results["queued"]["served"] == "store"
    st = client.stats()["stats"]["serve"]
    assert st["n_overloaded"] == 2 and st["served"] == 2
    out = client.call({"op": "shutdown"})
    assert out["ok"]
    t.join(timeout=10)
    assert not t.is_alive()


def test_sigkill_daemon_resumes_bit_identical(tmp_path):
    """The headline crash-safety claim: SIGKILL the daemon subprocess
    mid-search, restart it on the same store dir, and the journaled
    request resumes from its round-boundary checkpoint — the final
    plan/cost/decisions are bit-identical to an uninterrupted run."""
    import signal
    import subprocess
    import sys
    import time as timelib

    from repro.launch.tune_serve import TuneClient

    store = str(tmp_path / "store")
    sock = str(tmp_path / "tuner.sock")
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        "src" + os.pathsep + env["PYTHONPATH"]
        if env.get("PYTHONPATH") else "src"
    )

    def spawn(*extra):
        return subprocess.Popen(
            [sys.executable, "-m", "repro.launch.tune_serve", "serve",
             "--store", store, "--socket", sock,
             "--checkpoint-every", "1", "--round-delay", "0.15", *extra],
            env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
        )

    proc = spawn()
    try:
        deadline = timelib.time() + 60
        while not os.path.exists(sock) and timelib.time() < deadline:
            timelib.sleep(0.05)
        assert os.path.exists(sock), "daemon never came up"

        def fire():
            try:
                TuneClient(sock).tune(CELL[0], CELL[1], algo="mcts_1s",
                                      seed=0, n_standard=2, n_greedy=1)
            except Exception:
                pass  # the daemon dies mid-request by design

        t = threading.Thread(target=fire, daemon=True)
        t.start()

        ckpt_dir = os.path.join(store, "checkpoints")
        journal_dir = os.path.join(store, "journal")
        deadline = timelib.time() + 60
        while timelib.time() < deadline:
            if os.path.exists(ckpt_dir) and os.listdir(ckpt_dir):
                break
            timelib.sleep(0.02)
        assert os.listdir(ckpt_dir), "no checkpoint appeared mid-search"
        proc.send_signal(signal.SIGKILL)  # mid-search, rounds left to go
        proc.wait(timeout=10)
        t.join(timeout=10)
    finally:
        if proc.poll() is None:
            proc.kill()

    # the crash left the write-ahead journal entry pending and no plan
    assert len(os.listdir(journal_dir)) == 1
    assert os.listdir(os.path.join(store, "plans")) == []

    # restart on the same store: recovery replays the journal (resuming
    # from the checkpoint) before accepting, so the repeat request is a
    # store hit answered with the COMPLETE result
    os.remove(sock)  # the SIGKILLed daemon left a stale socket file
    proc = spawn("--max-requests", "1")
    try:
        deadline = timelib.time() + 60
        while not os.path.exists(sock) and timelib.time() < deadline:
            timelib.sleep(0.05)
        out = TuneClient(sock, timeout=120.0).tune(
            CELL[0], CELL[1], algo="mcts_1s", seed=0,
            n_standard=2, n_greedy=1)
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)

    assert out["ok"] and out["served"] == "store"
    ref = _ref()
    # the socket hop JSON-serializes the plan (tuples -> lists); decode
    # back before the bit-identity comparison
    from repro.core.space import SchedulePlan

    assert SchedulePlan.from_dict(out["result"]["plan"]) == ref.plan
    assert out["result"]["cost"] == ref.cost
    assert out["result"]["decisions"] == ref.decisions
    # recovery released the journal and cleared the checkpoint
    assert os.listdir(journal_dir) == []
    assert os.listdir(ckpt_dir) == []
