"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode on CPU executes the exact kernel bodies)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gemm import moe_gemm
from repro.kernels.quantize import dequantize_int8, quantize_int8
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.selective_scan import selective_scan

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,Hq,Hkv,S,D,bq,bkv,causal",
    [
        (2, 4, 2, 256, 64, 128, 128, True),
        (1, 8, 8, 128, 32, 64, 64, True),     # MHA
        (2, 4, 1, 256, 64, 128, 64, True),    # MQA, asymmetric blocks
        (1, 4, 2, 256, 128, 256, 128, True),  # block_q == S
        (2, 4, 2, 128, 64, 128, 128, False),  # non-causal
        (1, 2, 2, 512, 64, 128, 256, True),   # bkv > bq
    ],
)
def test_flash_attention_matches_ref(B, Hq, Hkv, S, D, bq, bkv, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_kv=bkv, interpret=True)
    exp = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_kv=64, interpret=True)
    exp = ref.attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), **_tol(jnp.bfloat16)
    )


# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,L,Di,N,chunk,dblk",
    [
        (2, 64, 32, 8, 16, 16),
        (1, 128, 64, 16, 64, 32),
        (2, 32, 16, 4, 32, 16),   # chunk == L
        (1, 96, 48, 8, 32, 48),   # dblk == Di
    ],
)
def test_selective_scan_matches_ref(B, L, Di, N, chunk, dblk):
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (B, L, Di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, Di)))
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    D = jnp.linspace(0.1, 1.0, Di)
    out = selective_scan(u, dt, A, Bm, Cm, D, chunk=chunk, d_block=dblk, interpret=True)
    exp = ref.selective_scan(u, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4, rtol=1e-3)


def test_selective_scan_step_consistency():
    """Decode step replays the full scan one token at a time."""
    B, L, Di, N = 2, 16, 8, 4
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (B, L, Di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, Di)))
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    D = jnp.ones(Di) * 0.3
    full = ref.selective_scan(u, dt, A, Bm, Cm, D)
    x = jnp.zeros((B, Di, N))
    ys = []
    for t in range(L):
        x, y = ref.selective_scan_step(x, u[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D)
        ys.append(y)
    step_out = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(step_out), np.asarray(full), atol=1e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,block", [((3, 7, 64), 4), ((16, 128), 16), ((5, 96), 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, block, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    w = jax.random.normal(ks[1], (shape[-1],), dtype)
    out = rmsnorm(x, w, block_rows=block, interpret=True)
    exp = ref.rmsnorm(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "E,C,d,f,bc,bf,bd",
    [(4, 32, 64, 48, 16, 16, 32), (2, 16, 32, 32, 16, 32, 16), (8, 8, 16, 16, 8, 16, 16)],
)
def test_moe_gemm_matches_ref(E, C, d, f, bc, bf, bd):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (E, C, d))
    w = jax.random.normal(ks[1], (E, d, f))
    out = moe_gemm(x, w, block_c=bc, block_f=bf, block_d=bd, interpret=True)
    exp = ref.moe_gemm(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("R,C", [(8, 128), (16, 64), (4, 256)])
def test_quantize_roundtrip(R, C):
    x = jax.random.normal(KEY, (R, C)) * 3.0
    q, s = quantize_int8(x, block_rows=4, interpret=True)
    qr, sr = ref.quantize_int8(x)
    assert (np.asarray(q) == np.asarray(qr)).all()
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    xd = dequantize_int8(q, s, interpret=True)
    # error bounded by scale/2 per element
    err = np.abs(np.asarray(xd) - np.asarray(x))
    bound = np.asarray(s) * 0.5 + 1e-7
    assert (err <= bound).all()
