"""Hypothesis property tests on system invariants.

The whole module skips cleanly when ``hypothesis`` is not installed (it is
an optional dev dependency — CI installs it; minimal environments run the
rest of the tier-1 suite without it)."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import ARCH_IDS, get_config, get_shape
from repro.core.autotuner import NoisyCostModel, make_mdp
from repro.core.cost_model import AnalyticCostModel
from repro.core.engine import CachedMDP
from repro.core.mcts import MCTS, MCTSConfig
from repro.core.space import SINGLE_POD, MULTI_POD, SchedulePlan, ScheduleSpace
from repro.kernels import ref

SETTINGS = settings(max_examples=30, deadline=None)


@st.composite
def cell(draw):
    arch = draw(st.sampled_from(ARCH_IDS))
    shape = draw(st.sampled_from(["train_4k", "prefill_32k", "decode_32k"]))
    mesh = draw(st.sampled_from([SINGLE_POD, MULTI_POD]))
    return arch, shape, mesh


@SETTINGS
@given(cell(), st.integers(0, 2**31 - 1))
def test_random_plans_always_cost_finite_positive(c, seed):
    """MDP invariant: EVERY complete schedule has a finite positive cost
    (infeasible = penalized, never rejected)."""
    arch, shape_name, mesh = c
    space = ScheduleSpace(get_config(arch), get_shape(shape_name), mesh)
    cm = AnalyticCostModel(get_config(arch), get_shape(shape_name), mesh)
    plan = space.random_plan(random.Random(seed))
    cost = cm.cost(plan)
    assert np.isfinite(cost) and cost > 0


@SETTINGS
@given(cell(), st.integers(0, 2**31 - 1))
def test_action_sequences_roundtrip(c, seed):
    arch, shape_name, mesh = c
    space = ScheduleSpace(get_config(arch), get_shape(shape_name), mesh)
    actions = space.random_actions(random.Random(seed))
    plan = space.plan_from_actions(actions)
    # every stage's chosen value is one of its options
    for s, a in zip(space.stages, actions):
        assert getattr(plan, s.name) == s.options[a]
    assert SchedulePlan.from_dict(plan.to_dict()) == plan


@st.composite
def plan_batch(draw):
    """A (cfg, shape, mesh, space, plans) tuple with arbitrary plans —
    duplicates injected deliberately, since concurrent rollouts collide
    on schedules; the mesh is sampled too, so the columnar kernel's
    multi-pod branches (pod-scaled dp, pod-link bandwidth blending) get
    certified alongside the single-pod ones."""
    arch = draw(st.sampled_from(
        ["granite-3-2b", "granite-moe-1b-a400m", "falcon-mamba-7b"]
    ))  # dense attn / MoE / SSM — every kernel branch family
    shape_name = draw(st.sampled_from(["train_4k", "decode_32k"]))
    mesh = draw(st.sampled_from([SINGLE_POD, MULTI_POD]))
    cfg, shape = get_config(arch).reduced(), get_shape(shape_name)
    space = ScheduleSpace(cfg, shape, mesh)
    seeds = draw(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=6))
    plans = [space.random_plan(random.Random(s)) for s in seeds]
    if draw(st.booleans()):
        plans = plans + plans[: draw(st.integers(1, len(plans)))]
    return cfg, shape, mesh, space, plans


@SETTINGS
@given(plan_batch())
def test_cost_batch_equals_scalar_sweep(batch):
    """The batch-pricing contract: ``cost_batch(plans)`` returns EXACTLY
    ``[cost(p) for p in plans]`` — element order preserved, duplicates
    included, floats compared with ``==`` (bit-identity, not tolerance).
    Held by the default (columnar, size-dispatched) model on random
    batches of both cell kinds."""
    cfg, shape, mesh, space, plans = batch
    cm = AnalyticCostModel(cfg, shape, mesh)
    scalar = [cm.cost(p) for p in plans]
    batched = cm.cost_batch(plans)
    assert batched == scalar
    # a second batched pass (warm context) returns the same values
    assert cm.cost_batch(plans) == scalar
    # unique plans are priced once per batch call
    n0 = cm.n_evals
    cm.cost_batch(plans)
    assert cm.n_evals - n0 == len(set(plans))


@SETTINGS
@given(plan_batch())
def test_columnar_kernel_equals_scalar_oracle(batch):
    """The columnar refactor's load-bearing property: the vectorized
    kernel (forced via ``columnar_min_batch=1`` so even batches of one run
    column math) and the pre-columnar scalar oracle (``columnar=False``)
    price every random batch bit-identically — ``cost``, ``cost_batch``
    (duplicates included), and every ``terms`` field down to the
    ``details`` dict."""
    cfg, shape, mesh, space, plans = batch
    kern = AnalyticCostModel(
        cfg, shape, mesh, columnar=True, columnar_min_batch=1
    )
    oracle = AnalyticCostModel(cfg, shape, mesh, columnar=False)
    want = [oracle.cost(p) for p in plans]
    assert kern.cost_batch(plans) == want
    assert [kern.cost(p) for p in plans] == want
    assert kern.terms(plans[0]).to_dict() == oracle.terms(plans[0]).to_dict()


@SETTINGS
@given(plan_batch())
def test_featurize_columns_matches_featurize_batch(batch):
    """The shared-encoding seam: featurizing a ``PlanColumns`` batch for
    the learned model produces the SAME float32 matrix as featurizing the
    plan objects — the serving layer's one-encode-per-batch guarantee."""
    from repro.core.cost_model import PlanColumns
    from repro.core.learned_cost import featurize_batch, featurize_columns

    cfg, shape, mesh, space, plans = batch
    cols = PlanColumns.from_plans(plans)
    a = featurize_batch(plans, space)
    b = featurize_columns(cols, space)
    assert a.dtype == b.dtype and a.shape == b.shape
    assert (a == b).all()


@SETTINGS
@given(plan_batch(), st.floats(0.05, 0.5), st.integers(0, 10**6))
def test_noisy_cost_batch_equals_scalar_sweep(batch, sigma, seed):
    cfg, shape, mesh, space, plans = batch
    noisy = NoisyCostModel(AnalyticCostModel(cfg, shape, mesh), sigma, seed)
    assert noisy.cost_batch(plans) == [noisy.cost(p) for p in plans]


@st.composite
def state_batch(draw):
    """A (CachedMDP, states) pair; states are complete schedules with
    duplicates injected."""
    from repro.core.mdp import ScheduleMDP

    cfg, shape = get_config("granite-moe-1b-a400m").reduced(), get_shape("train_4k")
    space = ScheduleSpace(cfg, shape, SINGLE_POD)
    mdp = ScheduleMDP(space, AnalyticCostModel(cfg, shape, SINGLE_POD))
    seeds = draw(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=6))
    states = [tuple(space.random_actions(random.Random(s))) for s in seeds]
    if draw(st.booleans()):
        states = states + states[: draw(st.integers(1, len(states)))]
    return CachedMDP(mdp), states


@SETTINGS
@given(state_batch())
def test_terminal_cost_batch_cache_consistency(batch):
    """``CachedMDP.terminal_cost_batch``: scalar-identical values, hit/miss
    accounting sums to the batch size, and a warm cache never changes the
    returned values (it only converts misses to hits)."""
    mdp, states = batch
    cache = mdp.cache
    cold = mdp.terminal_cost_batch(states)
    assert cache.hits + cache.misses == len(states)
    assert cache.misses == len(set(states))  # duplicates hit in-batch
    warm = mdp.terminal_cost_batch(states)
    assert warm == cold
    assert cache.misses == len(set(states))  # warm pass: all hits
    assert cache.hits + cache.misses == 2 * len(states)
    # scalar lookups agree element-for-element
    assert [mdp.terminal_cost(s) for s in states] == cold
    # the wrapped cost model priced each unique schedule exactly once
    assert mdp.cost_model.n_evals == len(set(states))


@SETTINGS
@given(state_batch(), st.integers(1, 12))
def test_partial_cost_batch_cache_consistency(batch, cut):
    """Mixed prefix/terminal batches through ``partial_cost_batch`` match
    the scalar method and keep ``hits + misses == len(batch)``."""
    mdp, states = batch
    prefixes = [s[: cut % (len(s) + 1)] for s in states]  # some terminal
    mixed = prefixes + states[:1]
    cold = mdp.partial_cost_batch(mixed)
    assert mdp.cache.hits + mdp.cache.misses == len(mixed)
    assert mdp.partial_cost_batch(mixed) == cold
    assert [mdp.partial_cost(s) for s in mixed] == cold


# ---------------------------------------------------------------------------
# Evolutionary operator closure (core/evolve.py)
#
# The operator catalog moves option *indices*, never raw values, so closure
# over ``ScheduleSpace`` should hold by construction — these properties pin
# it: every operator (and uniform crossover) applied to a valid plan yields
# a plan inside the space, and decoding the child plan re-encodes to exactly
# the child's action tuple.
# ---------------------------------------------------------------------------


@st.composite
def space_and_state(draw):
    """A (space, valid action tuple) pair over reduced configs of all three
    architecture families, both cell kinds, both meshes."""
    arch = draw(st.sampled_from(
        ["granite-3-2b", "granite-moe-1b-a400m", "falcon-mamba-7b"]
    ))
    shape_name = draw(st.sampled_from(["train_4k", "decode_32k"]))
    mesh = draw(st.sampled_from([SINGLE_POD, MULTI_POD]))
    space = ScheduleSpace(
        get_config(arch).reduced(), get_shape(shape_name), mesh
    )
    seed = draw(st.integers(0, 2**31 - 1))
    return space, tuple(space.random_actions(random.Random(seed)))


@SETTINGS
@given(space_and_state(), st.integers(0, 2**31 - 1))
def test_every_mutation_operator_is_closed(s, opseed):
    """Each single operator returns a DIFFERENT valid index for its stage,
    and the mutated plan decodes and re-encodes to itself."""
    from repro.core.evolve import encode_plan, mutation_operators

    space, actions = s
    rng = random.Random(opseed)
    ops = mutation_operators(space)
    # only single-option stages are excluded from the catalog
    assert {d for _n, d, _o in ops} == {
        d for d, st_ in enumerate(space.stages) if len(st_.options) >= 2
    }
    for name, depth, op in ops:
        new_idx = op(actions[depth], rng)
        assert 0 <= new_idx < len(space.stages[depth].options)
        assert new_idx != actions[depth]
        child = list(actions)
        child[depth] = new_idx
        plan = space.plan_from_actions(child)
        assert getattr(plan, space.stages[depth].name) == \
            space.stages[depth].options[new_idx]
        assert encode_plan(space, plan) == tuple(child)


@SETTINGS
@given(space_and_state(), st.integers(0, 2**31 - 1), st.floats(0.01, 1.0))
def test_mutate_is_closed_and_never_identity(s, opseed, rate):
    from repro.core.evolve import encode_plan, mutate, mutation_operators

    space, actions = s
    ops = mutation_operators(space)
    child = mutate(actions, random.Random(opseed), ops, rate)
    for stage, a in zip(space.stages, child):
        assert 0 <= a < len(stage.options)
    assert encode_plan(space, space.plan_from_actions(child)) == child
    if ops:  # mutate forces at least one operator when none fired
        assert child != tuple(actions)


@SETTINGS
@given(space_and_state(), st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1))
def test_crossover_is_closed(s, seed_b, seed_x):
    from repro.core.evolve import crossover, encode_plan

    space, a = s
    b = tuple(space.random_actions(random.Random(seed_b)))
    child = crossover(a, b, random.Random(seed_x))
    for x, ga, gb in zip(child, a, b):
        assert x in (ga, gb)
    assert encode_plan(space, space.plan_from_actions(child)) == child


# ---------------------------------------------------------------------------
# The jitted pricing kernel's tolerance contract (pricing="jit")
#
# ``_terms_jitted`` replays the same float64 arithmetic as the certified
# columnar kernel, but XLA is free to contract multiply-adds: empirically
# the two agree to 1-2 ULPs (max relative difference ~3.5e-16 across these
# grids on this build) — NOT bit-identical.  The pinned CONTRACT is
# relative agreement within ``JIT_RTOL``; because it is a tolerance, the
# jitted path carries the versioned ``JIT_PRICING_TAG`` so cache/store
# entries priced under different contracts never mix.
# ---------------------------------------------------------------------------

_JIT_PAIRS = {}


def _jit_pair(arch, shape_name, mesh):
    """Memoized (jit model, columnar model, space) per cell — the jit
    compile cache is per-model, so reusing models across hypothesis
    examples bounds the XLA compile count for the whole module."""
    key = (arch, shape_name, mesh.names)
    if key not in _JIT_PAIRS:
        cfg, shape = get_config(arch).reduced(), get_shape(shape_name)
        _JIT_PAIRS[key] = (
            AnalyticCostModel(cfg, shape, mesh, pricing="jit",
                              columnar_min_batch=1),
            AnalyticCostModel(cfg, shape, mesh),
            ScheduleSpace(cfg, shape, mesh),
        )
    return _JIT_PAIRS[key]


@SETTINGS
@given(
    st.sampled_from(["granite-3-2b", "granite-moe-1b-a400m",
                     "falcon-mamba-7b"]),
    st.sampled_from(["train_4k", "decode_32k"]),
    st.lists(st.integers(0, 2**31 - 1), min_size=8, max_size=8, unique=True),
)
def test_jitted_kernel_matches_columnar_within_rtol(arch, shape_name, seeds):
    """``pricing="jit"`` vs the exact columnar kernel on random plan
    batches: elementwise relative agreement within JIT_RTOL (see module
    note above for the exact-vs-ULP status), and the jit model carries a
    non-exact pricing tag while both exact paths share "exact"."""
    from repro.core.cost_model import JIT_PRICING_TAG, JIT_RTOL

    jit, col, space = _jit_pair(arch, shape_name, SINGLE_POD)
    plans = [space.random_plan(random.Random(s)) for s in seeds]
    a = np.asarray(jit.cost_batch(plans))
    b = np.asarray(col.cost_batch(plans))
    np.testing.assert_allclose(a, b, rtol=JIT_RTOL, atol=0.0)
    assert jit.pricing_tag == JIT_PRICING_TAG != "exact"
    assert col.pricing_tag == "exact"


def test_jitted_kernel_multipod_parity_fixed_batch():
    """Deterministic multi-pod leg (pod-scaled dp, pod-link blending) of
    the jit-vs-columnar contract — fixed batch so it costs exactly two
    extra XLA compiles."""
    from repro.core.cost_model import JIT_RTOL

    for shape_name in ("train_4k", "decode_32k"):
        jit, col, space = _jit_pair(
            "granite-moe-1b-a400m", shape_name, MULTI_POD
        )
        plans = [space.random_plan(random.Random(s)) for s in range(16)]
        np.testing.assert_allclose(
            np.asarray(jit.cost_batch(plans)),
            np.asarray(col.cost_batch(plans)),
            rtol=JIT_RTOL, atol=0.0,
        )


def test_jit_crossover_threshold_lowered_and_pinned():
    """Acceptance OR-branch: at batch 1 the jitted kernel does NOT beat the
    warm scalar replay (jax dispatch is ~100µs flat on CPU vs ~30µs for
    one scalar walk), so instead the measured jit-vs-scalar crossover —
    between 4 and 8 on the decode headline cell — is pinned here as
    JIT_MIN_BATCH, strictly below the columnar threshold (16).  Batches
    under the threshold price through the EXACT scalar replay."""
    from repro.core.cost_model import JIT_MIN_BATCH

    assert JIT_MIN_BATCH == 8 < 16
    cfg, shape = get_config("granite-3-2b").reduced(), get_shape("decode_32k")
    m = AnalyticCostModel(cfg, shape, SINGLE_POD, pricing="jit")
    assert m.columnar_min_batch == JIT_MIN_BATCH
    exact = AnalyticCostModel(cfg, shape, SINGLE_POD)
    assert exact.columnar_min_batch == 16


@SETTINGS
@given(st.integers(0, 10**6), st.floats(0.05, 0.5))
def test_noisy_cost_model_deterministic(seed, sigma):
    mdp = make_mdp("granite-3-2b", "train_4k")
    noisy = NoisyCostModel(mdp.cost_model, sigma=sigma, seed=seed)
    plan = mdp.space.plan_from_actions(mdp.space.default_actions())
    assert noisy.cost(plan) == noisy.cost(plan)
    assert noisy.cost(plan) > 0


@SETTINGS
@given(
    st.integers(1, 64),
    st.integers(16, 200),
    st.floats(0.1, 100.0),
)
def test_quantize_error_bound(rows, cols, scale):
    key = jax.random.PRNGKey(rows * 1000 + cols)
    x = jax.random.normal(key, (rows, cols)) * scale
    q, s = ref.quantize_int8(x)
    xd = ref.dequantize_int8(q, s)
    err = np.abs(np.asarray(xd - x))
    bound = np.asarray(s) * 0.5 + 1e-6
    assert (err <= bound).all()
    assert np.abs(np.asarray(q)).max() <= 127


@SETTINGS
@given(st.integers(2, 6), st.integers(1, 6), st.integers(0, 1000))
def test_mcts_never_produces_invalid_state(depth_actions, iters, seed):
    """Tree ops keep states inside the MDP for arbitrary budgets."""
    mdp = make_mdp("granite-moe-1b-a400m", "train_4k")
    t = MCTS(mdp, MCTSConfig(iters_per_decision=iters, seed=seed))
    res = t.run_decision()
    assert 0 <= res.action < mdp.n_actions(())
    assert mdp.is_terminal(res.best_state)
    assert len(res.best_state) == mdp.space.n_stages


@SETTINGS
@given(st.integers(0, 10**6))
def test_rendezvous_rebalance_is_stable(seed):
    """Adding a host only moves shards TO the new host (rendezvous)."""
    from repro.runtime.fault_tolerance import rebalance

    rng = random.Random(seed)
    n = rng.randint(2, 12)
    hosts = [f"h{i}" for i in range(n)]
    before = rebalance(hosts, 48)
    after = rebalance(hosts + ["hNEW"], 48)
    for s in range(48):
        if before[s] != after[s]:
            assert after[s] == "hNEW"


@SETTINGS
@given(st.integers(0, 2**31 - 1), st.integers(1, 16))
def test_pipeline_index_math_disjoint(seed, hosts):
    """For any host count dividing the batch, shards partition the batch."""
    from repro.configs.base import InputShape
    from repro.data.pipeline import DataConfig, Pipeline

    cfg = get_config("granite-3-2b").reduced()
    batch = 16
    if batch % hosts != 0:
        hosts = 1
    shape = InputShape("t", 8, batch, "train")
    full = Pipeline(cfg, shape, DataConfig(seed=seed)).batch_at(2)["inputs"]
    parts = [
        Pipeline(cfg, shape, DataConfig(seed=seed, host_count=hosts, host_index=h)).batch_at(2)["inputs"]
        for h in range(hosts)
    ]
    np.testing.assert_array_equal(np.concatenate(parts), full)


@SETTINGS
@given(st.sampled_from(ARCH_IDS))
def test_sharding_specs_are_mesh_consistent(arch):
    """Every generated PartitionSpec references only mesh axes and divides
    the dims it shards."""
    from repro.sharding.rules import ShardingRules, _axes_size

    cfg = get_config(arch).reduced()
    shape = get_shape("train_4k")
    space = ScheduleSpace(cfg, shape, SINGLE_POD)
    plan = space.plan_from_actions(space.default_actions())
    rules = ShardingRules(cfg, shape, plan, SINGLE_POD)
    from repro.models import transformer

    params = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    specs = rules.param_pspecs(params)

    def check(leaf, spec):
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                assert a in SINGLE_POD.names
            assert dim % _axes_size(SINGLE_POD, axes) == 0

    jax.tree.map(check, params, specs,
                 is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))
