"""Shared-memory transposition-cache log (``engine/shm_cache.py``):
layout roundtrip, the resize/swap generation protocol, per-pool segment
namespacing, and lifecycle — no /dev/shm residue after ``shutdown()``,
and two pools in one process never collide."""
import os

import pytest

from conftest import TRAIN_CELL
from repro.core.engine.cache import CachedMDP, TranspositionCache
from repro.core.engine.shm_cache import HAVE_SHM, ShmCacheLog, ShmCacheReader

pytestmark = pytest.mark.skipif(
    not HAVE_SHM, reason="no POSIX shared memory on this platform")


def _segments():
    """Names of live repro cache segments (Linux: files in /dev/shm)."""
    try:
        return {f for f in os.listdir("/dev/shm")
                if f.startswith("repro-cache-")}
    except FileNotFoundError:  # pragma: no cover - non-Linux shm
        return set()


def test_log_roundtrip_exact():
    """Entries fold back out of the segment bit-for-bit: same keys, same
    float64 values, same table (terminal vs partial); re-folding at the
    advanced cursor is a no-op."""
    log = ShmCacheLog(capacity=4, width=4)
    try:
        term = {(1, 2, 3): 0.125, (4,): -7.5e-11}
        part = {(1, 2): 3.0}
        assert log.append((term, part, {}, {})) == 3
        dst = TranspositionCache()
        r = ShmCacheReader()
        assert r.fold(dst, log.name, log.count) == 3
        assert dst.terminal == term
        assert dst.partial == part
        assert r.fold(dst, log.name, log.count) == 0  # cursor advanced
        assert r.folded == 3
        r.close()
    finally:
        log.close()
        log.unlink()
    assert log.name not in _segments()


def test_resize_swap_preserves_rows_and_cursors():
    """Overflowing capacity or key width migrates to a new generation with
    the row prefix copied — an attached reader follows the new NAME from
    its OLD cursor and misses nothing; the superseded segment survives
    until ``drain_retired()`` (an in-flight round message may still name
    it), then unlinks."""
    log = ShmCacheLog(capacity=2, width=2)
    try:
        g0 = log.name
        log.append(({(1, 2): 1.0}, {}, {}, {}))
        dst = TranspositionCache()
        r = ShmCacheReader()
        r.fold(dst, log.name, log.count)
        # blow past BOTH capacity (2) and key width (2) in one append
        burst = {tuple(range(i, i + 5)): float(i) for i in range(10, 16)}
        log.append((burst, {}, {}, {}))
        assert log.gen == 1 and log.name != g0
        assert log.capacity >= 7 and log.width >= 5
        r.fold(dst, log.name, log.count)
        assert dst.terminal == {(1, 2): 1.0, **burst}
        assert g0 in _segments()  # retired, not yet unlinked
        log.drain_retired()
        assert g0 not in _segments()
        r.close()
    finally:
        log.close()
        log.unlink()
    assert log.name not in _segments()


def test_learned_tagged_entries_rejected():
    """The log is exact-only: an export carrying learned version tags must
    be refused so callers fall back to the pickled-export protocol."""
    log = ShmCacheLog()
    try:
        with pytest.raises(ValueError):
            log.append(({(1,): 1.0}, {}, {(1,): 3}, {}))
    finally:
        log.close()
        log.unlink()


def test_two_logs_one_process_distinct_segments():
    """Segment names are namespaced per pool instance (pid + sequence), so
    two logs in one process write disjoint segments."""
    a, b = ShmCacheLog(), ShmCacheLog()
    try:
        assert a.name != b.name
        a.append(({(1,): 1.0}, {}, {}, {}))
        b.append(({(2,): 2.0}, {}, {}, {}))
        ca, cb = TranspositionCache(), TranspositionCache()
        ra, rb = ShmCacheReader(), ShmCacheReader()
        ra.fold(ca, a.name, a.count)
        rb.fold(cb, b.name, b.count)
        assert ca.terminal == {(1,): 1.0}
        assert cb.terminal == {(2,): 2.0}
        ra.close()
        rb.close()
    finally:
        for log in (a, b):
            log.close()
            log.unlink()


def test_two_pools_one_process_no_collision():
    """Two live pinned pools in one process run shm transport side by side
    — distinct segments, correct (sequential-identical) results on both,
    and zero /dev/shm residue after both shut down."""
    from repro.core.autotuner import make_mdp
    from repro.core.engine.workers import PinnedWorkerPool
    from repro.core.ensemble import ProTuner
    from repro.core.mcts import MCTSConfig

    pre = _segments()
    mc = MCTSConfig(iters_per_decision=4)
    pools = [
        PinnedWorkerPool([], CachedMDP(make_mdp(*TRAIN_CELL)), n_workers=2)
        for _ in range(2)
    ]
    try:
        results = []
        for seed, pool in enumerate(pools):
            tuner = ProTuner(CachedMDP(make_mdp(*TRAIN_CELL)), n_standard=2,
                             n_greedy=1, mcts_config=mc, seed=seed,
                             worker_pool=pool)
            results.append(tuner.run())
        names = {p._shm.name for p in pools if p._shm is not None}
        assert len(names) == 2  # both ran shm transport, disjoint segments
        for seed, res in enumerate(results):
            assert res.stats.get("shm") is True
            ref = ProTuner(CachedMDP(make_mdp(*TRAIN_CELL)), n_standard=2,
                           n_greedy=1, mcts_config=mc, seed=seed).run()
            assert res.plan == ref.plan and res.cost == ref.cost
            assert [d["action"] for d in res.decisions] == [
                d["action"] for d in ref.decisions]
    finally:
        for p in pools:
            p.shutdown()
    assert not (_segments() - pre)


def test_pool_stats_serving_split():
    """The pool's per-worker counters surface on ``TuneResult.stats``: in
    shm mode entries arrive via the fold (``shm_entries``), the export
    counter stays zero, and hit/miss/dedup counters are populated; forcing
    ``shm=False`` flips the split to ``export_entries``."""
    from repro.core.autotuner import make_mdp
    from repro.core.ensemble import ProTuner
    from repro.core.mcts import MCTSConfig

    def run(**kw):
        return ProTuner(
            CachedMDP(make_mdp(*TRAIN_CELL)), n_standard=2, n_greedy=1,
            mcts_config=MCTSConfig(iters_per_decision=8), seed=5,
            parallel=True, n_workers=2, **kw,
        ).run()

    shm = run(shm=True)
    assert shm.stats["shm"] is True
    workers = shm.stats["workers"]
    assert len(workers) == 2
    assert sum(w.get("shm_entries", 0) for w in workers) > 0
    assert sum(w.get("export_entries", 0) for w in workers) == 0
    assert sum(w.get("hits", 0) + w.get("misses", 0) for w in workers) > 0
    assert len(shm.stats["dup_evals_rounds"]) > 0

    exp = run(shm=False)
    assert exp.stats["shm"] is False
    assert sum(w.get("shm_entries", 0) for w in exp.stats["workers"]) == 0
    assert sum(
        w.get("export_entries", 0) for w in exp.stats["workers"]) > 0
    # transports are interchangeable: same plan either way
    assert shm.plan == exp.plan and shm.cost == exp.cost
