"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts shapes and absence of NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import InputShape
from repro.core.space import SchedulePlan
from repro.models import transformer
from repro.models.losses import cross_entropy
from repro.training import optimizer as optim
from repro.training.train_step import make_train_step

B, S = 2, 32

# the biggest reduced configs still take tens of seconds of XLA compile on
# CPU — run them in the slow lane, keep the small archs in tier-1
_HEAVY = {"jamba-1.5-large-398b", "falcon-mamba-7b", "qwen2-vl-72b",
          "musicgen-large"}
ARCHS_TIERED = [
    pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
    for a in ARCH_IDS
]


def _inputs(cfg, key):
    if cfg.input_kind == "tokens":
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    if cfg.pos_kind == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S)[None, None, :], (B, 3, S)).astype(jnp.int32)
    else:
        pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return inputs, pos, labels


@pytest.mark.parametrize("arch", ARCHS_TIERED)
def test_forward_shapes_no_nans(arch, rng_key):
    cfg = get_config(arch).reduced()
    params = transformer.init_params(cfg, rng_key)
    inputs, pos, _ = _inputs(cfg, rng_key)
    logits = transformer.forward(params, cfg, inputs, pos)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCHS_TIERED)
def test_train_step_no_nans(arch, rng_key):
    cfg = get_config(arch).reduced()
    shape = InputShape("t", S, B, "train")
    plan = SchedulePlan(microbatches=2, remat="dots", grad_comm="fp32")
    oc = optim.OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)
    step = jax.jit(make_train_step(cfg, shape, plan, oc))
    params = transformer.init_params(cfg, rng_key)
    opt_state = optim.init_opt_state(params, oc)
    inputs, pos, labels = _inputs(cfg, rng_key)
    batch = {"inputs": inputs, "labels": labels, "positions": pos}
    params2, opt2, m = step(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0


@pytest.mark.slow  # token-by-token decode compiles T distinct step programs
@pytest.mark.parametrize(
    "arch", ["granite-3-2b", "falcon-mamba-7b", "jamba-1.5-large-398b"]
)
def test_decode_matches_forward(arch, rng_key):
    """The strongest cache-correctness check: token-by-token decode must
    reproduce the teacher-forced forward logits (validates KV cache update,
    Mamba conv/ssm state carry, position handling)."""
    cfg = get_config(arch).reduced()
    params = transformer.init_params(cfg, rng_key)
    T = 8
    toks = jax.random.randint(rng_key, (B, T), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T)).astype(jnp.int32)
    full_logits = transformer.forward(params, cfg, toks, pos)  # (B,T,V)
    cache = transformer.init_cache(cfg, B, T)
    last = None
    for t in range(T):
        last, cache = transformer.decode_step(
            params, cfg, cache, toks[:, t : t + 1], jnp.int32(t)
        )
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, -1, :]), atol=2e-3, rtol=2e-3
    )


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen2-vl-72b"])
def test_decode_int8_kv_close_to_bf16(arch, rng_key):
    cfg = get_config(arch).reduced()
    params = transformer.init_params(cfg, rng_key)
    if cfg.input_kind == "tokens":
        tok = jnp.array([[5], [7]])
    else:
        tok = jax.random.normal(rng_key, (B, 1, cfg.d_model))
    l1, _ = transformer.decode_step(
        params, cfg, transformer.init_cache(cfg, B, 16), tok, jnp.int32(0)
    )
    l2, _ = transformer.decode_step(
        params, cfg, transformer.init_cache(cfg, B, 16, "int8"), tok, jnp.int32(0)
    )
    assert float(jnp.max(jnp.abs(l1 - l2))) < 0.05


def test_unrolled_forward_matches_scanned(rng_key):
    cfg = get_config("granite-3-2b").reduced()
    params = transformer.init_params(cfg, rng_key)
    inputs, pos, _ = _inputs(cfg, rng_key)
    a = transformer.forward(params, cfg, inputs, pos, unroll=False)
    b = transformer.forward(params, cfg, inputs, pos, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_loss_decreases_quickly(rng_key):
    from repro.data.pipeline import Pipeline

    cfg = get_config("granite-3-2b").reduced()
    shape = InputShape("t", 64, 8, "train")
    plan = SchedulePlan(microbatches=1, remat="none")
    oc = optim.OptimizerConfig(peak_lr=1e-2, warmup_steps=5, total_steps=40)
    step = jax.jit(make_train_step(cfg, shape, plan, oc))
    params = transformer.init_params(cfg, rng_key)
    opt_state = optim.init_opt_state(params, oc)
    pipe = Pipeline(cfg, shape)
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses
