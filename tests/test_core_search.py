"""MCTS / beam / ensemble unit tests, including a synthetic MDP with a known
optimum that greedy search provably misses (the paper's §3 trap)."""
import math
import random

import pytest

from repro.core.beam import beam_search, greedy_search
from repro.core.ensemble import ProTuner
from repro.core.mcts import MCTS, MCTSConfig
from repro.core.mdp import ScheduleMDP
from repro.core.random_search import random_search
from repro.core.autotuner import autotune, make_mdp
from repro.core.space import SINGLE_POD, ScheduleSpace
from repro.configs import get_config, get_shape


# ---------------------------------------------------------------------------
# A synthetic MDP with a deceptive landscape: two binary stages; taking the
# greedy-best first action leads to a local optimum.
# ---------------------------------------------------------------------------
class TrapMDP:
    """partial_cost is misleading: prefix (1,) completes (by default) to
    cost 10, prefix (0,) to cost 5; but the true optimum is (1, 1) = 1."""

    costs = {(0, 0): 5.0, (0, 1): 6.0, (1, 0): 10.0, (1, 1): 1.0}
    defaults = [0, 0]

    def __init__(self):
        self.n_evals = 0
        self.cost_model = self

    initial_state = ()

    def n_actions(self, state):
        return 2

    def step(self, state, a):
        return state + (a,)

    def is_terminal(self, state):
        return len(state) == 2

    def plan(self, state):
        return state

    def terminal_cost(self, state):
        self.n_evals += 1
        return self.costs[state]

    def partial_cost(self, state):
        full = tuple(list(state) + self.defaults[len(state):])
        return self.costs[full]

    # ScheduleMDP API compat
    @property
    def space(self):
        class _S:
            n_stages = 2
            stages = [type("St", (), {"name": "s0"}), type("St", (), {"name": "s1"})]
        return _S()


def test_greedy_falls_into_the_trap():
    res = greedy_search(TrapMDP())
    assert res.cost == 5.0  # local optimum — greedy never sees (1,1)


def test_mcts_escapes_the_trap():
    mdp = TrapMDP()
    tuner = ProTuner(mdp, n_standard=3, n_greedy=1,
                     mcts_config=MCTSConfig(iters_per_decision=32), seed=0)
    res = tuner.run()
    assert res.cost == 1.0


def test_beam_wide_enough_escapes():
    res = beam_search(TrapMDP(), beam_size=4, passes=1)
    assert res.cost == 1.0  # beam 4 covers the whole depth-1 frontier


# ---------------------------------------------------------------------------
# Real schedule MDP
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def mdp():
    return make_mdp("granite-moe-1b-a400m", "train_4k")


def test_mcts_single_tree_decision(mdp):
    t = MCTS(mdp, MCTSConfig(iters_per_decision=32, seed=1))
    res = t.run_decision()
    assert res.iterations == 32
    assert 0 <= res.action < mdp.n_actions(())
    assert res.best_state is not None and mdp.is_terminal(res.best_state)
    assert res.best_cost == mdp.terminal_cost(res.best_state)


def test_mcts_deterministic_given_seed(mdp):
    runs = []
    for _ in range(2):
        t = MCTS(mdp, MCTSConfig(iters_per_decision=64, seed=7))
        runs.append(t.run_decision())
    assert runs[0].action == runs[1].action
    assert runs[0].best_cost == runs[1].best_cost


def test_ensemble_advances_all_roots(mdp):
    tuner = ProTuner(mdp, n_standard=2, n_greedy=1,
                     mcts_config=MCTSConfig(iters_per_decision=8), seed=0)
    res = tuner.run()
    assert len(res.decisions) == mdp.space.n_stages
    for t in tuner.trees:
        assert t.done
    assert res.plan is not None
    # every decision recorded a stage name in order
    names = [d["stage"] for d in res.decisions]
    assert names == [s.name for s in mdp.space.stages]


def test_noise_is_lognormal_with_independent_uniforms():
    """Box-Muller needs two INDEPENDENT uniforms.  Pre-fix, the radius
    and angle of ``NoisyCostModel._noise`` both derived from the leading
    bytes of one 8-byte digest (the angle's bytes were a prefix of the
    radius's), correlating them and skewing the noise off the documented
    log-normal; ``or 0.5`` also silently remapped a zero angle.  Post-fix
    the log-noise over many plans is standard-normal to sampling
    accuracy."""
    from repro.core.autotuner import NoisyCostModel

    sigma = 0.25
    nm = NoisyCostModel(None, sigma=sigma, seed=7)
    zs = [math.log(nm._noise(i)) / sigma for i in range(4000)]
    n = len(zs)
    mean = sum(zs) / n
    std = math.sqrt(sum((z - mean) ** 2 for z in zs) / n)
    assert abs(mean) < 4 / math.sqrt(n), mean
    assert 0.93 < std < 1.07, std
    # seeded determinism survives the fix
    assert nm._noise(3) == NoisyCostModel(None, sigma, seed=7)._noise(3)
    assert nm._noise(3) != NoisyCostModel(None, sigma, seed=8)._noise(3)


def test_mcts_beats_or_matches_greedy_under_noise():
    """With a noisy cost model (the paper's setting) MCTS should not lose
    to greedy on average across seeds."""
    wins = ties = losses = 0
    for seed in range(5):
        mdp_g = make_mdp("phi3.5-moe-42b-a6.6b", "train_4k", noise_sigma=0.3,
                         noise_seed=seed)
        g = greedy_search(mdp_g, seed=seed)
        mdp_m = make_mdp("phi3.5-moe-42b-a6.6b", "train_4k", noise_sigma=0.3,
                         noise_seed=seed)
        m = autotune("phi3.5-moe-42b-a6.6b", "train_4k", algo="mcts_1s",
                     seed=seed, mdp=mdp_m, n_standard=3, n_greedy=1)
        # compare TRUE (noise-free) cost of chosen plans
        clean = make_mdp("phi3.5-moe-42b-a6.6b", "train_4k").cost_model
        gc, mc = clean.cost(g.plan), clean.cost(m.plan)
        if mc < gc * 0.999:
            wins += 1
        elif mc > gc * 1.001:
            losses += 1
        else:
            ties += 1
    assert wins + ties >= losses, (wins, ties, losses)


def test_greedy_is_beam_one(mdp):
    # same ranking signal: greedy == beam(k=1, 1 pass)
    g = greedy_search(make_mdp("granite-3-2b", "train_4k"), seed=3)
    b = beam_search(make_mdp("granite-3-2b", "train_4k"), beam_size=1, passes=1, seed=3)
    assert g.plan == b.plan


def test_random_search_improves_with_budget():
    m1 = make_mdp("granite-3-2b", "train_4k")
    r_small = random_search(m1, n_samples=4, seed=0)
    m2 = make_mdp("granite-3-2b", "train_4k")
    r_big = random_search(m2, n_samples=512, seed=0)
    assert r_big.cost <= r_small.cost


@pytest.mark.slow  # full 384-iteration Table-1 ensembles, ~30s
def test_table1_variants_run(mdp):
    from repro.core.autotuner import TABLE1

    for name in ("mcts_1s", "mcts_Cp10_30s", "mcts_sqrt2_30s", "mcts_binary_30s"):
        res = autotune("granite-moe-1b-a400m", "train_4k", algo=name, seed=0,
                       n_standard=2, n_greedy=1)
        assert res.plan is not None and res.cost > 0
