"""Shared fixtures. NOTE: never set xla_force_host_platform_device_count
here — smoke tests and benches must see 1 device; multi-device tests run in
subprocesses (see test_distributed.py)."""
import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture(scope="session")
def rng_key():
    import jax

    return jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Table-1 cell construction — ONE definition shared by the differential /
# engine / serving / service suites (each used to carry its own copy).
# Import as ``from conftest import TABLE1_CELLS, make_cell_mdp``.
# ---------------------------------------------------------------------------
MOE_TRAIN_CELL = ("granite-moe-1b-a400m", "train_4k")  # the MoE train cell
DECODE_CELL = ("granite-3-2b", "decode_32k")           # the decode cell
TRAIN_CELL = ("granite-3-2b", "train_4k")              # the dense train cell

# the differential grid's two headline cells (paper Table 1)
TABLE1_CELLS = {"moe_train": MOE_TRAIN_CELL, "decode": DECODE_CELL}


def make_cell_mdp(arch, shape_name, *, reduced=True, pricing=None,
                  columnar_min_batch=None):
    """A fresh ``ScheduleMDP`` for one Table-1 cell.

    ``reduced=True`` (the suites' default) shrinks the arch config so
    search grids stay inside the tier-1 budget; ``pricing`` /
    ``columnar_min_batch`` pass straight through to ``AnalyticCostModel``
    (None → the production defaults).  Engine-parity tests that need the
    FULL config use ``repro.core.autotuner.make_mdp`` directly."""
    from repro.configs import get_config, get_shape
    from repro.core.cost_model import AnalyticCostModel
    from repro.core.mdp import ScheduleMDP
    from repro.core.space import SINGLE_POD, ScheduleSpace

    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    shape = get_shape(shape_name)
    space = ScheduleSpace(cfg, shape, SINGLE_POD)
    cm = AnalyticCostModel(cfg, shape, SINGLE_POD, pricing=pricing,
                          columnar_min_batch=columnar_min_batch)
    return ScheduleMDP(space, cm)
