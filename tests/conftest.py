"""Shared fixtures. NOTE: never set xla_force_host_platform_device_count
here — smoke tests and benches must see 1 device; multi-device tests run in
subprocesses (see test_distributed.py)."""
import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture(scope="session")
def rng_key():
    import jax

    return jax.random.PRNGKey(0)
