"""Analytic cost model + HLO analysis tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_shape
from repro.core.autotuner import make_mdp
from repro.core.cost_model import AnalyticCostModel, HW
from repro.core.hlo_analysis import analyze
from repro.core.space import SINGLE_POD, MULTI_POD, SchedulePlan, ScheduleSpace


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_terms_positive_and_finite_for_all_archs(arch):
    for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
        mdp = make_mdp(arch, shape_name)
        plan = mdp.space.plan_from_actions(mdp.space.default_actions())
        t = mdp.cost_model.terms(plan)
        assert t.compute_s > 0 and t.memory_s > 0
        assert np.isfinite(t.step_s) and t.step_s > 0
        assert t.model_flops > 0


def test_flops_close_to_6nd_for_dense_train():
    cfg, shape = get_config("deepseek-67b"), get_shape("train_4k")
    cm = AnalyticCostModel(cfg, shape, SINGLE_POD)
    plan = SchedulePlan(remat="none")
    t = cm.terms(plan)
    model = 6 * cfg.param_count() * shape.tokens
    # structural fwd+bwd ≈ 3×fwd ≈ 6ND + attention extra: within 40%
    assert model * 0.9 < t.flops < model * 1.6, (t.flops / model)


def test_remat_increases_compute_reduces_memory_capacity():
    mdp = make_mdp("qwen2-vl-72b", "train_4k")
    base = mdp.space.plan_from_actions(mdp.space.default_actions())
    import dataclasses

    none_p = dataclasses.replace(base, remat="none")
    full_p = dataclasses.replace(base, remat="full")
    t_none, t_full = mdp.cost_model.terms(none_p), mdp.cost_model.terms(full_p)
    assert t_full.compute_s > t_none.compute_s
    assert t_full.hbm_per_chip < t_none.hbm_per_chip


def test_int8_gradcomm_reduces_collective():
    import dataclasses

    mdp = make_mdp("granite-3-2b", "train_4k")
    base = dataclasses.replace(
        mdp.space.plan_from_actions(mdp.space.default_actions()),
        param_strategy="tp",
    )
    int8 = dataclasses.replace(base, grad_comm="int8")
    assert (
        mdp.cost_model.terms(int8).collective_s
        < mdp.cost_model.terms(base).collective_s
    )


def test_infeasible_plan_penalized():
    mdp = make_mdp("jamba-1.5-large-398b", "train_4k")
    bad = SchedulePlan(param_strategy="replicated", remat="none", microbatches=1)
    good = mdp.space.plan_from_actions(mdp.space.default_actions())
    tb, tg = mdp.cost_model.terms(bad), mdp.cost_model.terms(good)
    assert not tb.feasible
    assert tb.step_s > 50 * tg.step_s


def test_partial_cost_equals_terminal_at_full_depth():
    mdp = make_mdp("granite-3-2b", "train_4k")
    actions = mdp.space.default_actions()
    state = tuple(actions)
    assert mdp.partial_cost(state) == pytest.approx(mdp.terminal_cost(state))


def test_multi_pod_batch_axes_matter():
    mdp = make_mdp("granite-3-2b", "train_4k", mesh="multi")
    import dataclasses

    base = mdp.space.plan_from_actions(mdp.space.default_actions())
    single = dataclasses.replace(base, batch_axes="data")
    double = dataclasses.replace(base, batch_axes="pod_data")
    ts, td = mdp.cost_model.terms(single), mdp.cost_model.terms(double)
    assert ts.step_s != td.step_s  # the pod axis is not free


# ---------------------------------------------------------------------------
# Schedule space
# ---------------------------------------------------------------------------
def test_space_collapses_inapplicable_stages():
    dense = ScheduleSpace(get_config("deepseek-67b"), get_shape("train_4k"), SINGLE_POD)
    moe = ScheduleSpace(get_config("phi3.5-moe-42b-a6.6b"), get_shape("train_4k"), SINGLE_POD)
    names_d = {s.name: len(s.options) for s in dense.stages}
    names_m = {s.name: len(s.options) for s in moe.stages}
    assert names_d["moe_mode"] == 1 and names_m["moe_mode"] == 3
    ssm = ScheduleSpace(get_config("falcon-mamba-7b"), get_shape("train_4k"), SINGLE_POD)
    names_s = {s.name: len(s.options) for s in ssm.stages}
    assert "attn_block" not in names_s and "scan_chunk" in names_s
    assert names_s["ffn_tp"] == 1  # no FFN in mamba-1
    decode = ScheduleSpace(get_config("deepseek-67b"), get_shape("decode_32k"), SINGLE_POD)
    names_dec = {s.name: len(s.options) for s in decode.stages}
    assert names_dec["microbatches"] == 1 and names_dec["remat"] == 1
    assert names_dec["kv_dtype"] == 2


def test_plan_roundtrip_and_random_valid():
    import random

    space = ScheduleSpace(get_config("jamba-1.5-large-398b"), get_shape("train_4k"), MULTI_POD)
    rng = random.Random(0)
    for _ in range(50):
        actions = space.random_actions(rng)
        plan = space.plan_from_actions(actions)
        d = plan.to_dict()
        assert SchedulePlan.from_dict(d) == plan


# ---------------------------------------------------------------------------
# HLO analysis (trip-count folding)
# ---------------------------------------------------------------------------
def test_hlo_analysis_folds_scan_trip_counts():
    def scanned(x, ws):
        def body(c, w):
            return c @ w, None

        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((9, 64, 64), jnp.float32)
    comp = jax.jit(scanned).lower(x, ws).compile()
    res = analyze(comp.as_text())
    expected = 9 * 2 * 64 * 64 * 64
    assert res["dot_flops"] == pytest.approx(expected, rel=0.01), (
        res["dot_flops"], expected, "XLA raw:", comp.cost_analysis().get("flops"),
    )


def test_hlo_analysis_counts_nested_loops():
    def nested(x, ws):
        def outer(c, _):
            def inner(ci, w):
                return ci @ w, None

            c2, _ = jax.lax.scan(inner, c, ws)
            return c2, None

        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    comp = jax.jit(nested).lower(x, ws).compile()
    res = analyze(comp.as_text())
    expected = 3 * 5 * 2 * 32 * 32 * 32
    assert res["dot_flops"] == pytest.approx(expected, rel=0.01)


@pytest.mark.slow  # subprocess XLA compile on a forced 8-device host
def test_hlo_analysis_collectives_on_sharded_matmul():
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core.hlo_analysis import analyze
        mesh = jax.make_mesh((8,), ("model",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        def f(x, w):
            y = x @ w
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, P(None, None)))
        x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 64), jnp.float32)
        comp = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P(None, "model")),
            NamedSharding(mesh, P("model", None)))).lower(x, w).compile()
        res = analyze(comp.as_text())
        total = sum(res["coll"].values())
        assert total > 0, res
        print("COLL_OK", total)
        """
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=__import__("os").path.dirname(__import__("os").path.dirname(__file__)),
        timeout=300,
    )
    assert "COLL_OK" in out.stdout, out.stdout + out.stderr
