"""Differential-testing harness: certifies the array engine everywhere.

Runs the ``reference`` Node-tree MCTS against ``ArrayMCTS`` in BOTH its
modes — scalar (one-at-a-time leaf evaluation, ``run_decision``) and
batched (lockstep pending-leaf rounds, ``run_decision_batch``) — over the
full configuration grid:

    UCB variant (paper | cp10 | sqrt2)
  × simulation policy (random | greedy)
  × reward mode (cost | binary)
  × 3 seeds
  × 2 model configs (a train MoE cell and a decode cell)

and asserts byte-identical trajectories: the same decision sequence, the
same per-decision best costs, and the same final best schedule.  This is
the parity coverage required before ``engine="array"`` became the default
in ``autotune`` / ``benchmarks.common.run_algo`` — any float drift, RNG
reordering, or tie-break change in the array engine fails loudly here.

The same grid also certifies the COLUMNAR PRICING KERNEL: a fourth leg
drives the batched engine over an MDP priced by the pre-columnar scalar
oracle (``AnalyticCostModel(columnar=False)``) while the other three legs
price through the column kernel with the small-batch dispatch disabled
(``columnar_min_batch=1`` — every batch, including every batch of one,
runs the vectorized kernel).  Identical trajectories mean the kernel
reproduces the scalar arithmetic bit-for-bit on every schedule the search
visits; any rounding difference would flip a UCB comparison somewhere in
the grid and fail loudly.

Engines sharing a pricing mode share a single ``CachedMDP`` per cell (the
two pricing modes get SEPARATE caches, so a cached value from one can
never mask a divergence in the other).  The cache is a pure memo
(identical values cached or not) — it only deduplicates pricing across
the grid's hundreds of trajectories, keeping the harness inside the
tier-1 budget.
"""
import pytest
from conftest import TABLE1_CELLS as CELLS
from conftest import make_cell_mdp

from repro.core.autotuner import autotune
from repro.core.engine import ArrayMCTS, CachedMDP
from repro.core.engine.batch import run_decision_batch
from repro.core.ensemble import ProTuner
from repro.core.mcts import MCTS, MCTSConfig

_SHARED = {}


def _mdp(cell: str, pricing: str = "columnar") -> CachedMDP:
    """One shared (cached) MDP per (cell, pricing mode) for the module.

    ``columnar`` forces every batch — every batch of ONE included —
    through the vectorized kernel (``columnar_min_batch=1``); ``scalar``
    is the pre-columnar per-plan oracle.  Separate caches per mode, so
    the memo cannot cross-feed values between the paths under test."""
    key = (cell, pricing)
    if key not in _SHARED:
        arch, shape_name = CELLS[cell]
        min_batch = 1 if pricing == "columnar" else None
        _SHARED[key] = CachedMDP(make_cell_mdp(
            arch, shape_name, pricing=pricing, columnar_min_batch=min_batch
        ))
    return _SHARED[key]


def _drive(tree, batched: bool = False, mdp=None):
    """Full tuning trajectory with one tree: every decision round, with
    tree reuse across rounds.  Returns everything an engine can diverge
    on."""
    actions, costs = [], []
    while not tree.done:
        if batched:
            res = run_decision_batch([tree], mdp)[0]
        else:
            res = tree.run_decision()
        actions.append(res.action)
        costs.append(res.best_cost)
        tree.advance_root(res.action)
    return actions, costs, tree.global_best, tree.global_best_state


# ---------------------------------------------------------------------------
# The grid
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cell", list(CELLS))
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("reward", ["cost", "binary"])
@pytest.mark.parametrize("simulation", ["random", "greedy"])
@pytest.mark.parametrize("ucb", ["paper", "cp10", "sqrt2"])
def test_engines_identical_across_grid(ucb, simulation, reward, seed, cell):
    mdp = _mdp(cell)
    cfg = MCTSConfig(
        ucb=ucb,
        simulation=simulation,
        reward_mode=reward,
        iters_per_decision=8,
        seed=seed,
    )
    ref = _drive(MCTS(mdp, cfg))
    arr = _drive(ArrayMCTS(mdp, cfg))
    bat = _drive(ArrayMCTS(mdp, cfg), batched=True, mdp=mdp)
    assert arr == ref, "scalar array engine diverged from reference"
    assert bat == ref, "batched array engine diverged from reference"
    # columnar-vs-scalar pricing leg: the batched engine over the
    # pre-columnar scalar oracle must reproduce the kernel-priced
    # trajectory exactly — bit-identical pricing, certified on the grid
    mdp_s = _mdp(cell, "scalar")
    sca = _drive(ArrayMCTS(mdp_s, cfg), batched=True, mdp=mdp_s)
    assert sca == ref, "scalar-oracle pricing diverged from columnar kernel"


# ---------------------------------------------------------------------------
# Ensemble level: the full ProTuner loop (root synchronization, winner
# selection, tree reuse) across all three engine modes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cell", list(CELLS))
def test_ensemble_identical_across_engines(cell):
    def run(**kw):
        res = ProTuner(
            _mdp(cell),
            n_standard=2,
            n_greedy=1,
            mcts_config=MCTSConfig(iters_per_decision=10),
            seed=3,
            **kw,
        ).run()
        return (
            res.plan,
            res.cost,
            [d["action"] for d in res.decisions],
            [d["best_cost"] for d in res.decisions],
            [d["winner_tree"] for d in res.decisions],
        )

    ref = run(engine="reference", cache=False)
    arr = run(engine="array", batch=False)
    bat = run(engine="array", batch=True)
    # pinned process-pool workers, pool defaults (shm transport +
    # in-worker lockstep batching auto-on for this pure-analytic run)
    par = run(engine="array", parallel=True)
    # the transport/batching matrix at 2 workers (trees split across
    # workers, so the shm fold and the export echo both carry real
    # cross-worker traffic): export baseline, shm without in-worker
    # batching, shm with it — all bit-identical
    exp = run(engine="array", parallel=True, n_workers=2,
              shm=False, worker_batch=False)
    shm = run(engine="array", parallel=True, n_workers=2,
              shm=True, worker_batch=False)
    lock = run(engine="array", parallel=True, n_workers=2,
               shm=True, worker_batch=True)
    # run-controller leg (core/run_control.py): an UNINTERRUPTED run with
    # the controller mounted — checkpoint cadence firing into a sink —
    # must stay bit-identical to a controller-free run (the controller
    # reads a clock and pickles snapshots; it never touches search state)
    from repro.core.run_control import RunController

    sink = []
    con = run(engine="array", batch=True,
              controller=RunController(checkpoint_every=2,
                                       checkpoint_fn=sink.append))
    assert arr == ref
    assert bat == ref
    assert par == ref
    assert exp == ref
    assert shm == ref
    assert lock == ref
    assert con == ref
    assert sink, "checkpoint cadence never fired"


# ---------------------------------------------------------------------------
# Parallel legs over the grid dimensions: the pinned process pool
# (engine/workers.py) must reproduce the sequential ensemble bit-for-bit
# for every UCB variant / simulation policy / reward mode, across both
# cache transports (shared-memory log vs pickled exports) and both
# in-worker evaluation modes (lockstep-batched vs per-tree).  One
# representative config per UCB keeps the pool spawns inside the tier-1
# budget; the full sequential grid above already certifies the engines,
# and the pool's transport is value-blind (pure-memo cache entries +
# per-round tree deltas), so any divergence here is a protocol bug.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "ucb,simulation,reward,shm,worker_batch",
    [
        ("paper", "random", "cost", None, None),    # pool defaults
        ("cp10", "greedy", "binary", False, False),  # export transport
        ("sqrt2", "greedy", "cost", True, True),     # shm + lockstep
    ],
)
def test_parallel_identical_across_grid(ucb, simulation, reward, shm,
                                        worker_batch):
    cfg = MCTSConfig(
        ucb=ucb, simulation=simulation, reward_mode=reward,
        iters_per_decision=8,
    )

    def run(parallel):
        res = ProTuner(
            _mdp("moe_train"), n_standard=2, n_greedy=1, mcts_config=cfg,
            seed=1, parallel=parallel, n_workers=2, shm=shm,
            worker_batch=worker_batch,
        ).run()
        return (
            res.plan,
            res.cost,
            [d["action"] for d in res.decisions],
            [d["best_cost"] for d in res.decisions],
            [d["winner_tree"] for d in res.decisions],
        )

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# Service leg: the tuner daemon's COLD path (fresh store, warm-cell cache
# mounted, shared machinery) must reproduce one-shot autotune bit-for-bit
# — same plan, same exact cost, same decision trace — and the daemon's
# WARM answer must equal its own cold one after a store round-trip.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cell", list(CELLS))
def test_service_cold_path_identical_to_autotune(cell, tmp_path):
    from repro.service import TunerService

    arch, shape_name = CELLS[cell]
    kw = dict(algo="mcts_1s", seed=2, n_standard=2, n_greedy=1)
    ref = autotune(arch, shape_name, **kw)

    svc = TunerService(str(tmp_path / "store"), log=lambda *a: None)
    cold = svc.handle(dict(arch=arch, shape=shape_name, **kw))
    warm = svc.handle(dict(arch=arch, shape=shape_name, **kw))
    svc.shutdown()

    assert cold["served"] == "search" and warm["served"] == "store"
    for out in (cold, warm):
        assert out["result"]["plan"] == ref.plan.to_dict()
        assert out["result"]["cost"] == ref.cost
        assert out["result"]["decisions"] == ref.decisions


# ---------------------------------------------------------------------------
# Default flip: with the grid green, the array engine is the default
# ---------------------------------------------------------------------------
def test_array_engine_is_the_default():
    res = autotune(
        "granite-moe-1b-a400m", "train_4k", algo="mcts_1s", seed=0,
        n_standard=2, n_greedy=1,
    )
    assert res.engine == "array"
    assert res.cache_hits > 0  # shared transposition cache on by default

    tuner = ProTuner(_mdp("decode"), n_standard=1, n_greedy=0)
    assert tuner.engine == "array" and tuner.batch and tuner.cache is not None

    from benchmarks.common import run_algo

    res2, _ = run_algo("granite-moe-1b-a400m", "train_4k", "mcts_1s", seed=0,
                       n_standard=2, n_greedy=1)
    assert res2.engine == "array"


# ---------------------------------------------------------------------------
# Evolutionary + portfolio legs: fixed seed × both cells × exact analytic
# cost.  These pin (a) run-to-run determinism on fresh caches, (b) the
# eval-budget accounting contract — generation pricing hits the cost model
# exactly ONCE per unique plan, i.e. ``n_evals == cache.misses`` — and
# (c) that the portfolio's reported winner is the best member's result
# bit-for-bit.
# ---------------------------------------------------------------------------
def _fresh_cached(cell: str) -> CachedMDP:
    arch, shape_name = CELLS[cell]
    return CachedMDP(make_cell_mdp(arch, shape_name))


def _strip_wall(decisions):
    return [{k: v for k, v in d.items() if k != "wall_time_s"}
            for d in decisions]


@pytest.mark.parametrize("cell", list(CELLS))
@pytest.mark.parametrize("seed", [0, 1])
def test_evolve_deterministic_with_exact_eval_accounting(cell, seed):
    from repro.core.evolve import EvolutionarySearchBackend

    def run(mdp):
        return EvolutionarySearchBackend(population=16, generations=8).run(
            mdp, seed=seed
        )

    mdp_a, mdp_b = _fresh_cached(cell), _fresh_cached(cell)
    a, b = run(mdp_a), run(mdp_b)
    # run-to-run determinism on fresh caches: bit-identical everything
    assert a.plan == b.plan and a.cost == b.cost
    assert a.n_evals == b.n_evals and a.decisions == b.decisions
    # eval-budget accounting: each unique plan priced exactly once for the
    # whole run — the shared cache's misses ARE the model evals (revisits
    # are hits, and the final best-plan re-read is a hit too)
    assert a.n_evals == mdp_a.cache.misses == a.cache_misses
    assert a.cache_hits == mdp_a.cache.hits > 0
    # warm rerun over the SAME cache: zero new pricings, identical result
    # values (the cache is a pure memo — only eval counts change)
    c = run(mdp_a)
    assert c.plan == a.plan and c.cost == a.cost
    assert c.n_evals == a.n_evals  # no new evals: everything was cached


@pytest.mark.parametrize("cell", list(CELLS))
def test_portfolio_winner_is_best_member_bit_for_bit(cell):
    from repro.core.evolve import PortfolioBackend

    def run():
        mdp = _fresh_cached(cell)
        return PortfolioBackend().run(
            mdp, seed=0, n_standard=2, n_greedy=1
        ), mdp

    res, mdp = run()
    assert res.algo == "portfolio"
    assert [d["member"] for d in res.decisions] == [
        "evolve", "mcts_1s", "beam", "random"]
    winners = [d for d in res.decisions if d["winner"]]
    assert len(winners) == 1
    # the reported winner IS the best member's result, unmodified
    assert winners[0]["plan"] == res.plan.to_dict()
    assert winners[0]["cost"] == res.cost
    assert res.cost == min(d["cost"] for d in res.decisions)
    # unique-plan accounting across ALL members through the one shared cache
    assert res.n_evals == mdp.cache.misses == res.cache_misses
    # run-to-run determinism (wall times aside)
    res2, _ = run()
    assert res2.plan == res.plan and res2.cost == res.cost
    assert res2.n_evals == res.n_evals
    assert _strip_wall(res2.decisions) == _strip_wall(res.decisions)


def test_portfolio_shared_budget_skips_members_once_spent():
    from repro.core.evolve import PortfolioBackend

    mdp = _fresh_cached("decode")
    res = PortfolioBackend().run(
        mdp, seed=0, max_evals=40, n_standard=2, n_greedy=1
    )
    ran = [d["member"] for d in res.decisions]
    # evolve's first generations spend the budget; later members are
    # skipped entirely (not run with a zero budget)
    assert ran[0] == "evolve" and len(ran) < 4
    assert res.cost == min(d["cost"] for d in res.decisions)


@pytest.mark.parametrize("cell", list(CELLS))
def test_autotune_routes_evolve_and_portfolio(cell):
    arch, shape_name = CELLS[cell]
    r1 = autotune(arch, shape_name, algo="evolve", seed=0)
    r2 = autotune(arch, shape_name, algo="evolve", seed=0)
    assert r1.algo == "evolve" and r1.plan == r2.plan and r1.cost == r2.cost
    rp = autotune(arch, shape_name, algo="portfolio", seed=0,
                  n_standard=2, n_greedy=1)
    assert rp.algo == "portfolio"
    assert rp.cost <= r1.cost  # the portfolio contains an evolve member
