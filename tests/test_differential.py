"""Differential-testing harness: certifies the array engine everywhere.

Runs the ``reference`` Node-tree MCTS against ``ArrayMCTS`` in BOTH its
modes — scalar (one-at-a-time leaf evaluation, ``run_decision``) and
batched (lockstep pending-leaf rounds, ``run_decision_batch``) — over the
full configuration grid:

    UCB variant (paper | cp10 | sqrt2)
  × simulation policy (random | greedy)
  × reward mode (cost | binary)
  × 3 seeds
  × 2 model configs (a train MoE cell and a decode cell)

and asserts byte-identical trajectories: the same decision sequence, the
same per-decision best costs, and the same final best schedule.  This is
the parity coverage required before ``engine="array"`` became the default
in ``autotune`` / ``benchmarks.common.run_algo`` — any float drift, RNG
reordering, or tie-break change in the array engine fails loudly here.

The same grid also certifies the COLUMNAR PRICING KERNEL: a fourth leg
drives the batched engine over an MDP priced by the pre-columnar scalar
oracle (``AnalyticCostModel(columnar=False)``) while the other three legs
price through the column kernel with the small-batch dispatch disabled
(``columnar_min_batch=1`` — every batch, including every batch of one,
runs the vectorized kernel).  Identical trajectories mean the kernel
reproduces the scalar arithmetic bit-for-bit on every schedule the search
visits; any rounding difference would flip a UCB comparison somewhere in
the grid and fail loudly.

Engines sharing a pricing mode share a single ``CachedMDP`` per cell (the
two pricing modes get SEPARATE caches, so a cached value from one can
never mask a divergence in the other).  The cache is a pure memo
(identical values cached or not) — it only deduplicates pricing across
the grid's hundreds of trajectories, keeping the harness inside the
tier-1 budget.
"""
import pytest

from repro.configs import get_config, get_shape
from repro.core.autotuner import autotune
from repro.core.cost_model import AnalyticCostModel
from repro.core.engine import ArrayMCTS, CachedMDP
from repro.core.engine.batch import run_decision_batch
from repro.core.ensemble import ProTuner
from repro.core.mcts import MCTS, MCTSConfig
from repro.core.mdp import ScheduleMDP
from repro.core.space import SINGLE_POD, ScheduleSpace

CELLS = {
    "moe_train": ("granite-moe-1b-a400m", "train_4k"),
    "decode": ("granite-3-2b", "decode_32k"),
}

_SHARED = {}


def _mdp(cell: str, pricing: str = "columnar") -> CachedMDP:
    """One shared (cached) MDP per (cell, pricing mode) for the module.

    ``columnar`` forces every batch — every batch of ONE included —
    through the vectorized kernel (``columnar_min_batch=1``); ``scalar``
    is the pre-columnar per-plan oracle.  Separate caches per mode, so
    the memo cannot cross-feed values between the paths under test."""
    key = (cell, pricing)
    if key not in _SHARED:
        arch, shape_name = CELLS[cell]
        cfg = get_config(arch).reduced()
        shape = get_shape(shape_name)
        space = ScheduleSpace(cfg, shape, SINGLE_POD)
        if pricing == "columnar":
            cm = AnalyticCostModel(
                cfg, shape, SINGLE_POD, columnar=True, columnar_min_batch=1
            )
        else:
            cm = AnalyticCostModel(cfg, shape, SINGLE_POD, columnar=False)
        _SHARED[key] = CachedMDP(ScheduleMDP(space, cm))
    return _SHARED[key]


def _drive(tree, batched: bool = False, mdp=None):
    """Full tuning trajectory with one tree: every decision round, with
    tree reuse across rounds.  Returns everything an engine can diverge
    on."""
    actions, costs = [], []
    while not tree.done:
        if batched:
            res = run_decision_batch([tree], mdp)[0]
        else:
            res = tree.run_decision()
        actions.append(res.action)
        costs.append(res.best_cost)
        tree.advance_root(res.action)
    return actions, costs, tree.global_best, tree.global_best_state


# ---------------------------------------------------------------------------
# The grid
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cell", list(CELLS))
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("reward", ["cost", "binary"])
@pytest.mark.parametrize("simulation", ["random", "greedy"])
@pytest.mark.parametrize("ucb", ["paper", "cp10", "sqrt2"])
def test_engines_identical_across_grid(ucb, simulation, reward, seed, cell):
    mdp = _mdp(cell)
    cfg = MCTSConfig(
        ucb=ucb,
        simulation=simulation,
        reward_mode=reward,
        iters_per_decision=8,
        seed=seed,
    )
    ref = _drive(MCTS(mdp, cfg))
    arr = _drive(ArrayMCTS(mdp, cfg))
    bat = _drive(ArrayMCTS(mdp, cfg), batched=True, mdp=mdp)
    assert arr == ref, "scalar array engine diverged from reference"
    assert bat == ref, "batched array engine diverged from reference"
    # columnar-vs-scalar pricing leg: the batched engine over the
    # pre-columnar scalar oracle must reproduce the kernel-priced
    # trajectory exactly — bit-identical pricing, certified on the grid
    mdp_s = _mdp(cell, "scalar")
    sca = _drive(ArrayMCTS(mdp_s, cfg), batched=True, mdp=mdp_s)
    assert sca == ref, "scalar-oracle pricing diverged from columnar kernel"


# ---------------------------------------------------------------------------
# Ensemble level: the full ProTuner loop (root synchronization, winner
# selection, tree reuse) across all three engine modes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cell", list(CELLS))
def test_ensemble_identical_across_engines(cell):
    def run(**kw):
        res = ProTuner(
            _mdp(cell),
            n_standard=2,
            n_greedy=1,
            mcts_config=MCTSConfig(iters_per_decision=10),
            seed=3,
            **kw,
        ).run()
        return (
            res.plan,
            res.cost,
            [d["action"] for d in res.decisions],
            [d["best_cost"] for d in res.decisions],
            [d["winner_tree"] for d in res.decisions],
        )

    ref = run(engine="reference", cache=False)
    arr = run(engine="array", batch=False)
    bat = run(engine="array", batch=True)
    par = run(engine="array", parallel=True)  # pinned process-pool workers
    assert arr == ref
    assert bat == ref
    assert par == ref


# ---------------------------------------------------------------------------
# Parallel legs over the grid dimensions: the pinned process pool
# (engine/workers.py) must reproduce the sequential ensemble bit-for-bit
# for every UCB variant / simulation policy / reward mode.  One
# representative config per UCB keeps the pool spawns inside the tier-1
# budget; the full sequential grid above already certifies the engines,
# and the pool's transport is value-blind (pure-memo cache entries +
# per-round tree deltas), so any divergence here is a protocol bug.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "ucb,simulation,reward",
    [
        ("paper", "random", "cost"),
        ("cp10", "greedy", "binary"),
        ("sqrt2", "greedy", "cost"),
    ],
)
def test_parallel_identical_across_grid(ucb, simulation, reward):
    cfg = MCTSConfig(
        ucb=ucb, simulation=simulation, reward_mode=reward,
        iters_per_decision=8,
    )

    def run(parallel):
        res = ProTuner(
            _mdp("moe_train"), n_standard=2, n_greedy=1, mcts_config=cfg,
            seed=1, parallel=parallel,
        ).run()
        return (
            res.plan,
            res.cost,
            [d["action"] for d in res.decisions],
            [d["best_cost"] for d in res.decisions],
            [d["winner_tree"] for d in res.decisions],
        )

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# Service leg: the tuner daemon's COLD path (fresh store, warm-cell cache
# mounted, shared machinery) must reproduce one-shot autotune bit-for-bit
# — same plan, same exact cost, same decision trace — and the daemon's
# WARM answer must equal its own cold one after a store round-trip.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cell", list(CELLS))
def test_service_cold_path_identical_to_autotune(cell, tmp_path):
    from repro.service import TunerService

    arch, shape_name = CELLS[cell]
    kw = dict(algo="mcts_1s", seed=2, n_standard=2, n_greedy=1)
    ref = autotune(arch, shape_name, **kw)

    svc = TunerService(str(tmp_path / "store"), log=lambda *a: None)
    cold = svc.handle(dict(arch=arch, shape=shape_name, **kw))
    warm = svc.handle(dict(arch=arch, shape=shape_name, **kw))
    svc.shutdown()

    assert cold["served"] == "search" and warm["served"] == "store"
    for out in (cold, warm):
        assert out["result"]["plan"] == ref.plan.to_dict()
        assert out["result"]["cost"] == ref.cost
        assert out["result"]["decisions"] == ref.decisions


# ---------------------------------------------------------------------------
# Default flip: with the grid green, the array engine is the default
# ---------------------------------------------------------------------------
def test_array_engine_is_the_default():
    res = autotune(
        "granite-moe-1b-a400m", "train_4k", algo="mcts_1s", seed=0,
        n_standard=2, n_greedy=1,
    )
    assert res.engine == "array"
    assert res.cache_hits > 0  # shared transposition cache on by default

    tuner = ProTuner(_mdp("decode"), n_standard=1, n_greedy=0)
    assert tuner.engine == "array" and tuner.batch and tuner.cache is not None

    from benchmarks.common import run_algo

    res2, _ = run_algo("granite-moe-1b-a400m", "train_4k", "mcts_1s", seed=0,
                       n_standard=2, n_greedy=1)
    assert res2.engine == "array"
