"""Optimizer, checkpoint, trainer-loop, and data-pipeline tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.space import SchedulePlan
from repro.data.pipeline import DataConfig, Pipeline
from repro.training import optimizer as optim


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------
def test_adamw_matches_reference_math():
    oc = optim.OptimizerConfig(peak_lr=0.1, warmup_steps=0, total_steps=10**9,
                               b1=0.9, b2=0.99, weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.ones((2, 16))}
    grads = {"w": jnp.full((2, 16), 0.5)}
    state = optim.init_opt_state(params, oc)
    new_params, state, m = optim.apply_updates(params, grads, state, oc)
    # step 1: mhat = g, vhat = g^2 -> delta = g/|g| = 1 -> w = 1 - lr(~cos at step1)
    lr1 = float(optim.lr_at(oc, jnp.int32(1)))
    expect = 1.0 - lr1 * (0.5 / (0.5 + oc.eps))
    np.testing.assert_allclose(np.asarray(new_params["w"]), expect, rtol=1e-5)


def test_grad_clipping_limits_update():
    oc = optim.OptimizerConfig(peak_lr=0.1, warmup_steps=0, clip_norm=0.1)
    params = {"w": jnp.zeros((4, 16))}
    grads = {"w": jnp.full((4, 16), 100.0)}
    state = optim.init_opt_state(params, oc)
    _, _, m = optim.apply_updates(params, grads, state, oc)
    assert float(m["grad_norm"]) > 100.0  # reported pre-clip


def test_int8_moments_track_fp32():
    oc8 = optim.OptimizerConfig(peak_lr=1e-2, warmup_steps=0, moment_dtype="int8")
    oc32 = optim.OptimizerConfig(peak_lr=1e-2, warmup_steps=0)
    key = jax.random.PRNGKey(0)
    params8 = {"w": jax.random.normal(key, (8, 64))}
    params32 = {"w": params8["w"]}
    s8, s32 = optim.init_opt_state(params8, oc8), optim.init_opt_state(params32, oc32)
    assert s8["mu"]["w"]["q"].dtype == jnp.int8
    for i in range(5):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i), (8, 64))}
        params8, s8, _ = optim.apply_updates(params8, g, s8, oc8)
        params32, s32, _ = optim.apply_updates(params32, g, s32, oc32)
    diff = float(jnp.max(jnp.abs(params8["w"] - params32["w"])))
    scale = float(jnp.max(jnp.abs(params32["w"])))
    assert diff < 0.05 * scale, (diff, scale)


def test_lr_schedule_shape():
    oc = optim.OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(optim.lr_at(oc, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0 < lrs[3] < 1.0 and lrs[4] == pytest.approx(0.0, abs=1e-6)


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    params = {"a": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.ones(4)}}
    oc = optim.OptimizerConfig()
    opt = optim.init_opt_state(params, oc)
    for step in (10, 20, 30):
        ck.save(step, params, opt, extra={"data_step": step})
    assert ck.list_steps() == [20, 30]  # gc kept 2
    p2, o2, step, extra = ck.restore(params, opt)
    assert step == 30 and extra["data_step"] == 30
    np.testing.assert_array_equal(np.asarray(p2["a"]), np.asarray(params["a"]))


def test_checkpoint_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    params = {"a": jnp.ones((128, 128))}
    ck.save(1, params, blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_trainer_resume_continues(tmp_path):
    from repro.training.trainer import Trainer, TrainerConfig

    cfg = get_config("granite-3-2b").reduced()
    shape = InputShape("t", 32, 4, "train")
    plan = SchedulePlan(microbatches=1, remat="none")
    tc = TrainerConfig(total_steps=6, ckpt_every=3, ckpt_dir=str(tmp_path),
                       log_every=1, ckpt_async=False)
    tr = Trainer(cfg, shape, plan, tc)
    tr.run()
    assert tr.ckpt.latest_step() == 6
    # resume to a longer horizon: restarts from step 6, not 0
    tc2 = TrainerConfig(total_steps=8, ckpt_every=4, ckpt_dir=str(tmp_path),
                        log_every=1, ckpt_async=False)
    tr2 = Trainer(cfg, shape, plan, tc2)
    _, _, end = tr2.run()
    assert end == 8
    steps_logged = [r["step"] for r in tr2.metrics_log]
    assert min(steps_logged) >= 7  # continued, not restarted


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_deterministic():
    cfg = get_config("granite-3-2b").reduced()
    shape = InputShape("t", 16, 4, "train")
    p1, p2 = Pipeline(cfg, shape), Pipeline(cfg, shape)
    b1, b2 = p1.batch_at(7), p2.batch_at(7)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])


def test_pipeline_host_shards_disjoint_and_complete():
    cfg = get_config("granite-3-2b").reduced()
    shape = InputShape("t", 16, 8, "train")
    full = Pipeline(cfg, shape, DataConfig(host_count=1)).batch_at(3)["inputs"]
    parts = [
        Pipeline(cfg, shape, DataConfig(host_count=4, host_index=h)).batch_at(3)["inputs"]
        for h in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_pipeline_prefetch_iterator():
    cfg = get_config("granite-3-2b").reduced()
    shape = InputShape("t", 16, 2, "train")
    pipe = Pipeline(cfg, shape)
    it = pipe.iterate()
    batches = [next(it) for _ in range(3)]
    pipe.close()
    np.testing.assert_array_equal(batches[0]["inputs"], pipe.batch_at(0)["inputs"])
    np.testing.assert_array_equal(batches[2]["inputs"], pipe.batch_at(2)["inputs"])
