"""Fault tolerance control-plane tests: heartbeats, rendezvous re-balance,
straggler eviction, elastic restart plans — plus the search engine's
pinned-worker death/resync protocol (``repro.core.engine.workers``)."""
import itertools
import os
import signal

from repro.runtime.fault_tolerance import (
    ElasticPlan,
    HeartbeatMonitor,
    StragglerPolicy,
    plan_restart,
    rebalance,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_detects_dead_host():
    clock = FakeClock()
    mon = HeartbeatMonitor(["h0", "h1", "h2"], timeout=10, clock=clock)
    clock.t = 5
    mon.beat("h0")
    mon.beat("h1")
    clock.t = 12
    assert mon.dead_hosts() == ["h2"]
    assert mon.alive_hosts() == ["h0", "h1"]


def test_rebalance_minimal_movement():
    hosts = [f"h{i}" for i in range(8)]
    before = rebalance(hosts, 64)
    after = rebalance([h for h in hosts if h != "h3"], 64)
    moved = [s for s in range(64) if before[s] != after[s]]
    # only shards that lived on the dead host move (rendezvous property)
    assert set(moved) == {s for s, h in before.items() if h == "h3"}
    # and the survivors' assignment is complete
    assert set(after) == set(range(64))
    assert "h3" not in after.values()


def test_straggler_eviction_after_repeat_offenses():
    pol = StragglerPolicy(threshold=1.5, evict_after=3, ewma=0.0)
    for step in range(4):
        for h in ("h0", "h1", "h2", "h3"):
            pol.observe(h, 1.0 if h != "h2" else 3.0)
        flagged = pol.stragglers()
        assert flagged == ["h2"]
    assert pol.evictions() == ["h2"]


def test_elastic_plan_shrinks_data_axis():
    alive = [f"h{i}" for i in range(7)]  # lost 1 of 8 hosts, 4 chips each
    plan = plan_restart(alive, chips_per_host=4, model_parallel=4,
                        latest_ckpt_step=120, global_batch=256)
    assert plan.restart_step == 120
    # 28 chips / mp 4 -> dp 7, shrunk to 4 so it divides the global batch
    assert plan.data_parallel == 4
    assert 256 % plan.data_parallel == 0


def test_elastic_plan_divides_batch():
    alive = [f"h{i}" for i in range(6)]
    plan = plan_restart(alive, chips_per_host=4, model_parallel=4,
                        latest_ckpt_step=10, global_batch=16)
    assert 16 % plan.data_parallel == 0
    assert plan.data_parallel <= 6


def test_elastic_plan_shard_map_covers_all_shards():
    alive = ["a", "b", "c"]
    plan = plan_restart(alive, 4, 4, 0, 12)
    shards = dict(plan.shard_map)
    assert sorted(shards) == list(range(plan.data_parallel))
    assert set(shards.values()) <= set(alive)


def test_rebalanced_pipeline_is_exact():
    """After a host dies, survivors recompute the lost shards exactly
    (stateless index math)."""
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.data.pipeline import DataConfig, Pipeline

    cfg = get_config("granite-3-2b").reduced()
    shape = InputShape("t", 16, 8, "train")
    # original 4-host layout
    orig = [
        Pipeline(cfg, shape, DataConfig(host_count=4, host_index=h)).batch_at(5)
        for h in range(4)
    ]
    # any survivor can recompute host 2's shard for step 5
    recomputed = Pipeline(
        cfg, shape, DataConfig(host_count=4, host_index=0)
    ).batch_at(5, host_index=2)
    import numpy as np

    np.testing.assert_array_equal(recomputed["inputs"], orig[2]["inputs"])


def test_pinned_worker_death_resync_identical_to_sequential(monkeypatch):
    """Kill a pinned search worker mid-run — twice, in different rounds.
    The master must respawn it and reseed it from its CANONICAL tree
    snapshot plus the merged cache (``PinnedWorkerPool._resync``); the
    replacement re-runs the lost round from the identical pre-round state
    (same pickled RNG), so the tuning result — plan, cost, decision
    sequence — is bit-identical to the sequential path regardless of the
    deaths."""
    from repro.core.autotuner import make_mdp
    from repro.core.ensemble import ProTuner
    from repro.core.mcts import MCTSConfig

    cfg = MCTSConfig(iters_per_decision=10)

    def make(parallel):
        return ProTuner(
            make_mdp("granite-moe-1b-a400m", "train_4k"), n_standard=2,
            n_greedy=1, mcts_config=cfg, seed=11, engine="array",
            parallel=parallel,
        )

    seq = make(False).run()

    rounds = {"n": 0}
    orig = ProTuner._round_pinned

    def killing(self):
        rounds["n"] += 1
        if rounds["n"] in (2, 4):  # before the round's submit: the dead
            w = self._pool._workers[0]  # pipe surfaces on send or collect
            os.kill(w.proc.pid, signal.SIGKILL)
            w.proc.join(timeout=10)
        return orig(self)

    monkeypatch.setattr(ProTuner, "_round_pinned", killing)
    tuner = make(True)
    par = tuner.run()
    assert par.n_worker_restarts == 2
    # each resync re-shipped a snapshot (beyond the two initial inits)
    assert par.snapshot_bytes > 0
    assert par.plan == seq.plan and par.cost == seq.cost
    assert [d["action"] for d in par.decisions] == [
        d["action"] for d in seq.decisions
    ]
