"""Fault tolerance control-plane tests: heartbeats, rendezvous re-balance,
straggler eviction, elastic restart plans — plus the search engine's
pinned-worker death/resync protocol (``repro.core.engine.workers``) and
the measurement fleet's retry/quarantine/watchdog machinery
(``repro.core.measure_fleet``; all via the XLA-free stub target)."""
import itertools
import json
import os
import signal

import pytest

from repro.runtime.fault_tolerance import (
    ElasticPlan,
    HeartbeatMonitor,
    StragglerPolicy,
    plan_restart,
    rebalance,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_detects_dead_host():
    clock = FakeClock()
    mon = HeartbeatMonitor(["h0", "h1", "h2"], timeout=10, clock=clock)
    clock.t = 5
    mon.beat("h0")
    mon.beat("h1")
    clock.t = 12
    assert mon.dead_hosts() == ["h2"]
    assert mon.alive_hosts() == ["h0", "h1"]


def test_rebalance_minimal_movement():
    hosts = [f"h{i}" for i in range(8)]
    before = rebalance(hosts, 64)
    after = rebalance([h for h in hosts if h != "h3"], 64)
    moved = [s for s in range(64) if before[s] != after[s]]
    # only shards that lived on the dead host move (rendezvous property)
    assert set(moved) == {s for s, h in before.items() if h == "h3"}
    # and the survivors' assignment is complete
    assert set(after) == set(range(64))
    assert "h3" not in after.values()


def test_straggler_eviction_after_repeat_offenses():
    pol = StragglerPolicy(threshold=1.5, evict_after=3, ewma=0.0)
    for step in range(4):
        for h in ("h0", "h1", "h2", "h3"):
            pol.observe(h, 1.0 if h != "h2" else 3.0)
        flagged = pol.stragglers()
        assert flagged == ["h2"]
    assert pol.evictions() == ["h2"]


def test_elastic_plan_shrinks_data_axis():
    alive = [f"h{i}" for i in range(7)]  # lost 1 of 8 hosts, 4 chips each
    plan = plan_restart(alive, chips_per_host=4, model_parallel=4,
                        latest_ckpt_step=120, global_batch=256)
    assert plan.restart_step == 120
    # 28 chips / mp 4 -> dp 7, shrunk to 4 so it divides the global batch
    assert plan.data_parallel == 4
    assert 256 % plan.data_parallel == 0


def test_elastic_plan_divides_batch():
    alive = [f"h{i}" for i in range(6)]
    plan = plan_restart(alive, chips_per_host=4, model_parallel=4,
                        latest_ckpt_step=10, global_batch=16)
    assert 16 % plan.data_parallel == 0
    assert plan.data_parallel <= 6


def test_elastic_plan_shard_map_covers_all_shards():
    alive = ["a", "b", "c"]
    plan = plan_restart(alive, 4, 4, 0, 12)
    shards = dict(plan.shard_map)
    assert sorted(shards) == list(range(plan.data_parallel))
    assert set(shards.values()) <= set(alive)


def test_rebalanced_pipeline_is_exact():
    """After a host dies, survivors recompute the lost shards exactly
    (stateless index math)."""
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.data.pipeline import DataConfig, Pipeline

    cfg = get_config("granite-3-2b").reduced()
    shape = InputShape("t", 16, 8, "train")
    # original 4-host layout
    orig = [
        Pipeline(cfg, shape, DataConfig(host_count=4, host_index=h)).batch_at(5)
        for h in range(4)
    ]
    # any survivor can recompute host 2's shard for step 5
    recomputed = Pipeline(
        cfg, shape, DataConfig(host_count=4, host_index=0)
    ).batch_at(5, host_index=2)
    import numpy as np

    np.testing.assert_array_equal(recomputed["inputs"], orig[2]["inputs"])


def _shm_segments():
    """Live repro shm cache segments (Linux: files in /dev/shm)."""
    try:
        return {f for f in os.listdir("/dev/shm")
                if f.startswith("repro-cache-")}
    except FileNotFoundError:  # pragma: no cover - non-Linux shm
        return set()


def test_pinned_worker_death_resync_identical_to_sequential(monkeypatch):
    """Kill a pinned search worker mid-run — twice, in different rounds.
    The master must respawn it and reseed it from its CANONICAL tree
    snapshot plus the merged cache (``PinnedWorkerPool._resync``); the
    replacement re-runs the lost round from the identical pre-round state
    (same pickled RNG), so the tuning result — plan, cost, decision
    sequence — is bit-identical to the sequential path regardless of the
    deaths.  Each resync also swaps the shm cache segment to a fresh
    generation (the dead worker's mapping is unknowable); every
    generation must be unlinked by the end of the run — no /dev/shm
    leak."""
    from repro.core.autotuner import make_mdp
    from repro.core.engine.shm_cache import HAVE_SHM
    from repro.core.ensemble import ProTuner
    from repro.core.mcts import MCTSConfig

    cfg = MCTSConfig(iters_per_decision=10)

    def make(parallel):
        return ProTuner(
            make_mdp("granite-moe-1b-a400m", "train_4k"), n_standard=2,
            n_greedy=1, mcts_config=cfg, seed=11, engine="array",
            parallel=parallel,
        )

    seq = make(False).run()

    rounds = {"n": 0}
    orig = ProTuner._round_pinned

    def killing(self):
        rounds["n"] += 1
        if rounds["n"] in (2, 4):  # before the round's submit: the dead
            w = self._pool._workers[0]  # pipe surfaces on send or collect
            os.kill(w.proc.pid, signal.SIGKILL)
            w.proc.join(timeout=10)
        return orig(self)

    monkeypatch.setattr(ProTuner, "_round_pinned", killing)
    pre = _shm_segments()
    tuner = make(True)
    par = tuner.run()
    assert par.n_worker_restarts == 2
    # each resync re-shipped a snapshot (beyond the two initial inits)
    assert par.snapshot_bytes > 0
    assert par.plan == seq.plan and par.cost == seq.cost
    assert [d["action"] for d in par.decisions] == [
        d["action"] for d in seq.decisions
    ]
    # the shm transport survived both deaths (pure-analytic run) and every
    # generation — the two retired by resync swaps included — is unlinked
    # once the run's pool shuts down
    if HAVE_SHM:
        assert par.stats.get("shm") is True
    assert not (_shm_segments() - pre)


# ---------------------------------------------------------------------------
# Measurement cache + fleet (core/measure, core/measure_fleet)
# ---------------------------------------------------------------------------
CELL = ("granite-3-2b", "train_4k")


def _fleet(tmp_path, n=2, **kw):
    from repro.core.measure_fleet import MeasurementFleet
    from repro.core.measure_stub import stub_measure

    kw.setdefault("cache_dir", str(tmp_path / "fleet_cache"))
    kw.setdefault("target", stub_measure)
    kw.setdefault("timeout", 30.0)
    kw.setdefault("grace_s", 10.0)
    kw.setdefault("backoff_s", 0.05)
    return MeasurementFleet(n, **kw)


def test_measure_cache_poisoning_quarantined(tmp_path):
    """A truncated JSON at the cache path (the pre-fix poisoning mode:
    a crashed compile writing straight to the final path) must be
    quarantined and re-measured — not served as a hit, not a crash."""
    from repro.core.measure import make_request, measure_cell, request_key
    from repro.core.measure_stub import stub_measure

    cache = str(tmp_path / "cache")
    rec = measure_cell(*CELL, cache_dir=cache, target=stub_measure)
    key = request_key(make_request(*CELL))
    path = os.path.join(cache, key + ".json")
    with open(path, "w") as f:
        f.write('{"step_s": 0.0')  # truncated: a torn pre-atomic write
    again = measure_cell(*CELL, cache_dir=cache, target=stub_measure)
    assert again == rec  # re-measured, corrupt entry gone
    # and the re-measured record now serves as a clean hit
    calls = {"n": 0}

    def counting(req):
        calls["n"] += 1
        return stub_measure(req)

    assert measure_cell(*CELL, cache_dir=cache, target=counting) == rec
    assert calls["n"] == 0


def test_cache_key_includes_devices():
    """Pre-fix, measuring the same cell at a different forced device
    count silently returned the first count's record."""
    from repro.core.measure import make_request, request_key

    base = request_key(make_request(*CELL))
    assert request_key(make_request(*CELL, devices=8)) != base
    assert request_key(make_request(*CELL, devices=16)) != request_key(
        make_request(*CELL, devices=8)
    )
    # extras are transport-only: they must never perturb the key
    assert request_key(make_request(*CELL, extras={"inject": {}})) == base


def test_timeout_surfaces_runtime_error_without_residue(tmp_path, monkeypatch):
    """``subprocess.TimeoutExpired`` must surface as the standard
    RuntimeError (naming the timeout) and leave nothing on disk."""
    from repro.core import measure

    monkeypatch.setattr(measure, "DRYRUN_MODULE", "repro.launch.dryrun_stub")
    monkeypatch.setenv("REPRO_STUB_SLEEP_S", "30")
    cache = str(tmp_path / "cache")
    with pytest.raises(RuntimeError, match="timed out after 1s"):
        measure.measure_cell(*CELL, cache_dir=cache, timeout=1.0)
    assert os.listdir(cache) == []  # no partial record, no tmp residue


def test_fleet_worker_sigkill_retries_identical_to_serial(tmp_path):
    """SIGKILL a fleet worker mid-request: the master respawns it,
    re-dispatches the request within the retry budget, and the cache
    record is byte-identical to the serial measure_cell path."""
    from repro.core.measure import make_request, measure_cell, request_key
    from repro.core.measure_stub import stub_measure

    serial_cache = str(tmp_path / "serial_cache")
    with _fleet(tmp_path) as fleet:
        marker = str(tmp_path / "kill.marker")
        req = make_request(
            *CELL, extras={"inject": {"marker": marker, "kind": "kill"}}
        )
        out = fleet.measure_many([req])[0]
        assert out.ok
        assert out.worker_deaths == 1 and out.retries == 1
        assert fleet.n_worker_restarts == 1
        serial = measure_cell(
            *CELL, cache_dir=serial_cache, target=stub_measure
        )
        assert out.record == serial
        key = request_key(req)
        with open(os.path.join(fleet.cache_dir, key + ".json"), "rb") as f:
            fleet_bytes = f.read()
        with open(os.path.join(serial_cache, key + ".json"), "rb") as f:
            assert f.read() == fleet_bytes


def test_fleet_quarantines_corrupt_cache_entry(tmp_path):
    from repro.core.measure import make_request, request_key

    with _fleet(tmp_path) as fleet:
        req = make_request(*CELL)
        os.makedirs(fleet.cache_dir, exist_ok=True)
        path = os.path.join(fleet.cache_dir, request_key(req) + ".json")
        with open(path, "w") as f:
            f.write("not json at all")
        out = fleet.measure_many([req])[0]
        assert out.ok and not out.from_cache
        assert fleet.n_measured == 1 and fleet.n_cache_hits == 0
        with open(path) as f:
            assert json.load(f)["step_s"] == out.record["step_s"]


def test_fleet_single_flight_dedup(tmp_path):
    """Five concurrent requests for the same plan compile once; all five
    share the record.  A second batch is pure cache hits."""
    from repro.core.measure import make_request

    with _fleet(tmp_path) as fleet:
        outs = fleet.measure_many([make_request(*CELL) for _ in range(5)])
        assert all(o.ok for o in outs)
        assert fleet.n_measured == 1 and fleet.n_deduped == 4
        assert len({id(o) for o in outs}) == 1  # one shared outcome
        again = fleet.measure_many([make_request(*CELL)])
        assert again[0].from_cache and fleet.n_measured == 1


def test_fleet_watchdog_kills_stalled_worker(tmp_path):
    """A worker stalled past (timeout + grace) is killed and the request
    re-dispatched; the injection fires once so the retry succeeds."""
    from repro.core.measure import make_request

    with _fleet(tmp_path, n=1, timeout=0.4, grace_s=0.4) as fleet:
        marker = str(tmp_path / "sleep.marker")
        req = make_request(
            *CELL, timeout=0.4,
            extras={"inject": {"marker": marker, "kind": "sleep",
                               "sleep_s": 30}},
        )
        out = fleet.measure_many([req])[0]
        assert out.ok
        assert out.timeouts == 1 and out.retries == 1
        assert fleet.n_timeouts == 1 and fleet.n_worker_restarts == 1


def test_sweep_resume_retries_failed_measurements(tmp_path):
    """A stored sweep row whose measurement FAILED must not mark its key
    done: pre-fix, ``stored_keys`` counted every stored row, so a
    transient fleet failure (``measured_step_s: null``) was never
    re-measured on resume."""
    from benchmarks.sweep import run_sweep, stored_keys
    from repro.core.measure_stub import failing_measure

    spec = {
        "name": "retry",
        "defaults": {"algo": "mcts_1s", "n_standard": 2, "n_greedy": 1},
        "matrix": {"cell": [list(CELL)]},
    }
    common = dict(results_dir=str(tmp_path), measure="stub", workers=1,
                  log=lambda *a: None)
    cache_dir = str(tmp_path / "mc")
    rows1 = run_sweep(spec, fleet_kwargs={
        "target": failing_measure, "max_retries": 0, "cache_dir": cache_dir,
    }, **common)
    assert rows1[0]["measured_step_s"] is None
    assert rows1[0]["measure"]["failed"]
    out_path = os.path.join(str(tmp_path), "retry.jsonl")
    assert stored_keys(out_path) == set()  # a failed row is NOT done
    # resume with a healthy fleet: the row re-runs and sticks
    rows2 = run_sweep(spec, fleet_kwargs={"cache_dir": cache_dir}, **common)
    assert len(rows2) == 1, "resume skipped the failed row"
    assert rows2[0]["measured_step_s"] is not None
    assert stored_keys(out_path) == {rows2[0]["key"]}
    # and a THIRD resume now runs nothing
    assert run_sweep(spec, fleet_kwargs={"cache_dir": cache_dir},
                     **common) == []


def test_fleet_exhausted_retries_fail_without_raising(tmp_path):
    from repro.core.measure import make_request
    from repro.core.measure_stub import failing_measure

    with _fleet(tmp_path, n=1, target=failing_measure, max_retries=1) as fleet:
        out = fleet.measure_many([make_request(*CELL)])[0]
        assert not out.ok and out.retries == 1
        assert "deliberate failure" in out.error
        assert fleet.n_failures == 1
        assert os.listdir(fleet.cache_dir) == []  # failures never cached
        with pytest.raises(RuntimeError, match="deliberate failure"):
            fleet.measure_cell(*CELL)


def test_measure_failure_degrades_to_analytic():
    """A raising measure_fn inside mcts_cost+real_* must not kill the
    run: the candidate re-ranks by its exact analytic cost and the
    failure is counted on TuneResult.n_measure_failures."""
    from repro.core.autotuner import make_mdp
    from repro.core.ensemble import ProTuner
    from repro.core.mcts import MCTSConfig

    calls = {"n": 0}

    def flaky(plan):
        calls["n"] += 1
        raise RuntimeError("compile exploded")

    mdp = make_mdp(*CELL)
    tuner = ProTuner(
        mdp, n_standard=2, n_greedy=1,
        mcts_config=MCTSConfig(iters_per_decision=4), seed=3,
        measure_fn=flaky,
    )
    res = tuner.run()
    assert calls["n"] > 0
    assert res.n_measure_failures > 0
    assert res.measured is None  # degraded analytic values are not
    assert res.cost > 0          # reported as real measurements
    # and the run matches a plain un-measured run's final schedule
    plain = ProTuner(
        make_mdp(*CELL), n_standard=2, n_greedy=1,
        mcts_config=MCTSConfig(iters_per_decision=4), seed=3,
    ).run()
    assert res.plan == plain.plan


def test_fleet_backend_batches_ensemble_measurements(tmp_path):
    """measure_backend= threads a fleet through the ensemble: candidate
    measurements prefetch through measure_plans, results match the
    serial measure_fn path, and failures degrade per-candidate."""
    from repro.core.autotuner import make_mdp
    from repro.core.ensemble import ProTuner
    from repro.core.mcts import MCTSConfig
    from repro.core.measure_stub import stub_measure

    def serial_fn(plan):
        return stub_measure(
            {"arch": CELL[0], "shape": CELL[1], "mesh": "single",
             "plan": plan.to_dict(), "devices": None}
        )["step_s"]

    cfg = MCTSConfig(iters_per_decision=4)
    serial = ProTuner(
        make_mdp(*CELL), n_standard=2, n_greedy=1, mcts_config=cfg,
        seed=5, measure_fn=serial_fn,
    ).run()
    with _fleet(tmp_path) as fleet:
        backend = fleet.bind(*CELL)
        res = ProTuner(
            make_mdp(*CELL), n_standard=2, n_greedy=1, mcts_config=cfg,
            seed=5, measure_backend=backend,
        ).run()
        assert fleet.n_measured > 0  # prefetches actually hit the fleet
    assert res.plan == serial.plan
    assert res.measured == pytest.approx(serial.measured)
    assert res.n_measure_failures == 0
    assert res.n_measurements == serial.n_measurements
