"""Learned cost model: featurization contract, the jit-once forward fix,
and the batched-pricing seam (``cost_batch``-vs-scalar parity)."""
import numpy as np
import pytest

from repro.configs import get_config, get_shape
from repro.core.space import SINGLE_POD, ScheduleSpace

learned = pytest.importorskip("repro.core.learned_cost")


def _space(arch="granite-moe-1b-a400m", shape="train_4k") -> ScheduleSpace:
    return ScheduleSpace(
        get_config(arch).reduced(), get_shape(shape), SINGLE_POD
    )


def _model(space, n=96, steps=60):
    import random

    from repro.core.cost_model import AnalyticCostModel

    rng = random.Random(0)
    plans = [space.random_plan(rng) for _ in range(n)]
    oracle = AnalyticCostModel(space.cfg, space.shape, space.mesh)
    return learned.fit_learned_cost(
        space, plans, [oracle.cost(p) for p in plans], steps=steps
    )


# ---------------------------------------------------------------------------
# featurize
# ---------------------------------------------------------------------------
def test_featurize_width_matches_space():
    space = _space()
    plan = space.plan_from_actions(space.default_actions())
    want = sum(len(s.options) for s in space.stages) + 5  # 4 log knobs + overlap
    assert learned.featurize(plan, space).shape == (want,)


def test_featurize_one_hot_exclusive_per_stage():
    import random

    space = _space()
    rng = random.Random(7)
    for _ in range(16):
        plan = space.random_plan(rng)
        f = learned.featurize(plan, space)
        off = 0
        for stage in space.stages:
            block = f[off:off + len(stage.options)]
            assert set(block.tolist()) <= {0.0, 1.0}
            assert block.sum() == 1.0, f"stage {stage.name} not one-hot"
            # the hot slot is the plan's actual value
            assert stage.options[int(np.argmax(block))] == getattr(
                plan, stage.name
            )
            off += len(stage.options)


def test_featurize_log_knobs_monotone():
    import dataclasses

    space = _space()
    base = space.plan_from_actions(space.default_actions())
    n_onehot = sum(len(s.options) for s in space.stages)
    # knob feature slots, in featurize's append order
    slots = {"microbatches": n_onehot, "attn_q": n_onehot + 1,
             "attn_kv": n_onehot + 2, "scan_chunk": n_onehot + 3}

    def feat(**kw):
        return learned.featurize(dataclasses.replace(base, **kw), space)

    mb = [feat(microbatches=m)[slots["microbatches"]] for m in (1, 2, 4, 8)]
    assert mb == sorted(mb) and len(set(mb)) == len(mb)
    bq = [feat(attn_block=(b, 256))[slots["attn_q"]] for b in (128, 256, 512)]
    assert bq == sorted(bq) and len(set(bq)) == len(bq)
    sc = [feat(scan_chunk=c)[slots["scan_chunk"]] for c in (64, 128, 256)]
    assert sc == sorted(sc) and len(set(sc)) == len(sc)
    # log scaling: doubling the knob adds a constant step
    steps = np.diff(mb)
    assert np.allclose(steps, steps[0])


def test_featurize_batch_stacks_featurize():
    import random

    space = _space()
    rng = random.Random(3)
    plans = [space.random_plan(rng) for _ in range(5)]
    X = learned.featurize_batch(plans, space)
    assert X.shape == (5, learned.featurize(plans[0], space).shape[0])
    for i, p in enumerate(plans):
        assert np.array_equal(X[i], learned.featurize(p, space))


# ---------------------------------------------------------------------------
# batched forward pass
# ---------------------------------------------------------------------------
def test_cost_batch_matches_scalar():
    import random

    space = _space()
    model = _model(space)
    rng = random.Random(11)
    plans = [space.random_plan(rng) for _ in range(13)]  # pads 13 -> 16
    batched = model.cost_batch(plans)
    scalar = [model.cost(p) for p in plans]
    assert np.allclose(batched, scalar, rtol=1e-5), (batched, scalar)
    assert all(c > 0 and np.isfinite(c) for c in batched)


def test_cost_batch_counts_one_forward_per_batch():
    import random

    space = _space()
    model = _model(space)
    rng = random.Random(11)
    plans = [space.random_plan(rng) for _ in range(9)]
    f0, e0 = model.n_forward, model.n_evals
    model.cost_batch(plans)
    assert model.n_forward == f0 + 1  # the whole batch is ONE forward pass
    assert model.n_evals == e0 + len(plans)
    model.cost(plans[0])
    assert model.n_forward == f0 + 2
    assert model.cost_batch([]) == []


def test_forward_jit_compiles_once_per_shape():
    import random

    space = _space()
    model = _model(space)
    rng = random.Random(5)
    plans = [space.random_plan(rng) for _ in range(8)]
    model.cost(plans[0])  # warm the batch-of-1 shape
    size0 = learned._mlp_apply_jit._cache_size()
    for p in plans:
        model.cost(p)  # the pre-fix code retraced the MLP on every call
    assert learned._mlp_apply_jit._cache_size() == size0


def test_refit_warm_start_and_per_fit_normalization():
    import random

    space = _space()
    rng = random.Random(2)
    plans = [space.random_plan(rng) for _ in range(64)]
    from repro.core.cost_model import AnalyticCostModel

    oracle = AnalyticCostModel(space.cfg, space.shape, space.mesh)
    costs = [oracle.cost(p) for p in plans]
    m1 = learned.fit_learned_cost(space, plans, costs, steps=40)
    # refit on a shifted cost distribution: normalization must follow it
    m2 = learned.fit_learned_cost(
        space, plans, [c * 100.0 for c in costs], params=m1.params, steps=40
    )
    assert m2.mean == pytest.approx(m1.mean + np.log(100.0), rel=1e-3)
    pred = m2.cost_batch(plans[:8])
    assert all(np.isfinite(p) and p > 0 for p in pred)
