"""Multi-device tests run in subprocesses (jax locks the host device count at
first init, so the main pytest process must stay at 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

# every test here spawns a subprocess that re-initializes jax on a forced
# 8-device host and compiles SPMD programs — minutes, not seconds
pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: float = 600.0):
    preamble = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, {os.path.join(ROOT, 'src')!r})
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", preamble + textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=timeout,
        cwd=ROOT,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


def test_int8_ring_allreduce_matches_psum():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.training.grad_compress import compressed_psum
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (8, 33, 130))  # odd shapes exercise padding

        def body(xs):
            reduced, err = compressed_psum(xs[0], "data")
            return reduced[None], err[None]

        f = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P("data"),),
                                  out_specs=(P("data"), P("data"))))
        red, err = f(x)
        true = jnp.sum(x, axis=0)
        for i in range(8):
            rel = float(jnp.max(jnp.abs(red[i] - true)) / (jnp.max(jnp.abs(true)) + 1e-9))
            assert rel < 0.05, rel
        print("RING_OK", rel)
        """
    )
    assert "RING_OK" in out


def test_error_feedback_reduces_bias():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.training.grad_compress import compressed_psum
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        g = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 256))
        def body(gs, es):
            red, err = compressed_psum(gs[0], "data", error=es[0])
            return red[None], err[None]
        f = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                                  out_specs=(P("data"), P("data"))))
        err = jnp.zeros_like(g)
        # same gradient applied repeatedly: with error feedback, the SUM of
        # transmitted values converges to the true sum (unbiased)
        acc = jnp.zeros((16, 256))
        true_acc = jnp.zeros((16, 256))
        for step in range(8):
            red, err = f(g, err)
            acc = acc + red[0]
            true_acc = true_acc + jnp.sum(g, axis=0)
        rel = float(jnp.linalg.norm(acc - true_acc) / jnp.linalg.norm(true_acc))
        assert rel < 0.01, rel
        print("EF_OK", rel)
        """
    )
    assert "EF_OK" in out


def test_sharded_train_step_matches_single_device():
    """The FSDP+TP sharded step computes the SAME numbers as 1 device."""
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.core.space import MeshSpec, SchedulePlan
        from repro.models import transformer
        from repro.training import optimizer as optim
        from repro.training.train_step import make_train_step, shardings_for_train

        cfg = get_config("granite-3-2b").reduced()
        shape = InputShape("t", 32, 8, "train")
        oc = optim.OptimizerConfig(peak_lr=1e-3, warmup_steps=0)
        key = jax.random.PRNGKey(0)
        params = transformer.init_params(cfg, key)
        opt = optim.init_opt_state(params, oc)
        tok = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
        pos = jnp.broadcast_to(jnp.arange(32)[None], (8, 32)).astype(jnp.int32)
        batch = {"inputs": tok, "labels": tok, "positions": pos}

        plan0 = SchedulePlan(param_strategy="replicated", mixer_tp=False,
                             ffn_tp=False, vocab_shard=False, microbatches=1,
                             remat="none")
        ref_step = jax.jit(make_train_step(cfg, shape, plan0, oc))
        p_ref, _, m_ref = ref_step(params, opt, batch)

        mesh_spec = MeshSpec(("data", "model"), (4, 2))
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        plan = SchedulePlan(param_strategy="fsdp_tp", microbatches=2,
                            remat="dots")
        ps, os_, bs, rules = shardings_for_train(cfg, shape, plan, mesh,
                                                 mesh_spec, params, opt)
        step = jax.jit(make_train_step(cfg, shape, plan, oc, mesh, mesh_spec),
                       in_shardings=(ps, os_, bs))
        p_sh, _, m_sh = step(params, opt, batch)
        assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 2e-3, (
            float(m_ref["loss"]), float(m_sh["loss"]))
        # compare a few parameter leaves after the update
        la = jax.tree.leaves(p_ref)
        lb = jax.tree.leaves(jax.device_get(p_sh))
        worst = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(la, lb))
        assert worst < 5e-3, worst
        print("SHARD_OK", float(m_ref["loss"]), worst)
        """
    )
    assert "SHARD_OK" in out


def test_checkpoint_elastic_restore_across_mesh_shapes(tmp_path):
    """Save under a (4,2) mesh, restore under (2,4): elastic re-shard."""
    out = _run(
        f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.ckpt import Checkpointer
        mesh1 = jax.make_mesh((4, 2), ("data", "model"),
                              axis_types=(jax.sharding.AxisType.Auto,) * 2)
        mesh2 = jax.make_mesh((2, 4), ("data", "model"),
                              axis_types=(jax.sharding.AxisType.Auto,) * 2)
        x = jnp.arange(64.0 * 8).reshape(64, 8)
        xs = jax.device_put(x, NamedSharding(mesh1, P("data", "model")))
        ck = Checkpointer({str(tmp_path)!r})
        ck.save(5, {{"w": xs}})
        tmpl = {{"w": jax.ShapeDtypeStruct((64, 8), jnp.float32)}}
        restored, _, step, _ = ck.restore(
            tmpl, shardings={{"w": NamedSharding(mesh2, P("data", "model"))}})
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
        assert restored["w"].sharding.mesh.shape["data"] == 2
        print("ELASTIC_OK")
        """
    )
    assert "ELASTIC_OK" in out


@pytest.mark.slow
def test_dryrun_one_cell_small_arch():
    """End-to-end dry-run subprocess on the production mesh for the
    cheapest arch (proves the deliverable-(e) machinery from a test)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "granite-moe-1b-a400m", "--shape", "train_4k", "--mesh", "single"],
        capture_output=True, text=True, timeout=1200, env=env, cwd=ROOT,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "compiled OK" in proc.stdout
