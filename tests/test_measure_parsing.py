"""Pure string-parsing tests for ``repro.core.measure`` — no XLA compile.

Covers ``parse_collective_bytes`` over all five collective kinds, both
``replica_groups`` syntaxes (v1 ``{{...}}`` and v2 ``[n,g]<=[...]``),
async ``-start`` forms, tuple output shapes, and ``combine_terms``'s
roofline arithmetic."""
import pytest

from repro.core.cost_model import HardwareSpec
from repro.core.measure import combine_terms, parse_collective_bytes

# one op line per collective kind, shaped like real post-optimization HLO
HLO = """
HloModule jit_step, entry_computation_layout={...}

ENTRY %main {
  %ag = f32[2048,128]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}, use_global_device_ids=true
  %rs = f32[64]{0} reduce-scatter(%y), channel_id=2, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%add
  %ar = bf16[1024]{0} all-reduce(%z), channel_id=3, replica_groups={{0,1},{2,3}}, to_apply=%add
  %aa = f32[8,4]{1,0} all-to-all(%w), channel_id=4, replica_groups=[4,8]<=[32], dimensions={0}
  %cp = f32[16]{0} collective-permute(%v), channel_id=5, source_target_pairs={{0,1},{1,0}}
}
"""


def test_all_five_kinds_counted():
    out = parse_collective_bytes(HLO)
    counts = out["_counts"]
    assert counts == {
        "all-gather": 1,
        "reduce-scatter": 1,
        "all-reduce": 1,
        "all-to-all": 1,
        "collective-permute": 1,
    }


def test_operand_bytes_per_kind():
    out = parse_collective_bytes(HLO)
    # all-gather: output 2048*128*4 = 1048576 B, v2 groups [16,16] -> g=16,
    # operand = output / g
    assert out["all-gather"] == 1048576 / 16
    # reduce-scatter: output 64*4 = 256 B, v1 groups of 4 -> operand = out*g
    assert out["reduce-scatter"] == 256 * 4
    # all-reduce: output 1024*2 = 2048 B (bf16), operand = output
    assert out["all-reduce"] == 2048
    # all-to-all: output 8*4*4 = 128 B, operand = output
    assert out["all-to-all"] == 128
    # collective-permute: output 16*4 = 64 B
    assert out["collective-permute"] == 64


def test_ring_wire_bytes():
    out = parse_collective_bytes(HLO)
    expect = (
        1048576 * 15 / 16  # all-gather: S_full*(g-1)/g
        + 256 * 3  # reduce-scatter: out*(g-1)
        + 2 * 2048 * 1 / 2  # all-reduce: 2*S*(g-1)/g, g=2
        + 128 * 7 / 8  # all-to-all: S*(g-1)/g, g=8
        + 64  # collective-permute: S
    )
    assert out["_wire"] == pytest.approx(expect)


def test_v1_vs_v2_group_syntax_equivalent():
    v1 = "  %r = f32[256]{0} all-reduce(%a), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add"
    v2 = "  %r = f32[256]{0} all-reduce(%a), replica_groups=[1,8]<=[8], to_apply=%add"
    b1, b2 = parse_collective_bytes(v1), parse_collective_bytes(v2)
    assert b1["all-reduce"] == b2["all-reduce"] == 1024
    assert b1["_wire"] == b2["_wire"] == 2 * 1024 * 7 / 8


def test_async_start_and_tuple_shapes():
    # async all-reduce-start with a tuple output: both members counted
    line = "  %ars = (f32[128]{0}, f32[128]{0}) all-reduce-start(%p), replica_groups={{0,1}}, to_apply=%add"
    out = parse_collective_bytes(line)
    assert out["_counts"] == {"all-reduce": 1}
    assert out["all-reduce"] == 2 * 128 * 4


def test_missing_groups_defaults_to_group_of_one():
    line = "  %cp = f32[32]{0} collective-permute(%v), source_target_pairs={{0,1}}"
    out = parse_collective_bytes(line)
    assert out["collective-permute"] == 128
    assert out["_wire"] == 128


def test_non_collective_lines_ignored():
    text = """
  %d = f32[128,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}
  %fusion = bf16[64]{0} fusion(%c), kind=kLoop, calls=%fused
  %gather = f32[8,16]{1,0} gather(%o, %i), offset_dims={1}
"""
    out = parse_collective_bytes(text)
    assert out["_counts"] == {}
    assert out["_wire"] == 0.0


def test_unknown_dtype_contributes_zero_bytes():
    line = "  %ar = token[] all-reduce(%t), replica_groups={{0,1}}, to_apply=%add"
    out = parse_collective_bytes(line)
    # matched as a collective but the payload is unpriceable -> 0 bytes
    assert out.get("all-reduce", 0) == 0


def test_combine_terms_roofline_math():
    hw = HardwareSpec()
    chips = 4
    flops = 2 * chips * hw.peak_flops  # 2 s of compute across the fleet
    hbm = 1 * chips * hw.hbm_bw  # 1 s of memory traffic
    coll = 3 * hw.link_bw  # 3 s of wire per chip
    t = combine_terms(flops, hbm, coll, chips, overlap=0.5, hw=hw)
    assert t["compute_s"] == pytest.approx(2.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(3.0)
    # step = max(compute, memory) + (1-overlap)*collective
    assert t["step_s"] == pytest.approx(2.0 + 0.5 * 3.0)


def test_combine_terms_memory_bound_and_full_overlap():
    hw = HardwareSpec()
    t = combine_terms(0.0, 5 * hw.hbm_bw, 2 * hw.link_bw, 1, overlap=1.0, hw=hw)
    assert t["step_s"] == pytest.approx(5.0)  # collective fully hidden
