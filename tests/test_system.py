"""End-to-end behaviour tests: autotune → train → checkpoint → failure →
elastic resume → serve, on reduced configs."""
import numpy as np
import pytest

# full end-to-end flows (autotune -> train -> serve, CLI subprocesses,
# learned-cost training) — the long tail of the suite
pytestmark = pytest.mark.slow

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.autotuner import autotune
from repro.core.space import SchedulePlan


def test_autotune_then_train_then_serve(tmp_path):
    import jax

    from repro.serving.engine import ServingEngine
    from repro.training.trainer import Trainer, TrainerConfig

    # 1. autotune the REAL cell (full config, analytic model) — the plan's
    #    kernel/remat knobs transfer to the smoke run
    res = autotune("granite-3-2b", "train_4k", algo="mcts_1s", seed=0,
                   n_standard=2, n_greedy=1)
    assert res.plan is not None

    # 2. train a reduced model with (a safe projection of) that plan
    cfg = get_config("granite-3-2b").reduced()
    shape = InputShape("t", 32, 4, "train")
    plan = SchedulePlan(microbatches=2, remat=res.plan.remat,
                        opt_dtype=res.plan.opt_dtype)
    tc = TrainerConfig(total_steps=8, ckpt_every=4, ckpt_dir=str(tmp_path),
                       log_every=2, ckpt_async=False)
    trainer = Trainer(cfg, shape, plan, tc)
    params, _, step = trainer.run()
    assert step == 8

    # 3. simulated node failure -> elastic restart plan from checkpoint
    plan2 = trainer.handle_failure(["h0", "h1", "h2"], chips_per_host=4,
                                   model_parallel=4)
    assert plan2.restart_step == 8
    assert plan2.data_parallel >= 1

    # 4. serve with the trained weights
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=32)
    eng.submit(np.array([1, 2, 3]), max_new_tokens=4)
    eng.submit(np.array([9]), max_new_tokens=4)
    done = eng.run()
    assert len(done) == 2
    assert all(len(r.generated) == 4 for r in done)


def test_learned_cost_model_trains_and_ranks():
    from repro.core.autotuner import make_mdp
    from repro.core.learned_cost import ranking_correlation, train_learned_cost

    mdp = make_mdp("phi3.5-moe-42b-a6.6b", "train_4k")
    lcm = train_learned_cost(mdp.space, mdp.cost_model, n_samples=192, steps=250)
    rc = ranking_correlation(lcm, mdp.cost_model, mdp.space, n=96)
    assert rc > 0.5, rc


def test_cli_entrypoints_smoke(capsys, tmp_path):
    from repro.launch.serve import main as serve_main
    from repro.launch.train import main as train_main

    assert train_main(["--arch", "granite-3-2b", "--smoke", "--steps", "4",
                       "--ckpt-dir", str(tmp_path / "ckpt")]) == 0
    assert serve_main(["--arch", "granite-3-2b", "--smoke",
                       "--requests", "2", "--max-new", "3"]) == 0
    out = capsys.readouterr().out
    assert "[train] done" in out and "completed 2/2" in out
