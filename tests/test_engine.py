"""Engine-layer tests: ArrayMCTS ↔ reference MCTS parity, transposition
cache exactness, process-pool reproducibility, and SearchBackend routing.

Parity is asserted EXACTLY (same action, same best_cost, same best_state
for a fixed seed): the array engine replicates the reference's RNG call
sequence and computes UCB with the same IEEE-754 operations, so any
drift is a real behavioral bug, not float noise."""
import dataclasses
import random

import pytest

from repro.core.autotuner import autotune, make_mdp
from repro.core.engine import ArrayMCTS, CachedMDP, TranspositionCache, make_tree
from repro.core.engine.backend import TABLE1, SearchBackend, resolve_backend
from repro.core.ensemble import ProTuner
from repro.core.mcts import MCTS, MCTSConfig

from conftest import MOE_TRAIN_CELL as CELL


def _mdp():
    return make_mdp(*CELL)


# ---------------------------------------------------------------------------
# ArrayMCTS ↔ MCTS parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ucb", ["paper", "cp10", "sqrt2"])
@pytest.mark.parametrize("simulation", ["random", "greedy"])
def test_array_matches_reference_single_decision(ucb, simulation):
    cfg = MCTSConfig(ucb=ucb, simulation=simulation,
                     iters_per_decision=32, seed=11)
    ref = MCTS(_mdp(), cfg)
    arr = ArrayMCTS(_mdp(), cfg)
    r, a = ref.run_decision(), arr.run_decision()
    assert (r.action, r.best_cost, r.best_state, r.iterations) == (
        a.action, a.best_cost, a.best_state, a.iterations
    )


def test_array_matches_reference_binary_reward():
    cfg = MCTSConfig(ucb="sqrt2", reward_mode="binary",
                     iters_per_decision=32, seed=2)
    r = MCTS(_mdp(), cfg).run_decision()
    a = ArrayMCTS(_mdp(), cfg).run_decision()
    assert (r.action, r.best_cost) == (a.action, a.best_cost)


def test_array_matches_reference_full_tuning_run():
    """Whole ensemble, all decision rounds, with tree reuse across rounds —
    and with the array side running through the shared cache (cached costs
    must be bit-identical, so the trajectories cannot diverge)."""
    cfg = MCTSConfig(iters_per_decision=16)
    r_ref = ProTuner(_mdp(), n_standard=2, n_greedy=1, mcts_config=cfg,
                     seed=5, engine="reference").run()
    r_arr = ProTuner(_mdp(), n_standard=2, n_greedy=1, mcts_config=cfg,
                     seed=5, engine="array").run()
    assert r_ref.plan == r_arr.plan
    assert r_ref.cost == r_arr.cost
    assert [d["action"] for d in r_ref.decisions] == [
        d["action"] for d in r_arr.decisions
    ]
    assert r_arr.cache_hits > 0  # ensemble trees share the cache


def test_array_engine_via_make_tree_and_autotune():
    assert isinstance(make_tree(_mdp(), MCTSConfig(), "array"), ArrayMCTS)
    assert isinstance(make_tree(_mdp(), MCTSConfig(), "reference"), MCTS)
    with pytest.raises(ValueError):
        make_tree(_mdp(), MCTSConfig(), "cuda")
    ra = autotune(*CELL, algo="mcts_1s", seed=0, n_standard=2, n_greedy=1,
                  engine="array")
    rb = autotune(*CELL, algo="mcts_1s", seed=0, n_standard=2, n_greedy=1,
                  engine="reference")
    assert ra.plan == rb.plan and ra.cost == rb.cost
    assert ra.engine == "array" and rb.engine == "reference"


# ---------------------------------------------------------------------------
# Batched leaf evaluation (lockstep pending-leaf rounds)
# ---------------------------------------------------------------------------
def test_batched_round_counts_each_evaluation_once():
    """Two identical-seed trees put the SAME pending leaf in every lockstep
    batch; the duplicate must be priced once — evaluation and cache
    counters must match the scalar one-at-a-time accounting exactly (no
    double count when a leaf is both expanded and simulated in the same
    batch by different trees)."""
    from repro.core.engine.batch import run_decision_batch

    cfg = MCTSConfig(iters_per_decision=16, seed=4)
    m_bat = CachedMDP(_mdp())
    res_bat = run_decision_batch(
        [ArrayMCTS(m_bat, cfg), ArrayMCTS(m_bat, cfg)], m_bat
    )
    m_seq = CachedMDP(_mdp())
    res_seq = [t.run_decision() for t in
               (ArrayMCTS(m_seq, cfg), ArrayMCTS(m_seq, cfg))]
    key = lambda r: (r.action, r.best_cost, r.best_state, r.iterations)
    assert [key(r) for r in res_bat] == [key(r) for r in res_seq]
    assert key(res_bat[0]) == key(res_bat[1])  # twins stayed in lockstep
    # each unique schedule priced exactly once, batched or not
    assert m_bat.mdp.cost_model.n_evals == m_bat.cache.misses
    assert m_bat.cache.misses == m_seq.cache.misses
    assert m_bat.cache.hits == m_seq.cache.hits
    assert m_bat.mdp.cost_model.n_evals == m_seq.mdp.cost_model.n_evals


def test_run_decision_counters_survive_batched_ensemble():
    """`n_evals` through a whole batched ensemble equals the unique misses
    the shared cache recorded — each batched evaluation counted once."""
    cfg = MCTSConfig(iters_per_decision=12)
    res = ProTuner(_mdp(), n_standard=3, n_greedy=1, mcts_config=cfg,
                   seed=2, engine="array", batch=True).run()
    assert res.n_evals == res.cache_misses
    res_scalar = ProTuner(_mdp(), n_standard=3, n_greedy=1, mcts_config=cfg,
                          seed=2, engine="array", batch=False).run()
    assert res.plan == res_scalar.plan and res.cost == res_scalar.cost
    assert res.n_evals == res_scalar.n_evals
    assert (res.cache_hits, res.cache_misses) == (
        res_scalar.cache_hits, res_scalar.cache_misses
    )


# ---------------------------------------------------------------------------
# Per-round tree deltas (process-pool transport)
# ---------------------------------------------------------------------------
def test_parallel_delta_merge_equals_whole_tree():
    """The master tree with a worker's round delta applied must equal the
    worker's post-round tree — the whole-tree-pickle result — field for
    field, and continue identically afterwards."""
    import pickle

    import numpy as np

    mdp = CachedMDP(_mdp())
    master = ArrayMCTS(mdp, MCTSConfig(iters_per_decision=24, seed=6))
    for _ in range(2):  # grow a real subtree before the measured round
        r = master.run_decision()
        master.advance_root(r.action)
    worker = pickle.loads(pickle.dumps(master))  # ship to the worker
    worker.begin_delta()
    res_w = worker.run_decision()
    delta = worker.collect_delta()
    # TRUE delta: the numeric payload is the round's new-node slices plus
    # the round's touched pre-round stat rows — never the whole [:size]
    # arrays (that O(total tree) copy was the ROADMAP item this replaces)
    base, size = delta["base"], delta["size"]
    assert base > 16  # the warm-up rounds grew a real pre-round tree
    for name in ("visit_counts", "sum_cost", "sum_reward", "best_cost",
                 "node_action", "n_children"):
        assert len(delta[name]) == size - base, name
    assert delta["children"].shape[0] == size - base
    assert 0 < len(delta["touched"]) < base  # paths only, not every node
    assert (delta["touched"] < base).all()
    wire = pickle.dumps(delta)
    master.apply_delta(pickle.loads(wire))  # return trip

    assert master.size == worker.size
    n = master.size
    for name in ("visit_counts", "sum_cost", "sum_reward", "best_cost",
                 "node_action", "n_children"):
        np.testing.assert_array_equal(
            getattr(master, name)[:n], getattr(worker, name)[:n], err_msg=name
        )
    w = worker.children.shape[1]
    np.testing.assert_array_equal(master.children[:n, :w], worker.children[:n, :w])
    assert master.untried == worker.untried
    assert master._childlist == worker._childlist
    assert master.best_state == worker.best_state
    assert master.rng.getstate() == worker.rng.getstate()
    assert (master.baseline, master.global_best, master.global_best_state) == (
        worker.baseline, worker.global_best, worker.global_best_state
    )
    # the delta payload is what crosses the pool boundary — it must be
    # smaller than the whole-tree pickle it replaces
    assert len(wire) < len(pickle.dumps(worker))
    # payload accounting helper: the numeric delta payload is positive and
    # bounded by the wire size
    from repro.core.engine.array_mcts import delta_nbytes

    assert 0 < delta_nbytes(delta) <= len(wire)
    # merged tree and whole-tree result keep evolving identically
    r_m, r_w = master.run_decision(), worker.run_decision()
    assert (r_m.action, r_m.best_cost, r_m.best_state) == (
        r_w.action, r_w.best_cost, r_w.best_state
    )


def test_delta_rejects_mismatched_base():
    mdp = CachedMDP(_mdp())
    a = ArrayMCTS(mdp, MCTSConfig(iters_per_decision=8, seed=1))
    b = ArrayMCTS(mdp, MCTSConfig(iters_per_decision=8, seed=1))
    a.run_decision()  # a grew past b's size
    b.begin_delta()
    b.run_decision()
    delta = b.collect_delta()
    with pytest.raises(ValueError):
        a.apply_delta(delta)


def test_batched_round_under_delta_matches_per_tree():
    """In-worker lockstep batching composes with delta recording (the
    shm-pool configuration: a pinned worker batches its subset's rounds
    while recording per-tree deltas).  A ``run_decision_batch`` round with
    delta recording active must return the same results as per-tree
    ``run_decision`` rounds, and the collected deltas, applied to
    pre-round master copies, must rebuild each worker tree field for
    field."""
    import pickle

    import numpy as np

    from repro.core.engine.batch import run_decision_batch

    def grow(mdp, seeds):
        trees = []
        for s in seeds:
            t = ArrayMCTS(mdp, MCTSConfig(iters_per_decision=16, seed=s))
            r = t.run_decision()  # a real pre-round tree, not a stub root
            t.advance_root(r.action)
            trees.append(t)
        return trees

    m_bat, m_seq = CachedMDP(_mdp()), CachedMDP(_mdp())
    bat = grow(m_bat, (6, 7))
    seq = grow(m_seq, (6, 7))
    masters = [pickle.loads(pickle.dumps(t)) for t in bat]  # pre-round

    for t in bat:
        t.begin_delta()
    res_bat = run_decision_batch(bat, m_bat)
    deltas = [t.collect_delta() for t in bat]

    for t in seq:
        t.begin_delta()
    res_seq = [t.run_decision() for t in seq]
    for t in seq:
        t.collect_delta()

    key = lambda r: (r.action, r.best_cost, r.best_state, r.iterations)
    assert [key(r) for r in res_bat] == [key(r) for r in res_seq]
    # batching never double-prices a shared leaf, delta recording or not
    assert m_bat.mdp.cost_model.n_evals == m_seq.mdp.cost_model.n_evals
    assert (m_bat.cache.hits, m_bat.cache.misses) == (
        m_seq.cache.hits, m_seq.cache.misses)

    for master, delta, worker in zip(masters, deltas, bat):
        master.apply_delta(delta)
        assert master.size == worker.size
        n = master.size
        for name in ("visit_counts", "sum_cost", "sum_reward", "best_cost",
                     "node_action", "n_children"):
            np.testing.assert_array_equal(
                getattr(master, name)[:n], getattr(worker, name)[:n],
                err_msg=name)
        w = worker.children.shape[1]
        np.testing.assert_array_equal(
            master.children[:n, :w], worker.children[:n, :w])
        assert master.untried == worker.untried
        assert master._childlist == worker._childlist
        assert master.best_state == worker.best_state
        assert master.rng.getstate() == worker.rng.getstate()


# ---------------------------------------------------------------------------
# Transposition cache
# ---------------------------------------------------------------------------
def test_cache_returns_bit_identical_costs():
    raw, cached = _mdp(), CachedMDP(_mdp())
    rng = random.Random(0)
    states = [tuple(raw.space.random_actions(rng)) for _ in range(50)]
    for s in states:
        direct = raw.terminal_cost(s)
        assert cached.terminal_cost(s) == direct  # first: miss
        assert cached.terminal_cost(s) == direct  # second: hit
        prefix = s[: len(s) // 2]
        dp = raw.partial_cost(prefix)
        assert cached.partial_cost(prefix) == dp
        assert cached.partial_cost(prefix) == dp
    n_lookups = 4 * len(states)
    expect_misses = len(set(states)) + len({s[: len(s) // 2] for s in states})
    assert cached.cache.misses == expect_misses
    assert cached.cache.hits == n_lookups - expect_misses


def test_cache_shared_across_trees_saves_evals():
    """The cached ensemble must do strictly fewer cost-model evaluations
    than the uncached one, at identical results."""
    cfg = MCTSConfig(iters_per_decision=16)
    r_ref = ProTuner(_mdp(), n_standard=3, n_greedy=1, mcts_config=cfg,
                     seed=1, engine="reference").run()
    r_arr = ProTuner(_mdp(), n_standard=3, n_greedy=1, mcts_config=cfg,
                     seed=1, engine="array").run()
    assert r_arr.plan == r_ref.plan
    assert r_arr.n_evals < r_ref.n_evals
    assert r_arr.cache_hits == r_ref.n_evals - r_arr.n_evals


def test_pinned_worker_preload_chain_is_jax_free():
    """``pick_mp_context`` preloads ``repro.core.ensemble`` into the
    forkserver on the promise that the chain is jax-free (forking a
    jax-threaded process can deadlock; jax lives behind lazy imports in
    ``learned_cost``/``serving.fit``).  A top-level jax import sneaking
    into that chain would silently poison every pinned worker — keep it
    out."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    ) + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c",
         "import sys, repro.core.ensemble; print('jax' in sys.modules)"],
        capture_output=True, text=True, env=env, check=True,
    )
    assert out.stdout.strip() == "False"


def test_cache_watermark_incremental_export():
    """The pinned-worker forward-delta seam: ``export_since(watermark)``
    returns exactly the entries added since the cursor — O(new entries),
    never a whole-table diff — and degrades to a full resync exactly when
    the tables stopped being append-only (an eviction)."""
    c = TranspositionCache()
    c.terminal[(1,)] = 1.0
    wm = c.watermark()
    c.terminal[(2,)] = 2.0
    c.partial[(0,)] = 0.5
    entries, full = c.export_since(wm)
    assert not full
    t, p, tv, pv = entries
    assert t == {(2,): 2.0} and p == {(0,): 0.5} and not tv and not pv
    d = TranspositionCache()
    d.apply_export(entries)
    assert d.terminal == {(2,): 2.0} and d.partial == {(0,): 0.5}
    # nothing new since the current watermark -> empty incremental export
    entries, full = c.export_since(c.watermark())
    assert not full and not entries[0] and not entries[1]
    # no cursor at all -> full snapshot
    entries, full = c.export_since(None)
    assert full and entries[0] == c.terminal
    # an eviction bumps the mutation epoch: length-based cursors are stale
    # and the next export is a full resync (exactly once per epoch)
    wm2 = c.watermark()
    c.terminal[(9, 9)] = 9.0
    c.terminal_version[(9, 9)] = 1
    assert c.evict_learned() == 1
    assert (9, 9) not in c.terminal and not c.terminal_version
    _, full = c.export_since(wm2)
    assert full
    assert not c.export_since(c.watermark())[1]  # new cursor: incremental


def test_pinned_submit_payload_stays_round_sized():
    """The tentpole's O(round) SUBMIT claim, measured on the Table-1
    decode cell: with persistent pinned workers, consecutive mid-run
    rounds ship submit payloads within a constant factor of each other,
    and no round's forward delta ever reaches the one-time init snapshot
    — which is what the stateless pool re-pickled EVERY round, at the
    run's smallest point (the tree then keeps growing every round, so the
    old path's per-round bytes only go up from there)."""
    import pickle

    tuner = ProTuner(
        make_mdp("granite-3-2b", "decode_32k"), n_standard=2, n_greedy=1,
        mcts_config=MCTSConfig(iters_per_decision=16), seed=1,
        engine="array", parallel=True,
    )
    res = tuner.run()
    b = res.submit_bytes_rounds
    assert res.n_worker_restarts == 0 and len(b) >= 4
    # consecutive steady-state rounds (cache warm, constant per-round
    # activity) ship submit payloads within a constant factor of each
    # other — and once the hit rate saturates the forward delta collapses
    # to little more than the root-advance message, even though the trees
    # have grown every single round
    assert b[-2] <= 4 * b[-3] and b[-3] <= 4 * b[-2]
    assert b[-2] < 4096
    # the return side is per-round work too: consecutive rounds stay
    # within a constant factor (no tree-sized growth)
    r = res.return_bytes_rounds
    assert r[-2] <= 4 * r[-3] and r[-3] <= 4 * r[-2]
    # no forward delta approaches the full-state snapshot
    assert max(b) < res.snapshot_bytes
    # and the old path's submit side only grows: at run END the whole
    # state (trees + cache) dwarfs every round delta we actually shipped
    end_state = len(
        pickle.dumps((tuner.mdp, tuner.trees), pickle.HIGHEST_PROTOCOL)
    )
    assert max(b) * 2 < end_state
    # totals are consistent with the per-round counters
    assert res.submit_bytes == sum(b)
    assert res.return_bytes == sum(res.return_bytes_rounds)
    assert res.snapshot_bytes > 0


def test_cache_stats_and_merge():
    c1, c2 = TranspositionCache(), TranspositionCache()
    c1.terminal[(0, 1)] = 3.0
    c1.hits, c1.misses = 4, 1
    c2.terminal[(1, 1)] = 5.0
    c2.partial[(1,)] = 2.0
    c2.hits, c2.misses = 1, 2
    c1.merge(c2)
    assert c1.terminal == {(0, 1): 3.0, (1, 1): 5.0}
    assert c1.partial == {(1,): 2.0}
    assert (c1.hits, c1.misses) == (5, 3)
    assert c1.n_entries == 3
    assert 0 < c1.hit_rate < 1
    # pickling keeps mappings, resets counters (multiprocess protocol)
    import pickle

    c3 = pickle.loads(pickle.dumps(c1))
    assert c3.terminal == c1.terminal and c3.partial == c1.partial
    assert (c3.hits, c3.misses) == (0, 0)


# ---------------------------------------------------------------------------
# Process-pool path
# ---------------------------------------------------------------------------
def test_protuner_reproducible_parallel_on_and_off():
    """Fixed seed => identical plan/cost/decisions, sequential or in the
    process pool, and across repeats."""
    cfg = MCTSConfig(iters_per_decision=12)

    def run(parallel):
        return ProTuner(_mdp(), n_standard=2, n_greedy=1, mcts_config=cfg,
                        seed=7, engine="array", parallel=parallel).run()

    seq1, seq2 = run(False), run(False)
    assert seq1.plan == seq2.plan and seq1.cost == seq2.cost
    par = run(True)
    assert par.plan == seq1.plan
    assert par.cost == seq1.cost
    assert [d["action"] for d in par.decisions] == [
        d["action"] for d in seq1.decisions
    ]


def test_parallel_reference_engine_also_reproducible():
    cfg = MCTSConfig(iters_per_decision=8)
    seq = ProTuner(_mdp(), n_standard=2, n_greedy=0, mcts_config=cfg,
                   seed=3, engine="reference").run()
    par = ProTuner(_mdp(), n_standard=2, n_greedy=0, mcts_config=cfg,
                   seed=3, engine="reference", parallel=True).run()
    assert par.plan == seq.plan and par.cost == seq.cost
    # uncached trees keep private cost-model copies across rounds; each
    # eval must be counted exactly once (regression: was quadratic)
    assert par.n_evals == seq.n_evals


# ---------------------------------------------------------------------------
# SearchBackend protocol
# ---------------------------------------------------------------------------
def test_resolve_backend_covers_all_algos():
    for algo in ["beam", "greedy", "random", "mcts", *TABLE1]:
        b = resolve_backend(algo)
        assert isinstance(b, SearchBackend), algo
    with pytest.raises(ValueError):
        resolve_backend("simulated_annealing")


def test_backends_run_through_protocol():
    for algo in ("beam", "greedy", "random"):
        res = resolve_backend(algo).run(_mdp(), seed=2)
        assert res.plan is not None and res.cost > 0
    res = resolve_backend("mcts_1s", engine="array").run(
        _mdp(), seed=2, n_standard=2, n_greedy=1
    )
    assert res.algo == "mcts_1s" and res.engine == "array"
    assert res.cache_hits > 0


def test_random_search_cached_backend_same_result():
    from repro.core.random_search import RandomBackend

    plain = RandomBackend(n_samples=64).run(_mdp(), seed=9)
    cached = RandomBackend(n_samples=64).run(_mdp(), seed=9, cache=True)
    assert plain.plan == cached.plan and plain.cost == cached.cost
